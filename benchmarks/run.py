"""Benchmark harness -- one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,fig13]

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is the host
wall time of the modeled run where meaningful; ``derived`` is the
figure's metric: normalized traffic, modeled seconds, speedup, error %,
or a 1.0/0.0 claim check).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = {
    "table1": "benchmarks.table1_designs",
    "fig9": "benchmarks.fig9_memory_traffic",
    "fig10": "benchmarks.fig10_performance",
    "fig11": "benchmarks.fig11_energy",
    "fig13": "benchmarks.fig13_vcp",
    "table2": "benchmarks.table2_zoo",
    "kernels": "benchmarks.kernels_bench",
    "roofline": "benchmarks.roofline_lm",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of: "
                    + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod_name = BENCHES[name]
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            rows = mod.run()
            for rname, us, derived in rows:
                print(f"{rname},{us:.1f},{derived}")
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name}/FAILED,0.0,0.0")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
