"""Benchmark harness -- one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,fig13]
                                           [--backend python|vector|analytic]
                                           [--smoke] [--explain-fallbacks]

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is the host
wall time of the modeled run where meaningful; ``derived`` is the
figure's metric: normalized traffic, modeled seconds, speedup, error %,
or a 1.0/0.0 claim check).

``--backend`` selects the execution engine for benchmarks that thread
it through (backend, kernels, table2); ``--smoke`` runs the fast
functional subset used by CI; ``--explain-fallbacks`` runs every
accelerator spec and zoo cascade through the selected backend (default
vector) on small inputs and prints the per-Einsum ``fallback_reasons``
-- the CLI view of vector-path coverage gaps that is otherwise only
visible on ``SimResult``.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback


#: every accelerator spec, graph design (BFS + SSSP), and zoo cascade
#: must run native on the vector path.  The two plan classes still
#: outside the VectorPlan IR have no zoo representative: update-in-place
#: outputs whose declared order differs from the execution order, and
#: non-atomic sums (summands whose ranks do not align with the full
#: loop nest).  A regression of any listed entry exits nonzero.
REMAINING_REASONS = (
    "update-in-place output not in execution form",
    "summands with unaligned ranks (non-atomic sum)",
)


def explain_fallbacks(backend: str) -> int:
    """Print ``cascade,einsum,reason`` for every Einsum the selected
    backend routed through the Python oracle; returns the number of
    fallbacks across accelerator specs, graph designs, and zoo
    cascades (0 = full native coverage -- the CI gate)."""
    import numpy as np

    from repro.accelerators import DEFAULT_PARAMS, REGISTRY, simulate
    from repro.accelerators.zoo import ZOO
    from repro.core.einsum import Semiring
    from repro.core.generator import CascadeSimulator
    from benchmarks.table2_zoo import _inputs
    from benchmarks.workloads import grid_graph

    rng = np.random.default_rng(0)
    a = rng.random((24, 24)) * (rng.random((24, 24)) < 0.2)
    b = rng.random((24, 24)) * (rng.random((24, 24)) < 0.2)
    shapes = {"m": 24, "k": 24, "n": 24}
    print("cascade,einsum,reason")
    n_fallbacks = 0

    def report(name, reasons, downgrades=None):
        nonlocal n_fallbacks
        # kernel-level degradation-chain events (seam faults absorbed
        # by the guarded dispatcher) -- distinct from Einsum fallbacks:
        # the Einsum still ran on the vector path, just on a lower
        # backend; reported for visibility, not counted against the
        # native-coverage gate
        for einsum, evs in sorted((downgrades or {}).items()):
            for ev in evs:
                arrow = f"->{ev.fallback}" if ev.fallback else ""
                print(f"{name},{einsum},DOWNGRADE {ev.action} "
                      f"{ev.seam}@{ev.backend}{arrow}: {ev.exc_type}")
        if not reasons:
            print(f"{name},-,native")
            return
        for einsum, reason in sorted(reasons.items()):
            n_fallbacks += 1
            print(f"{name},{einsum},{reason}")

    graph_designs = [n for n in REGISTRY
                     if n.startswith("graph") or n == "ours-vcp"]
    for name in sorted(REGISTRY):
        if name in graph_designs:
            continue                 # graph designs need graph inputs
        try:
            res = simulate(name, {"A": a, "B": b}, shapes,
                           params=DEFAULT_PARAMS.get(name),
                           backend=backend, model=False)
        except Exception as e:       # pragma: no cover - diagnostic path
            print(f"{name},-,ERROR: {e}")
            n_fallbacks += 1
            continue
        report(name, res.fallback_reasons,
               getattr(res, "downgrade_events", None))

    # graph designs: one BFS (unweighted) + one SSSP (weighted) pass
    # under the min-plus semiring on a small grid frontier
    adj_w = grid_graph(6, extra=6, weighted=True)
    adj_u = grid_graph(6, extra=6, weighted=False)
    v = adj_w.shape[0]
    a0 = np.zeros(v)
    a0[0] = 1.0
    p0 = np.zeros(v)
    p0[0] = 1.0
    for name in sorted(graph_designs):
        for algo, adj in (("bfs", adj_u), ("sssp", adj_w)):
            kw = {"n_vertices": v} if name == "graphdyns" else {}
            try:
                res = simulate(name, {"G": adj, "A0": a0, "P0": p0},
                               {"d": v, "s": v}, backend=backend,
                               model=False, semiring=Semiring.min_plus(),
                               weighted=(algo == "sssp"), **kw)
            except Exception as e:   # pragma: no cover - diagnostic path
                print(f"{name}/{algo},-,ERROR: {e}")
                n_fallbacks += 1
                continue
            report(f"{name}/{algo}", res.fallback_reasons,
                   getattr(res, "downgrade_events", None))

    for name in sorted(ZOO):
        inputs, shp = _inputs(name, np.random.default_rng(0))
        sim = CascadeSimulator(ZOO[name](), model=False, backend=backend)
        res = sim.run(dict(inputs), shp)
        report(name, res.fallback_reasons,
               getattr(res, "downgrade_events", None))
    if n_fallbacks == 0:
        print("# full native coverage; plan classes still outside the "
              "IR (no zoo representative):", file=sys.stderr)
        for r in REMAINING_REASONS:
            print(f"#   - {r}", file=sys.stderr)
    return n_fallbacks

BENCHES = {
    "table1": "benchmarks.table1_designs",
    "fig9": "benchmarks.fig9_memory_traffic",
    "fig10": "benchmarks.fig10_performance",
    "fig11": "benchmarks.fig11_energy",
    "fig13": "benchmarks.fig13_vcp",
    "table2": "benchmarks.table2_zoo",
    "kernels": "benchmarks.kernels_bench",
    "roofline": "benchmarks.roofline_lm",
    "backend": "benchmarks.backend_throughput",
    "dse": "benchmarks.dse_sweep",
}

SMOKE_BENCHES = ["backend", "dse"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of: "
                    + ",".join(BENCHES))
    ap.add_argument("--backend", type=str, default=None,
                    choices=["python", "vector", "analytic", "both"],
                    help="execution backend for benchmarks that "
                    "support selection")
    ap.add_argument("--smoke", action="store_true",
                    help="fast functional subset (CI)")
    ap.add_argument("--explain-fallbacks", action="store_true",
                    help="print per-Einsum fallback_reasons for every "
                    "accelerator and zoo cascade, then exit")
    ap.add_argument("--trace", type=str, default=None, metavar="OUT",
                    help="write a Perfetto-loadable Chrome trace "
                    "(*.jsonl for the structured event log) covering "
                    "every benchmark in the run")
    args = ap.parse_args()
    if args.explain_fallbacks:
        n = explain_fallbacks(args.backend or "vector")
        if n and (args.backend or "vector") == "vector":
            # every validated accelerator design must run native on
            # the vector path (the CI coverage gate)
            raise SystemExit(1)
        return
    if args.only:
        names = args.only.split(",")
    elif args.smoke:
        names = list(SMOKE_BENCHES)
    else:
        names = list(BENCHES)

    from repro.obs.export import cli_trace
    print("name,us_per_call,derived")
    failures = 0
    with cli_trace(args.trace):
        for name in names:
            mod_name = BENCHES[name]
            t0 = time.time()
            try:
                mod = __import__(mod_name, fromlist=["run"])
                kwargs = {}
                params = inspect.signature(mod.run).parameters
                if args.backend is not None and "backend" in params:
                    # 'both' is a harness-level concept only the
                    # throughput bench understands; single-backend
                    # benches keep their default rather than receiving
                    # an invalid selection
                    if args.backend != "both" or name == "backend":
                        kwargs["backend"] = args.backend
                if args.smoke and "smoke" in params:
                    kwargs["smoke"] = True
                rows = mod.run(**kwargs)
                for rname, us, derived in rows:
                    print(f"{rname},{us:.1f},{derived}")
                print(f"# {name} done in {time.time() - t0:.1f}s",
                      file=sys.stderr)
            except Exception:
                failures += 1
                traceback.print_exc()
                print(f"{name}/FAILED,0.0,0.0")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
