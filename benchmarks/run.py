"""Benchmark harness -- one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,fig13]
                                           [--backend python|vector|analytic]
                                           [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is the host
wall time of the modeled run where meaningful; ``derived`` is the
figure's metric: normalized traffic, modeled seconds, speedup, error %,
or a 1.0/0.0 claim check).

``--backend`` selects the execution engine for benchmarks that thread
it through (backend, kernels, table2); ``--smoke`` runs the fast
functional subset used by CI.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

BENCHES = {
    "table1": "benchmarks.table1_designs",
    "fig9": "benchmarks.fig9_memory_traffic",
    "fig10": "benchmarks.fig10_performance",
    "fig11": "benchmarks.fig11_energy",
    "fig13": "benchmarks.fig13_vcp",
    "table2": "benchmarks.table2_zoo",
    "kernels": "benchmarks.kernels_bench",
    "roofline": "benchmarks.roofline_lm",
    "backend": "benchmarks.backend_throughput",
    "dse": "benchmarks.dse_sweep",
}

SMOKE_BENCHES = ["backend", "dse"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of: "
                    + ",".join(BENCHES))
    ap.add_argument("--backend", type=str, default=None,
                    choices=["python", "vector", "analytic", "both"],
                    help="execution backend for benchmarks that "
                    "support selection")
    ap.add_argument("--smoke", action="store_true",
                    help="fast functional subset (CI)")
    args = ap.parse_args()
    if args.only:
        names = args.only.split(",")
    elif args.smoke:
        names = list(SMOKE_BENCHES)
    else:
        names = list(BENCHES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod_name = BENCHES[name]
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            kwargs = {}
            params = inspect.signature(mod.run).parameters
            if args.backend is not None and "backend" in params:
                # 'both' is a harness-level concept only the throughput
                # bench understands; single-backend benches keep their
                # default rather than receiving an invalid selection
                if args.backend != "both" or name == "backend":
                    kwargs["backend"] = args.backend
            if args.smoke and "smoke" in params:
                kwargs["smoke"] = True
            rows = mod.run(**kwargs)
            for rname, us, derived in rows:
                print(f"{rname},{us:.1f},{derived}")
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name}/FAILED,0.0,0.0")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
