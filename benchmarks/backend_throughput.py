"""Simulator-throughput benchmark: elements/sec per execution backend.

Runs three SpMSpM mappings on synthetic uniform sparse matrices
through the execution backends and reports throughput as *leaf
multiply operations per second* -- the loop-nest work unit both
backends count identically (``compute mul`` actions, verified equal by
tests/test_backends.py):

  * ``rowwise``      unpartitioned Gustavson (zoo), up to 10k x 10k at
                     1% -- the legacy baseline series;
  * ``flattened``    SIGMA-style mapping: K shape-split, (M, K0)
                     flattened, MK0 occupancy-split, output ranks bound
                     at the leaf -- runs through the vector path's CSF
                     transform pre-pass;
  * ``partitioned``  OuterSPACE/Gamma-style double occupancy split of
                     M and K.

The Python interpreter is capped at ``PY_MAX_SIZE`` (its rate is flat
in problem size, so the cap does not flatter it); the vector backend
runs every size through ``VectorBackend.execute_csf`` -- columnar in,
columnar out, no per-element Python objects on the hot path.

``python -m benchmarks.backend_throughput --record`` rewrites
BENCH_backend.json, the perf-trajectory baseline later PRs must beat
(``vector_rate`` is the legacy rowwise key; the mapped workloads add
``vector_rate_flattened`` / ``vector_rate_partitioned``).
"""
from __future__ import annotations

import argparse
import ctypes
import gc
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.accelerators.zoo import rowwise_spmspm
from repro.core.csf import CSF
from repro.core.iteration import PythonBackend
from repro.core.mapping import MappingResolver
from repro.core.spec import AcceleratorSpec, load_spec
from repro.core.trace import CollectingInstr
from repro.core.vectorized import VectorBackend

SIZES = [1024, 4096, 10000]
MAPPED_SIZES = [1024, 4096]          # flattened/partitioned series
SMOKE_SIZES = [256]
DENSITY = 0.01
PY_MAX_SIZE = 1024
BENCH_JSON = Path(__file__).resolve().parent.parent / "BENCH_backend.json"


def flattened_spmspm(k_tile: int = 128,
                     stationary: int = 4096) -> AcceleratorSpec:
    """SIGMA-style flattened mapping of plain SpMSpM: K shape-split at
    the FlexDPE granularity, (M, K0) flattened, the flattened nonzeros
    occupancy-distributed; Z's coordinates are recovered from index-var
    bindings at the leaf (no loop rank matches an output rank)."""
    return load_spec({
        "name": "Flattened-SpMSpM",
        "einsum": {
            "declaration": {
                "A": ["K", "M"],
                "B": ["K", "N"],
                "Z": ["M", "N"],
            },
            "expressions": ["Z[m, n] = A[k, m] * B[k, n]"],
        },
        "mapping": {
            "rank-order": {"A": ["K", "M"], "B": ["K", "N"],
                           "Z": ["M", "N"]},
            "partitioning": {
                "Z": {
                    "K": [f"uniform_shape({k_tile})"],
                    "(M, K0)": ["flatten()"],
                    "MK0": [f"uniform_occupancy(A.{stationary})"],
                },
            },
            "loop-order": {"Z": ["K1", "MK01", "MK00", "N"]},
        },
    })


def partitioned_spmspm(rows: int = 128,
                       k_tile: int = 256) -> AcceleratorSpec:
    """OuterSPACE/Gamma-style partitioned mapping of plain SpMSpM:
    rows of A occupancy-cycled, K occupancy-split per row batch; B is
    fetched by coordinate (leader-follower boundaries are per-fiber, so
    B stays unpartitioned and co-iterates at K0)."""
    return load_spec({
        "name": "Partitioned-SpMSpM",
        "einsum": {
            "declaration": {
                "A": ["M", "K"],
                "B": ["K", "N"],
                "Z": ["M", "N"],
            },
            "expressions": ["Z[m, n] = A[m, k] * B[k, n]"],
        },
        "mapping": {
            "partitioning": {
                "Z": {
                    "M": [f"uniform_occupancy(A.{rows})"],
                    "K": [f"uniform_occupancy(A.{k_tile})"],
                },
            },
            "loop-order": {"Z": ["M1", "M0", "K1", "K0", "N"]},
        },
    })


MAPPED_WORKLOADS = {
    "flattened": (flattened_spmspm, ["K", "M"]),
    "partitioned": (partitioned_spmspm, ["M", "K"]),
}


def synth_csf(n: int, density: float, seed: int, name: str,
              ranks: List[str]) -> CSF:
    """Uniform random n x n sparse matrix, built columnar (no dense
    intermediate, so 10k x 10k stays cheap)."""
    rng = np.random.default_rng(seed)
    nnz = int(n * n * density)
    flat = np.unique(rng.integers(0, n * n, size=int(nnz * 1.03)))
    rng.shuffle(flat)
    flat = np.sort(flat[:nnz])
    pts = np.stack([flat // n, flat % n], axis=1)
    vals = rng.random(len(pts)) + 0.1
    return CSF.from_coo(name, ranks, pts, vals, {r: n for r in ranks})


def _trim_allocator() -> None:
    """Return freed arenas to the OS between reps.  A fragmented glibc
    heap makes large fresh allocations fault in 4k pages instead of
    huge pages, which can triple the wall time of the same columnar
    run -- measured 8s -> 21s on the 4096 rowwise workload."""
    gc.collect()
    try:
        ctypes.CDLL("libc.so.6").malloc_trim(0)
    except Exception:
        pass


def _measure_vector(plan, a: CSF, b: CSF,
                    reps: int = 3) -> Tuple[float, int, int]:
    """Best-of-``reps`` wall time: the work is deterministic, so the
    minimum is the least allocator- and page-fault-contaminated sample."""
    best = float("inf")
    for _ in range(reps):
        _trim_allocator()
        vb = VectorBackend()
        t0 = time.time()
        _, stats = vb.execute_csf(plan, {"A": a, "B": b})
        best = min(best, time.time() - t0)
        del vb
    return best, stats["muls"], stats["out_nnz"]


def _measure_python(plan, a: CSF, b: CSF, n: int) -> Tuple[float, int, int]:
    fa, fb = a.to_ftensor(), b.to_ftensor()
    ci = CollectingInstr()
    t0 = time.time()
    out = PythonBackend().execute(plan, {"A": fa, "B": fb},
                                  {"m": n, "k": n, "n": n}, instr=ci)
    dt = time.time() - t0
    return dt, int(ci.compute_counts[("Z", "mul")]), out.nnz


def _measure_analytic(plan, a: CSF, b: CSF, n: int
                      ) -> Tuple[float, int, int]:
    """Modeled (not executed) multiplies per second of wall time: the
    calibration scan dominates, the propagation itself is closed-form."""
    from repro.core.analytic import AnalyticBackend

    fa, fb = a.to_ftensor(), b.to_ftensor()
    ci = CollectingInstr()
    t0 = time.time()
    AnalyticBackend(fallback=False).execute(
        plan, {"A": fa, "B": fb}, {"m": n, "k": n, "n": n}, instr=ci)
    dt = time.time() - t0
    return dt, int(ci.compute_counts[("Z", "mul")]), 0


def bench(sizes: Optional[List[int]] = None, backend: str = "both",
          py_max_size: int = PY_MAX_SIZE, density: float = DENSITY,
          mapped_sizes: Optional[List[int]] = None) -> List[Dict]:
    spec = rowwise_spmspm()
    plan = MappingResolver(spec).plan("Z")
    # warm lazy imports (jax) outside the timed region
    tiny = synth_csf(64, 0.05, 0, "A", ["M", "K"])
    tinyb = synth_csf(64, 0.05, 1, "B", ["K", "N"])
    VectorBackend().execute_csf(plan, {"A": tiny, "B": tinyb})

    records: List[Dict] = []
    for n in (sizes or SIZES):
        a = synth_csf(n, density, 1, "A", ["M", "K"])
        b = synth_csf(n, density, 2, "B", ["K", "N"])
        runs = []
        if backend in ("vector", "both"):
            runs.append(("vector", _measure_vector(plan, a, b)))
        if backend in ("python", "both") and n <= py_max_size:
            runs.append(("python", _measure_python(plan, a, b, n)))
        if backend == "analytic":
            runs.append(("analytic", _measure_analytic(plan, a, b, n)))
        for bname, (dt, muls, out_nnz) in runs:
            records.append({
                "workload": "rowwise", "backend": bname, "size": n,
                "density": density,
                "nnz_a": a.nnz, "nnz_b": b.nnz, "out_nnz": out_nnz,
                "elements": muls, "seconds": round(dt, 4),
                "elements_per_sec": round(muls / dt, 1) if dt else 0.0,
            })

    # flattened / partitioned mappings: vector path only (raw CSFs in,
    # the Section-3.2 transform pre-pass runs inside execute_csf)
    if backend in ("vector", "both"):
        for wname, (factory, a_ranks) in MAPPED_WORKLOADS.items():
            mplan = MappingResolver(factory()).plan("Z")
            for n in (mapped_sizes if mapped_sizes is not None
                      else MAPPED_SIZES):
                a = synth_csf(n, density, 1, "A", a_ranks)
                b = synth_csf(n, density, 2, "B", ["K", "N"])
                dt, muls, out_nnz = _measure_vector(mplan, a, b)
                records.append({
                    "workload": wname, "backend": "vector", "size": n,
                    "density": density,
                    "nnz_a": a.nnz, "nnz_b": b.nnz, "out_nnz": out_nnz,
                    "elements": muls, "seconds": round(dt, 4),
                    "elements_per_sec": round(muls / dt, 1) if dt else 0.0,
                })
    return records


def summarize(records: List[Dict]) -> Dict:
    by = {}
    for r in records:
        if r.get("workload", "rowwise") == "rowwise":
            by.setdefault(r["backend"], []).append(r)
    workloads = sorted({r.get("workload", "rowwise") for r in records})
    out: Dict = {"workload": "spmspm",
                 "mappings": workloads,
                 "metric": "leaf multiplies per second",
                 "records": records}
    for wname in MAPPED_WORKLOADS:
        ws = [r for r in records
              if r.get("workload") == wname and r["backend"] == "vector"]
        if ws:
            best = max(ws, key=lambda r: r["size"])
            out[f"vector_rate_{wname}"] = best["elements_per_sec"]
            out[f"vector_rate_{wname}_measured_at"] = best["size"]
    if "python" in by and "vector" in by:
        py_best = max(by["python"], key=lambda r: r["size"])
        vec_best = max(by["vector"], key=lambda r: r["size"])
        out["python_rate"] = py_best["elements_per_sec"]
        out["python_measured_at"] = py_best["size"]
        out["vector_rate"] = vec_best["elements_per_sec"]
        out["vector_measured_at"] = vec_best["size"]
        # cross-size rate ratio: the interpreter is rate-capped (its
        # per-element cost is flat in problem size) and measured at its
        # feasible cap; same-size ratio below is the apples-to-apples one
        out["speedup"] = round(vec_best["elements_per_sec"]
                               / py_best["elements_per_sec"], 2)
        common = set(r["size"] for r in by["python"]) \
            & set(r["size"] for r in by["vector"])
        if common:
            n = max(common)
            pr = next(r for r in by["python"] if r["size"] == n)
            vr = next(r for r in by["vector"] if r["size"] == n)
            out["speedup_same_size"] = round(
                vr["elements_per_sec"] / pr["elements_per_sec"], 2)
            assert pr["elements"] == vr["elements"], \
                "backends disagree on work performed"
    return out


def run(backend: str = "both", smoke: bool = False
        ) -> List[Tuple[str, float, float]]:
    """benchmarks.run entry point: CSV rows (name, us, derived)."""
    sizes = SMOKE_SIZES if smoke else SIZES
    py_max = max(sizes) if smoke else PY_MAX_SIZE
    records = bench(sizes=sizes, backend=backend, py_max_size=py_max,
                    mapped_sizes=SMOKE_SIZES if smoke else None)
    rows = []
    for r in records:
        rows.append((f"backend/{r['workload']}/{r['backend']}/n{r['size']}",
                     r["seconds"] * 1e6, r["elements_per_sec"]))
    summary = summarize(records)
    if "speedup" in summary:
        rows.append(("backend/speedup_vector_over_python", 0.0,
                     summary["speedup"]))
    return rows


def profile_stages(sizes: Optional[List[int]] = None) -> None:
    """Per-stage wall-time breakdown of the vector path (materialize /
    pair-merge / lookup / finalize / reduce / output-build) for each
    workload, from the backend's public ``stage_seconds`` accessor
    (the same dict ``SimResult.stage_seconds`` surfaces)."""
    jobs: List[Tuple[str, object, List[str], int]] = []
    plan = MappingResolver(rowwise_spmspm()).plan("Z")
    for n in (sizes or [SIZES[-1]]):
        jobs.append(("rowwise", plan, ["M", "K"], n))
    for wname, (factory, a_ranks) in MAPPED_WORKLOADS.items():
        mplan = MappingResolver(factory()).plan("Z")
        for n in (sizes or [MAPPED_SIZES[-1]]):
            jobs.append((wname, mplan, a_ranks, n))
    for wname, plan_, a_ranks, n in jobs:
        a = synth_csf(n, DENSITY, 1, "A", a_ranks)
        b = synth_csf(n, DENSITY, 2, "B", ["K", "N"])
        vb = VectorBackend(profile=True)
        vb.execute_csf(plan_, {"A": a, "B": b})      # warm
        _trim_allocator()
        t0 = time.time()
        _, stats = vb.execute_csf(plan_, {"A": a, "B": b})
        wall = time.time() - t0
        stage_seconds = vb.stage_seconds
        staged = sum(stage_seconds.values())
        print(f"{wname} n={n}: {wall:.3f}s wall, "
              f"{stats['muls'] / max(wall, 1e-9) / 1e6:.2f} M muls/s")
        for stage, dt in sorted(stage_seconds.items(),
                                key=lambda kv: -kv[1]):
            print(f"  {stage:<14} {dt:7.3f}s  {dt / wall * 100:5.1f}%")
        print(f"  {'(untracked)':<14} {wall - staged:7.3f}s  "
              f"{(wall - staged) / wall * 100:5.1f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--record", action="store_true",
                    help=f"rewrite {BENCH_JSON.name} (preserves the "
                         f"kernel_rates section, see kernels_bench.py)")
    ap.add_argument("--backend", default="both",
                    choices=["python", "vector", "analytic", "both"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated sizes override")
    ap.add_argument("--profile", action="store_true",
                    help="print per-stage vector-path wall-time "
                         "breakdown instead of recording rates")
    ap.add_argument("--trace", type=str, default=None, metavar="OUT",
                    help="write a Perfetto-loadable Chrome trace "
                         "(*.jsonl for the structured event log) of "
                         "the benchmark run")
    args = ap.parse_args()
    sizes = ([int(s) for s in args.sizes.split(",")] if args.sizes
             else (SMOKE_SIZES if args.smoke else SIZES))
    if args.profile:
        profile_stages(sizes if args.sizes or args.smoke else None)
        return
    from repro.obs.export import cli_trace
    with cli_trace(args.trace):
        records = bench(sizes=sizes, backend=args.backend,
                        py_max_size=max(sizes) if args.smoke
                        else PY_MAX_SIZE,
                        mapped_sizes=SMOKE_SIZES if args.smoke else None)
    summary = summarize(records)
    print(json.dumps(summary, indent=2))
    if args.record:
        if BENCH_JSON.exists():
            prev = json.loads(BENCH_JSON.read_text())
            if "kernel_rates" in prev:
                summary["kernel_rates"] = prev["kernel_rates"]
        BENCH_JSON.write_text(json.dumps(summary, indent=2) + "\n")
        print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
