"""Fig. 13: the Sec.-8 design study -- Graphicionado vs GraphDynS vs
our proposal on BFS and SSSP (sparse active-vertex-set algorithms).

Paper claims validated (direction, at simulator scale):
  * GraphDynS speeds up Graphicionado,
  * our proposal speeds up GraphDynS on BFS (paper: 1.9x) and SSSP
    (paper: 1.2x), with BFS > SSSP gains (BFS drops the weight loads).

The semiring- and affine-generalized vector pipeline runs all three
designs natively (``fallback_reasons == {}``) under ``min_plus``, so
the study executes at 10^5+ vertices on columnar CSF graphs -- sizes
the per-element Python interpreter could never touch.  ``--record``
writes the result as the committed BENCH_graph.json baseline;
``--check`` (and every run's exit code) gates on the two Fig.-13
direction claims.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.workloads import sparse_grid_graph
from repro.accelerators import graphicionado as G
from repro.core.einsum import Semiring
from repro.core.generator import CascadeSimulator

BENCH_JSON = Path(__file__).resolve().parent.parent / "BENCH_graph.json"
FULL_SIDE = 362                      # 362^2 = 131044 vertices (>= 10^5)
SMOKE_SIDE = 48
MAX_ITERS = 64                       # BFS wavefront cap: every design
                                     # sees the identical frontier schedule

#: paper Sec.-8 direction claims the benchmark (and CI) gate on
GATED_CLAIMS = ("graphdyns_beats_graphicionado", "ours_beats_graphdyns_bfs")


def _designs(weighted: bool, v: int):
    return {
        "graphicionado": G.graphicionado_spec(weighted=weighted),
        "graphdyns": G.graphdyns_spec(weighted=weighted, n_vertices=v),
        "ours": G.improved_spec(weighted=weighted),
    }


def _run(spec, g_ft, v: int, backend: str = "vector") -> Dict:
    a0 = np.zeros(v)
    a0[0] = 1.0
    p0 = np.zeros(v)
    p0[0] = 1.0                      # properties stored as distance+1
    sim = CascadeSimulator(spec, semiring=Semiring.min_plus(),
                           backend=backend)
    t0 = time.time()
    res, iters = sim.run_iterative(
        {"G": g_ft, "A0": a0, "P0": p0},
        carry={"A0": "A1", "P0": "P1"}, done_when_empty="A1",
        max_iters=MAX_ITERS, var_shapes={"d": v, "s": v})
    return {
        "modeled_seconds": res.report.seconds,
        "wall_seconds": round(time.time() - t0, 3),
        "iters": iters,
        "fallback_reasons": dict(res.fallback_reasons),
        "reached": int(res.tensors["P1"].nnz),
    }


def bench(side: int = FULL_SIDE, backend: str = "vector",
          seed: int = 0) -> Dict:
    v = side * side
    extra = v // 16                  # small-world shortcuts
    summary: Dict = {"vertices": v, "grid_side": side, "extra": extra,
                     "max_iters": MAX_ITERS, "backend": backend,
                     "runs": {}, "speedups": {}, "claims": {}}
    times: Dict[str, Dict[str, float]] = {}
    for algo, weighted in (("bfs", False), ("sssp", True)):
        g = sparse_grid_graph(side, extra=extra, weighted=weighted,
                              seed=seed)
        summary.setdefault("edges", g.nnz)
        times[algo] = {}
        for name, spec in _designs(weighted, v).items():
            r = _run(spec, g, v, backend=backend)
            summary["runs"][f"{algo}/{name}"] = r
            times[algo][name] = r["modeled_seconds"]
    for algo in ("bfs", "sssp"):
        t = times[algo]
        summary["speedups"][f"{algo}/graphdyns_over_graphicionado"] = \
            round(t["graphicionado"] / t["graphdyns"], 3)
        summary["speedups"][f"{algo}/ours_over_graphdyns"] = \
            round(t["graphdyns"] / t["ours"], 3)
    sp = summary["speedups"]
    summary["claims"] = {
        "graphdyns_beats_graphicionado":
            sp["bfs/graphdyns_over_graphicionado"] > 1.0
            and sp["sssp/graphdyns_over_graphicionado"] > 1.0,
        "ours_beats_graphdyns_bfs": sp["bfs/ours_over_graphdyns"] > 1.0,
        "ours_beats_graphdyns_sssp": sp["sssp/ours_over_graphdyns"] > 1.0,
        "bfs_gain_exceeds_sssp_gain":
            sp["bfs/ours_over_graphdyns"] > sp["sssp/ours_over_graphdyns"],
        "all_native": all(not r["fallback_reasons"]
                          for r in summary["runs"].values()),
    }
    return summary


def run(smoke: bool = False, backend: str = "vector"
        ) -> List[Tuple[str, float, float]]:
    """benchmarks.run entry point: CSV rows (name, us, derived)."""
    summary = bench(side=SMOKE_SIDE if smoke else FULL_SIDE,
                    backend=backend)
    rows: List[Tuple[str, float, float]] = []
    for key, r in summary["runs"].items():
        rows.append((f"fig13/{key}", r["wall_seconds"] * 1e6,
                     r["modeled_seconds"]))
    for key, s in summary["speedups"].items():
        rows.append((f"fig13/speedup/{key}", 0.0, s))
    for key, ok in summary["claims"].items():
        rows.append((f"fig13/claim/{key}", 0.0, float(ok)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--record", action="store_true",
                    help=f"rewrite {BENCH_JSON.name}")
    ap.add_argument("--check", action="store_true",
                    help=f"compare against committed {BENCH_JSON.name}")
    ap.add_argument("--smoke", action="store_true",
                    help=f"{SMOKE_SIDE}^2 vertices instead of "
                    f"{FULL_SIDE}^2")
    ap.add_argument("--side", type=int, default=None,
                    help="grid side override (vertices = side^2)")
    ap.add_argument("--backend", default="vector",
                    choices=["python", "vector"])
    args = ap.parse_args()
    side = args.side or (SMOKE_SIDE if args.smoke else FULL_SIDE)
    summary = bench(side=side, backend=args.backend)
    print(json.dumps(summary, indent=2))
    if args.record:
        BENCH_JSON.write_text(json.dumps(summary, indent=2) + "\n")
        print(f"wrote {BENCH_JSON}")
    failed = [c for c in GATED_CLAIMS if not summary["claims"][c]]
    if not summary["claims"]["all_native"]:
        failed.append("all_native")
    if args.check and BENCH_JSON.exists():
        base = json.loads(BENCH_JSON.read_text())
        for c in GATED_CLAIMS:
            if base["claims"].get(c) and not summary["claims"][c]:
                failed.append(f"regressed:{c}")
    if failed:
        print(f"FAILED direction claims: {failed}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
