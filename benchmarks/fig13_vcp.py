"""Fig. 13: the Sec.-8 design study -- Graphicionado vs GraphDynS vs
our proposal on BFS and SSSP (sparse active-vertex-set algorithms).

Paper claims validated (direction, at simulator scale):
  * GraphDynS speeds up Graphicionado,
  * our proposal speeds up GraphDynS on BFS (paper: 1.9x) and SSSP
    (paper: 1.2x), with BFS > SSSP gains (BFS drops the weight loads).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.workloads import grid_graph, powerlaw_graph
from repro.accelerators import graphicionado as G
from repro.core.einsum import Semiring
from repro.core.generator import CascadeSimulator


def _run(spec, adj, max_iters=300) -> float:
    v = adj.shape[0]
    a0 = np.zeros(v)
    a0[0] = 1.0
    p0 = np.zeros(v)
    p0[0] = 1.0
    sim = CascadeSimulator(spec, semiring=Semiring.min_plus())
    res, _ = sim.run_iterative(
        {"G": adj, "A0": a0, "P0": p0},
        carry={"A0": "A1", "P0": "P1"}, done_when_empty="A1",
        max_iters=max_iters, var_shapes={"d": v, "s": v})
    return res.report.seconds


def run() -> List[Tuple[str, float, float]]:
    rows = []
    speedups: Dict[str, Dict[str, float]] = {"bfs": {}, "sssp": {}}
    for algo, weighted in (("bfs", False), ("sssp", True)):
        for gname, adj in (
                ("grid", grid_graph(16, extra=16, weighted=weighted)),
                ("powerlaw", powerlaw_graph(200, 3.0,
                                            weighted=weighted))):
            v = adj.shape[0]
            designs = {
                "graphicionado": G.graphicionado_spec(weighted=weighted),
                "graphdyns": G.graphdyns_spec(weighted=weighted,
                                              n_vertices=v),
                "ours": G.improved_spec(weighted=weighted),
            }
            times = {}
            for name, spec in designs.items():
                t0 = time.time()
                times[name] = _run(spec, adj)
                us = (time.time() - t0) * 1e6
                rows.append((f"fig13/{algo}/{gname}/{name}", us,
                             times[name]))
            rows.append((f"fig13/{algo}/{gname}/ours_over_graphdyns",
                         0.0, round(times["graphdyns"] / times["ours"],
                                    3)))
            if gname == "grid":
                speedups[algo]["gd"] = times["graphdyns"] / times["ours"]
                speedups[algo]["gr"] = (times["graphicionado"]
                                        / times["ours"])

    rows.append(("fig13/claim/ours_beats_graphdyns_bfs", 0.0,
                 float(speedups["bfs"]["gd"] > 1.0)))
    rows.append(("fig13/claim/ours_beats_graphdyns_sssp", 0.0,
                 float(speedups["sssp"]["gd"] > 1.0)))
    rows.append(("fig13/claim/ours_beats_graphicionado", 0.0,
                 float(speedups["bfs"]["gr"] > 1.0
                       and speedups["sssp"]["gr"] > 1.0)))
    return rows
