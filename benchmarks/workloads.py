"""Synthetic workloads mirroring the paper's data sets at simulator
scale.

The paper evaluates on SuiteSparse/SNAP matrices (8K-63K rows, 100-370K
nnz); the pure-Python fibertree simulator is cycle-accurate but ~10^4x
slower than the ASICs it models, so benchmarks synthesize matrices with
the same STRUCTURAL character (uniform vs power-law row occupancy,
matching density) at 256-512 rows.  All comparisons are RELATIVE
(normalized to the algorithmic minimum or across designs), which is
scale-robust; EXPERIMENTS.md carries the methodology note.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

# name -> (rows, cols, density, row-degree distribution)
# densities match the paper's Table 4 (nnz / (rows*cols))
PAPER_MATRICES: Dict[str, Tuple[int, int, float, str]] = {
    "wi": (256, 256, 1.5e-3 * 16, "powerlaw"),   # wiki-Vote: skewed
    "p2": (320, 320, 3.7e-5 * 160, "uniform"),   # p2p-Gnutella31
    "ca": (256, 256, 3.5e-4 * 40, "powerlaw"),   # ca-CondMat
    "po": (256, 384, 1.1e-3 * 16, "uniform"),    # poisson3Da
    "em": (288, 288, 2.7e-4 * 50, "powerlaw"),   # email-Enron
}


def synth_matrix(name: str, seed: int = 0) -> np.ndarray:
    rows, cols, density, dist = PAPER_MATRICES[name]
    rng = np.random.default_rng(seed + hash(name) % 1000)
    nnz_target = max(8, int(rows * cols * density))
    a = np.zeros((rows, cols))
    if dist == "uniform":
        idx = rng.choice(rows * cols, size=nnz_target, replace=False)
        a.flat[idx] = rng.random(nnz_target) + 0.1
    else:
        # zipf-ish row occupancy (graph degree skew)
        w = 1.0 / np.arange(1, rows + 1) ** 1.1
        row_nnz = rng.multinomial(nnz_target, w / w.sum())
        order = rng.permutation(rows)
        for r, n in zip(order, row_nnz):
            n = min(n, cols)
            if n:
                c = rng.choice(cols, size=n, replace=False)
                a[r, c] = rng.random(n) + 0.1
    return a


def uniform_pair(m=256, k=256, n=256, da=0.1, db=0.1, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.random((m, k)) * (rng.random((m, k)) < da)
    b = rng.random((k, n)) * (rng.random((k, n)) < db)
    return a, b


def grid_graph(side: int, extra: int = 0, weighted: bool = False,
               seed: int = 0) -> np.ndarray:
    """2D grid + shortcuts: the sparse-frontier BFS/SSSP workload."""
    v = side * side
    adj = np.zeros((v, v))
    for i in range(side):
        for j in range(side):
            u = i * side + j
            if j + 1 < side:
                adj[u + 1, u] = 1
            if i + 1 < side:
                adj[u + side, u] = 1
    rng = np.random.default_rng(seed)
    for _ in range(extra):
        s, d = rng.integers(0, v, 2)
        if s != d:
            adj[d, s] = 1
    if weighted:
        adj = adj * rng.integers(1, 8, size=adj.shape)
    return adj


def powerlaw_graph(v: int = 256, avg_deg: float = 4.0, weighted=False,
                   seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, v + 1) ** 1.0
    p = w / w.sum()
    nnz = int(v * avg_deg)
    src = rng.choice(v, size=nnz, p=p)
    dst = rng.choice(v, size=nnz, p=p)
    adj = np.zeros((v, v))
    for s, d in zip(src, dst):
        if s != d:
            adj[d, s] = rng.integers(1, 8) if weighted else 1.0
    return adj


def sparse_grid_graph(side: int, extra: int = 0, weighted: bool = False,
                      seed: int = 0):
    """2D grid + random shortcuts as a columnar FTensor in stored order
    [S, D] -- the sparse-frontier BFS/SSSP workload of ``grid_graph``,
    built without the dense v x v adjacency so 10^5+ vertex runs are
    feasible.  High diameter keeps per-iteration frontiers small
    relative to v (the regime where partition-gated property loading
    pays off)."""
    from repro.core.csf import CSF

    rng = np.random.default_rng(seed)
    v = side * side
    u = np.arange(v).reshape(side, side)
    src = np.concatenate([u[:, :-1].ravel(), u[:-1, :].ravel()])
    dst = np.concatenate([u[:, 1:].ravel(), u[1:, :].ravel()])
    if extra:
        s = rng.integers(0, v, size=extra)
        d = rng.integers(0, v, size=extra)
        keep = s != d
        src = np.concatenate([src, s[keep]])
        dst = np.concatenate([dst, d[keep]])
    vals = (rng.integers(1, 8, size=len(src)).astype(np.float64)
            if weighted else np.ones(len(src)))
    pts = np.stack([src, dst], axis=1).astype(np.int64)
    csf = CSF.from_coo("G", ["S", "D"], pts, vals, {"S": v, "D": v})
    return csf.to_ftensor()


def sparse_graph(v: int, avg_deg: float = 8.0, weighted: bool = False,
                 seed: int = 0, dist: str = "powerlaw"):
    """Power-law (or uniform) random digraph built columnar as an
    FTensor in the graph specs' stored order [S, D] -- no dense v x v
    adjacency, so 10^5+ vertex BFS/SSSP runs are feasible on the
    vector backend.  Duplicate (s, d) draws collapse (last wins)."""
    from repro.core.csf import CSF

    rng = np.random.default_rng(seed)
    nnz = int(v * avg_deg)
    if dist == "powerlaw":
        w = 1.0 / np.arange(1, v + 1) ** 1.0
        p = w / w.sum()
        src = rng.choice(v, size=nnz, p=p)
        dst = rng.choice(v, size=nnz, p=p)
    else:
        src = rng.integers(0, v, size=nnz)
        dst = rng.integers(0, v, size=nnz)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    vals = (rng.integers(1, 8, size=len(src)).astype(np.float64)
            if weighted else np.ones(len(src)))
    pts = np.stack([src, dst], axis=1).astype(np.int64)
    csf = CSF.from_coo("G", ["S", "D"], pts, vals, {"S": v, "D": v})
    return csf.to_ftensor()
