"""DSE throughput benchmark: sweep points evaluated per second.

Runs the Gamma FiberCache-capacity sweep (the paper's Sec.-8 workflow,
``examples/design_space_study.py``) through the DSE engine with each
execution backend and reports **points/sec** -- the metric that decides
whether a real design-space exploration (thousands of configurations)
is feasible.

The analytic backend evaluates the full sweep; the execution-based
backends ('vector' falls back to the Python oracle on Gamma's
partitioned plans, so both are interpreter-speed here) are measured on
a small prefix of the sweep and reported at their per-point rate.

``python -m benchmarks.dse_sweep --record`` rewrites BENCH_dse.json,
the trajectory baseline (acceptance bar: analytic >= 100x vector).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.dse import DesignSpace, SweepEngine, pareto_front

CAPACITIES_MB = [0.001, 0.002, 0.003, 0.005, 0.008, 0.013, 0.02, 0.05,
                 0.1, 0.25, 0.5, 1.0, 2.0, 3.0, 4.0, 6.0]
SMOKE_CAPACITIES_MB = [0.002, 3.0]
EXEC_MAX_POINTS = 2          # execution-backend prefix (interpreter speed)
BENCH_JSON = Path(__file__).resolve().parent.parent / "BENCH_dse.json"


def workload(seed: int = 0, m: int = 96, k: int = 96, n: int = 96,
             da: float = 0.12, db: float = 0.12):
    rng = np.random.default_rng(seed)
    a = rng.random((k, m)) * (rng.random((k, m)) < da)
    b = rng.random((k, n)) * (rng.random((k, n)) < db)
    return {"A": a, "B": b}, {"m": m, "k": k, "n": n}


def fibercache_space(capacities: List[float]) -> DesignSpace:
    return DesignSpace("gamma", axes={"fibercache_mb": capacities})


def _measure(backend: str, capacities: List[float],
             inputs, shapes,
             engine_kw: Optional[Dict] = None,
             sweep_kw: Optional[Dict] = None) -> Dict:
    points = fibercache_space(capacities).grid()
    eng = SweepEngine(inputs, shapes, backend=backend,
                      **(engine_kw or {}))
    # pay one-time setup (operand conversion, plan lowering,
    # calibration, first-call library warmup) outside the timed
    # region: the record measures the steady-state sweep rate a
    # service would observe
    if points:
        from repro.testing.faults import active_injector
        eng.prime(points[0])
        # not under fault injection: a warmup sweep must not consume
        # the chaos schedule the timed sweep is meant to exercise
        if backend == "analytic" and active_injector() is None:
            eng.sweep(points[:2])
    t0 = time.perf_counter()
    results = eng.sweep(points, **(sweep_kw or {}))
    dt = time.perf_counter() - t0
    ok = [r for r in results if r.ok]
    if len(ok) != len(points):
        # hard failure only when no faults were injected: a chaos run
        # legitimately reports a partial front + coverage instead
        from repro.testing.faults import active_injector
        if active_injector() is None:
            raise AssertionError(
                [r.error for r in results if not r.ok])
    front = pareto_front(ok)
    return {
        "backend": backend,
        "points": len(points),
        "seconds": round(dt, 4),
        "points_per_sec": round(len(points) / dt, 3) if dt else 0.0,
        "pareto_points": [r.label for r in front],
        "traffic_range_kb": [round(min(r.dram_bytes for r in ok) / 1e3, 1),
                             round(max(r.dram_bytes for r in ok) / 1e3, 1)]
        if ok else [0.0, 0.0],
        "coverage": dict(eng.last_coverage),
        "summary": SweepEngine.summarize(results),
    }


def bench(capacities: Optional[List[float]] = None,
          backend: str = "all",
          exec_max_points: int = EXEC_MAX_POINTS,
          engine_kw: Optional[Dict] = None,
          sweep_kw: Optional[Dict] = None) -> Dict:
    capacities = capacities or CAPACITIES_MB
    inputs, shapes = workload()
    out: Dict = {"workload": "gamma-fibercache-sweep",
                 "sweep_axis": {"fibercache_mb": capacities},
                 "metric": "sweep points per second",
                 "records": []}
    wanted = (["analytic", "vector", "python"] if backend == "all"
              else [backend])
    for bk in wanted:
        caps = capacities if bk == "analytic" \
            else capacities[:exec_max_points]
        out["records"].append(_measure(bk, caps, inputs, shapes,
                                       engine_kw=engine_kw,
                                       sweep_kw=sweep_kw))
    by = {r["backend"]: r for r in out["records"]}
    if "analytic" in by:
        out["analytic_rate"] = by["analytic"]["points_per_sec"]
    if "analytic" in by and "vector" in by:
        vr = by["vector"]["points_per_sec"]
        out["vector_rate"] = vr
        out["speedup_analytic_over_vector"] = round(
            by["analytic"]["points_per_sec"] / vr, 1) if vr else 0.0
    if "analytic" in by and "python" in by:
        pr = by["python"]["points_per_sec"]
        out["python_rate"] = pr
        out["speedup_analytic_over_python"] = round(
            by["analytic"]["points_per_sec"] / pr, 1) if pr else 0.0
    return out


SCALE_POINTS = 256


def scale_capacities(n: int = SCALE_POINTS) -> List[float]:
    """A dense ``n``-point FiberCache capacity axis (geometric, same
    0.001..6 MB range as ``CAPACITIES_MB``)."""
    return [round(float(c), 6) for c in np.geomspace(0.001, 6.0, n)]


def scale_bench(n_points: int = SCALE_POINTS,
                workers: Tuple[int, ...] = (1, 2, 4)) -> Dict:
    """Production-scale records: a >=256-point axis through the batched
    evaluator, repeat-query serving from the result cache, and the
    process-pool worker-count series (each worker pays its own setup --
    the series reports end-to-end sharded rates, not marginal ones)."""
    from repro.dse import ResultCache

    inputs, shapes = workload()
    points = fibercache_space(scale_capacities(n_points)).grid()
    out: Dict = {"points": len(points)}

    cache = ResultCache(capacity=2 * n_points)
    eng = SweepEngine(inputs, shapes, backend="analytic",
                      result_cache=cache)
    eng.prime(points[0])
    eng.sweep(points[:2])
    t0 = time.perf_counter()
    first = eng.sweep(points)
    dt = time.perf_counter() - t0
    assert all(r.ok for r in first), \
        [r.error for r in first if not r.ok]
    out["batched_rate"] = round(len(points) / dt, 1)

    t0 = time.perf_counter()
    again = eng.sweep(points)
    dt = time.perf_counter() - t0
    assert all(r.cached for r in again)
    out["cache_hit_rate"] = round(len(points) / dt, 1)
    out["cache"] = cache.stats()

    out["worker_scaling"] = []
    for w in workers:
        eng_w = SweepEngine(inputs, shapes, backend="analytic",
                            executor="process", max_workers=w)
        eng_w.prime(points[0])
        if w == 1:
            eng_w.sweep(points[:2])       # in-process baseline, warmed
        t0 = time.perf_counter()
        res = eng_w.sweep(points)
        dt = time.perf_counter() - t0
        assert all(r.ok for r in res)
        out["worker_scaling"].append(
            {"workers": w, "points_per_sec": round(len(points) / dt, 1)})
    return out


def run(backend: Optional[str] = None, smoke: bool = False
        ) -> List[Tuple[str, float, float]]:
    """benchmarks.run entry point: CSV rows (name, us, derived)."""
    caps = SMOKE_CAPACITIES_MB if smoke else CAPACITIES_MB
    wanted = backend if backend not in (None, "both") else "all"
    if smoke and wanted == "all":
        wanted = "analytic"
    summary = bench(capacities=caps, backend=wanted,
                    exec_max_points=1 if smoke else EXEC_MAX_POINTS)
    rows = []
    for r in summary["records"]:
        rows.append((f"dse/{r['backend']}/points{r['points']}",
                     r["seconds"] * 1e6, r["points_per_sec"]))
    if "speedup_analytic_over_vector" in summary:
        rows.append(("dse/speedup_analytic_over_vector", 0.0,
                     summary["speedup_analytic_over_vector"]))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--record", action="store_true",
                    help=f"rewrite {BENCH_JSON.name}")
    ap.add_argument("--backend", default="all",
                    choices=["analytic", "vector", "python", "all"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--checkpoint", type=str, default=None,
                    metavar="DIR",
                    help="checkpoint completed sweep points to DIR "
                    "(atomic, periodic); an interrupted sweep can be "
                    "finished with --resume")
    ap.add_argument("--checkpoint-every", type=int, default=4,
                    help="save every N completed points")
    ap.add_argument("--resume", action="store_true",
                    help="restore completed points from --checkpoint "
                    "instead of re-evaluating them")
    ap.add_argument("--point-timeout-s", type=float, default=None,
                    help="per-point wall-clock budget; a point past it "
                    "is recorded as timed out and the sweep proceeds")
    ap.add_argument("--point-retries", type=int, default=0,
                    help="bounded re-evaluations of a failed point")
    ap.add_argument("--scale", action="store_true",
                    help=f"also run the production-scale records "
                    f"({SCALE_POINTS}-point axis, cache-hit serving, "
                    f"worker scaling); implied by --record")
    ap.add_argument("--trace", type=str, default=None, metavar="OUT",
                    help="write a Perfetto-loadable Chrome trace "
                         "(*.jsonl for the structured event log) of "
                         "the sweep (one span per point)")
    args = ap.parse_args()
    if args.resume and not args.checkpoint:
        ap.error("--resume requires --checkpoint DIR")
    caps = SMOKE_CAPACITIES_MB if args.smoke else CAPACITIES_MB
    engine_kw = {"point_timeout_s": args.point_timeout_s,
                 "point_retries": args.point_retries}
    sweep_kw = {}
    if args.checkpoint:
        sweep_kw = {"checkpoint_dir": args.checkpoint,
                    "checkpoint_every": args.checkpoint_every,
                    "resume": args.resume}
    from repro.obs.export import cli_trace
    with cli_trace(args.trace):
        summary = bench(capacities=caps, backend=args.backend,
                        engine_kw=engine_kw, sweep_kw=sweep_kw)
        if (args.scale or args.record) and not args.smoke:
            summary["scale"] = scale_bench()
    print(json.dumps(summary, indent=2))
    if args.record:
        BENCH_JSON.write_text(json.dumps(summary, indent=2) + "\n")
        print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
