"""Fig. 10: performance validation + the Sparseloop-style analytical
ablation.

The paper's Fig. 10a shows Sparseloop (an analytical model using
probability distributions) erring by 187% on average while TeAAL's
data-driven traces stay within ~9%.  We reproduce the MECHANISM: for
each design, compare the modeled time on a SKEWED (power-law) matrix
against the 'analytical expectation' -- the same model run on a
degree-uniformized matrix with identical shape/nnz (exactly what a
hypergeometric sparsity model assumes).  The uniformized estimate
diverges on skewed data; on uniform data it agrees (control).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.workloads import synth_matrix, uniform_pair
from repro.accelerators import extensor, gamma, outerspace, sigma
from repro.core.generator import CascadeSimulator


def _uniformize(a: np.ndarray, seed: int = 9) -> np.ndarray:
    """Same shape + nnz, uniform placement (the analytical assumption)."""
    rng = np.random.default_rng(seed)
    nnz = int(np.count_nonzero(a))
    out = np.zeros_like(a)
    idx = rng.choice(a.size, size=nnz, replace=False)
    out.flat[idx] = rng.random(nnz) + 0.1
    return out


def _model_time(mod, params, a, b) -> float:
    sim = CascadeSimulator(mod.spec(), params=params)
    shapes = {"m": a.shape[0], "k": a.shape[1], "n": b.shape[1]}
    return sim.run({"A": a, "B": b}, shapes).report.seconds


def run() -> List[Tuple[str, float, float]]:
    rows = []
    designs = [("ExTensor", extensor, extensor.DEFAULT_PARAMS),
               ("Gamma", gamma, None),
               ("OuterSPACE", outerspace, None),
               ("SIGMA", sigma, None)]

    # -- absolute modeled times on the uniform-random workload the
    #    paper uses for OuterSPACE/SIGMA validation
    a_u, b_u = uniform_pair(m=256, k=256, n=256, da=0.05, db=0.05)
    for name, mod, params in designs:
        t0 = time.time()
        secs = _model_time(mod, params, a_u, b_u)
        us = (time.time() - t0) * 1e6
        rows.append((f"fig10/time/{name}/uniform", us, secs))

    # -- analytical-vs-data-driven ablation on skewed data
    a_p = synth_matrix("wi")                    # power-law rows
    rng = np.random.default_rng(2)
    kdim, n = a_p.shape[1], 256
    b = (rng.random((kdim, n)) < 0.05) * rng.random((kdim, n))
    errs_skew, errs_unif = [], []
    for name, mod, params in designs[:3]:
        t_real = _model_time(mod, params, a_p, b)
        t_analytic = _model_time(mod, params, _uniformize(a_p), b)
        err = abs(t_analytic - t_real) / t_real * 100
        errs_skew.append(err)
        rows.append((f"fig10/analytical_err%/{name}/powerlaw", 0.0,
                     round(err, 1)))
        # control: uniform data, analytical assumption holds
        t_real_u = _model_time(mod, params, a_u, b_u)
        t_analytic_u = _model_time(mod, params, _uniformize(a_u), b_u)
        err_u = abs(t_analytic_u - t_real_u) / t_real_u * 100
        errs_unif.append(err_u)
        rows.append((f"fig10/analytical_err%/{name}/uniform", 0.0,
                     round(err_u, 1)))

    rows.append(("fig10/claim/analytical_worse_on_skew", 0.0,
                 float(np.mean(errs_skew) > np.mean(errs_unif))))
    return rows
