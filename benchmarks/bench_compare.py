"""Regression gate: fresh smoke run vs the committed BENCH baselines.

Turns the ROADMAP's "must not regress ``vector_rate*``" rule from a
convention into an enforced check.  Three legs:

* **backend** -- re-runs ``backend_throughput.bench`` at the
  comparison size (1024, a committed full-run size, so fresh records
  diff directly against ``BENCH_backend.json`` entries) and checks,
  per (workload, backend, size) record: work invariants
  (``elements`` / ``out_nnz`` / ``nnz_a`` / ``nnz_b``) **exactly**,
  and ``elements_per_sec`` one-sided -- a fresh rate below
  ``committed * (1 - tolerance)`` is a regression, a faster rate
  passes.
* **dse** -- re-runs the analytic capacity sweep (it is closed-form
  and fast at full size) and checks ``points`` / ``pareto_points``
  exactly and ``analytic_rate`` one-sided.
* **graph** -- checks the committed ``BENCH_graph.json`` Fig-13
  direction claims structurally (GraphDynS beats Graphicionado, ours
  beats GraphDynS on BFS) without re-running the multi-minute
  workload.

Exit status is nonzero on any regression; every comparison prints a
``key, committed, fresh, verdict`` row.  ``--skip`` drops a leg (CI
keeps all three).  Rates are host-dependent: the committed baselines
must have been recorded on comparable hardware (CI re-records them on
the runner class it compares on).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

ROOT = Path(__file__).resolve().parent.parent
BENCH_BACKEND = ROOT / "BENCH_backend.json"
BENCH_DSE = ROOT / "BENCH_dse.json"
BENCH_GRAPH = ROOT / "BENCH_graph.json"

#: the size whose committed records the fresh run compares against --
#: large enough that rates are stable, small enough for CI
COMPARE_SIZE = 1024

#: work-count keys that must match bit-for-bit (the workload is seeded)
EXACT_KEYS = ("elements", "out_nnz", "nnz_a", "nnz_b")


class Gate:
    """Collects comparison rows and the overall verdict."""

    def __init__(self) -> None:
        self.rows: List[Tuple[str, str, str, str]] = []
        self.failures = 0

    def check(self, key: str, committed, fresh, ok: bool) -> None:
        verdict = "ok" if ok else "REGRESSION"
        if not ok:
            self.failures += 1
        self.rows.append((key, str(committed), str(fresh), verdict))

    def rate(self, key: str, committed: float, fresh: float,
             tolerance: float) -> None:
        """One-sided: fresh below committed*(1-tol) fails."""
        self.check(key, round(committed, 1), round(fresh, 1),
                   fresh >= committed * (1.0 - tolerance))

    def exact(self, key: str, committed, fresh) -> None:
        self.check(key, committed, fresh, committed == fresh)

    def skip(self, key: str, why: str) -> None:
        self.rows.append((key, "-", "-", f"skipped ({why})"))

    def report(self) -> str:
        w = max((len(r[0]) for r in self.rows), default=10) + 2
        lines = [f"{'key':<{w}} {'committed':>14} {'fresh':>14} verdict"]
        for key, c, f, v in self.rows:
            lines.append(f"{key:<{w}} {c:>14} {f:>14} {v}")
        lines.append(f"# {self.failures} regression(s)"
                     if self.failures else "# all comparisons passed")
        return "\n".join(lines)


# ---------------------------------------------------------------------- #
def _load(path: Path) -> Optional[Dict]:
    if not path.exists():
        return None
    return json.loads(path.read_text())


def compare_backend(gate: Gate, tolerance: float,
                    fresh_records: Optional[List[Dict]] = None) -> None:
    committed = _load(BENCH_BACKEND)
    if committed is None:
        gate.skip("backend", f"{BENCH_BACKEND.name} missing")
        return
    base = {(r.get("workload", "rowwise"), r["backend"], r["size"]): r
            for r in committed.get("records", [])}
    wanted = [k for k in base if k[2] == COMPARE_SIZE]
    if not wanted:
        gate.skip("backend", f"no committed records at n={COMPARE_SIZE}")
        return
    if fresh_records is None:
        from benchmarks.backend_throughput import bench
        fresh_records = bench(sizes=[COMPARE_SIZE], backend="both",
                              py_max_size=COMPARE_SIZE,
                              mapped_sizes=[COMPARE_SIZE])
    fresh = {(r.get("workload", "rowwise"), r["backend"], r["size"]): r
             for r in fresh_records}
    for key in sorted(wanted):
        label = f"backend/{key[0]}/{key[1]}/n{key[2]}"
        fr = fresh.get(key)
        if fr is None:
            gate.check(label, "present", "missing", False)
            continue
        for field in EXACT_KEYS:
            gate.exact(f"{label}/{field}", base[key][field], fr[field])
        gate.rate(f"{label}/elements_per_sec",
                  base[key]["elements_per_sec"],
                  fr["elements_per_sec"], tolerance)


def compare_dse(gate: Gate, tolerance: float,
                fresh_summary: Optional[Dict] = None) -> None:
    committed = _load(BENCH_DSE)
    if committed is None:
        gate.skip("dse", f"{BENCH_DSE.name} missing")
        return
    if fresh_summary is None:
        from benchmarks.dse_sweep import bench
        fresh_summary = bench(backend="analytic")
    base_rec = next((r for r in committed.get("records", [])
                     if r["backend"] == "analytic"), None)
    fresh_rec = next((r for r in fresh_summary.get("records", [])
                      if r["backend"] == "analytic"), None)
    if base_rec is None or fresh_rec is None:
        gate.skip("dse", "no analytic record to compare")
        return
    gate.exact("dse/analytic/points", base_rec["points"],
               fresh_rec["points"])
    gate.exact("dse/analytic/pareto_points",
               base_rec["pareto_points"], fresh_rec["pareto_points"])
    gate.exact("dse/analytic/traffic_range_kb",
               base_rec["traffic_range_kb"],
               fresh_rec["traffic_range_kb"])
    gate.rate("dse/analytic_rate", committed.get("analytic_rate", 0.0),
              fresh_summary.get("analytic_rate", 0.0), tolerance)


def compare_graph(gate: Gate) -> None:
    """Structural Fig-13 direction claims on the committed baseline
    (the graph workload is minutes-long; re-running it is the
    bench-smoke job's fig13 leg, not this gate's)."""
    committed = _load(BENCH_GRAPH)
    if committed is None:
        gate.skip("graph", f"{BENCH_GRAPH.name} missing")
        return
    runs = committed.get("runs", {})

    def seconds(key: str) -> float:
        return runs.get(key, {}).get("modeled_seconds", float("nan"))

    gate.check("graph/bfs/graphdyns_beats_graphicionado",
               round(seconds("bfs/graphicionado"), 6),
               round(seconds("bfs/graphdyns"), 6),
               seconds("bfs/graphdyns") < seconds("bfs/graphicionado"))
    gate.check("graph/bfs/ours_beats_graphdyns",
               round(seconds("bfs/graphdyns"), 6),
               round(seconds("bfs/ours"), 6),
               seconds("bfs/ours") < seconds("bfs/graphdyns"))
    claims = committed.get("claims", {})
    for claim in ("graphdyns_beats_graphicionado",
                  "ours_beats_graphdyns_bfs"):
        gate.exact(f"graph/claims/{claim}", True,
                   bool(claims.get(claim)))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed one-sided fractional rate drop "
                         "before a comparison fails (default 0.25)")
    ap.add_argument("--skip", action="append", default=[],
                    choices=["backend", "dse", "graph"],
                    help="drop a comparison leg (repeatable)")
    ap.add_argument("--trace", type=str, default=None, metavar="OUT",
                    help="write a Perfetto-loadable Chrome trace of "
                         "the fresh comparison runs")
    args = ap.parse_args(argv)
    gate = Gate()
    from repro.obs.export import cli_trace
    with cli_trace(args.trace):
        if "backend" not in args.skip:
            compare_backend(gate, args.tolerance)
        if "dse" not in args.skip:
            compare_dse(gate, args.tolerance)
        if "graph" not in args.skip:
            compare_graph(gate)
    print(gate.report())
    return 1 if gate.failures else 0


if __name__ == "__main__":
    sys.exit(main())
