"""Table 2: the cascade zoo -- every published cascade form compiles
through the TeAAL pipeline and evaluates correctly vs the dense oracle
(including the Toeplitz == direct-convolution equivalence)."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.accelerators.zoo import ZOO
from repro.core.einsum import dense_reference
from repro.core.generator import CascadeSimulator


def _inputs(name, rng):
    if name in ("eyeriss-conv", "toeplitz-conv"):
        shapes = {"b": 2, "c": 3, "h": 6, "w": 6, "m": 4, "r": 3, "s": 3,
                  "p": 4, "q": 4}
        return {"I": rng.random((2, 3, 6, 6)) *
                (rng.random((2, 3, 6, 6)) < .5),
                "F": rng.random((3, 4, 3, 3))}, shapes
    if name in ("tensaurus-mttkrp", "factorized-mttkrp"):
        shapes = {"i": 5, "j": 4, "k": 3, "r": 6}
        return {"T": rng.random((5, 4, 3)) *
                (rng.random((5, 4, 3)) < 0.4),
                "A": rng.random((3, 6)), "B": rng.random((4, 6))}, shapes
    if name == "fft-step":
        shapes = {"u": 1, "k0": 4, "n1": 2, "v": 2}
        return {"P": rng.random((1, 4, 2, 2)),
                "X": rng.random((2, 2))}, shapes
    if name in ("rowwise-spmspm", "sparse-add"):
        shapes = {"m": 24, "k": 24, "n": 24}
        return {"A": rng.random((24, 24)) * (rng.random((24, 24)) < 0.2),
                "B": rng.random((24, 24)) *
                (rng.random((24, 24)) < 0.2)}, shapes
    if name in ("elementwise-3way", "sparse-add-3way"):
        shapes = {"m": 24, "n": 24}

        def sp():
            return rng.random((24, 24)) * (rng.random((24, 24)) < 0.3)
        return {"A": sp(), "B": sp(), "C": sp()}, shapes
    if name == "broadcast-outer":
        shapes = {"m": 24, "n": 8}
        return {"A": rng.random(24) * (rng.random(24) < 0.5),
                "B": rng.random(24) * (rng.random(24) < 0.5)}, shapes
    raise KeyError(name)


def run(backend: str = None) -> List[Tuple[str, float, float]]:
    rows = []
    all_ok = True
    for name in sorted(ZOO):
        rng = np.random.default_rng(0)
        spec = ZOO[name]()
        inputs, shapes = _inputs(name, rng)
        t0 = time.time()
        sim = CascadeSimulator(spec, model=False, backend=backend)
        res = sim.run(dict(inputs), shapes)
        us = (time.time() - t0) * 1e6

        dense = {k: np.asarray(v) for k, v in inputs.items()}
        ok = True
        for e in spec.einsum.expressions:
            dense[e.output.tensor] = dense_reference(
                e, dense, {k.upper(): v for k, v in shapes.items()})
            out = e.output.tensor
            got = res.tensors[out].to_dense()
            decl = spec.einsum.declaration[out]
            order = spec.mapping.rank_order.get(out, decl)
            want = np.transpose(dense[out],
                                [decl.index(r) for r in order])
            pad = np.zeros(want.shape)
            pad[tuple(slice(0, s) for s in got.shape)] = got
            ok = ok and bool(np.allclose(pad, want))
        all_ok = all_ok and ok
        rows.append((f"table2/{name}", us, float(ok)))
    rows.append(("table2/claim/all_cascades_validate", 0.0,
                 float(all_ok)))
    return rows
