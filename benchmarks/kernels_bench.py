"""Pallas kernel micro-bench: interpret-mode correctness latency vs the
jnp reference (CPU container; TPU wall-clock is out of scope -- the
roofline table carries the performance story).

``backend`` additionally drives a small SpMSpM loop nest through the
selected execution backend (python | vector), so the offset-keyed
co-iteration primitives (intersect_keys / union_keys) are exercised on
their real call path."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _t(fn, *args, reps=3) -> Tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6, out


def run(backend: str = "vector") -> List[Tuple[str, float, float]]:
    rows = []
    rng = np.random.default_rng(0)

    # flash attention
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    us, got = _t(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v)
    err = float(jnp.max(jnp.abs(got - ref.attention_ref(q, k, v))))
    rows.append(("kernels/flash_attention/interpret", us, err))
    us_ref, _ = _t(ref.attention_ref, q, k, v)
    rows.append(("kernels/flash_attention/jnp_ref", us_ref, 0.0))

    # block-sparse matmul
    a = rng.standard_normal((256, 256)).astype(np.float32)
    mask = rng.random((4, 4)) < 0.4
    a = a * np.kron(mask, np.ones((64, 64), np.float32))
    b = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    tiles, rws, cls = ops.compact_tiles(a, 64, 64)
    us, got = _t(lambda t_, r_, c_, b_: ops.block_sparse_matmul(
        t_, r_, c_, b_, m=256, bn=64), tiles, rws, cls, b)
    err = float(jnp.max(jnp.abs(
        got - ref.block_sparse_matmul_ref(jnp.asarray(a), b))))
    rows.append(("kernels/block_sparse_matmul/interpret", us, err))

    # ssd chunk
    x = jnp.asarray(rng.standard_normal((1, 2, 128, 4, 64)), jnp.float32)
    aa = -jnp.abs(jnp.asarray(rng.standard_normal((1, 4, 2, 128)),
                              jnp.float32)) * 0.1
    bb = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    us, got = _t(ops.ssd_chunk, x, aa, bb, cc)
    err = float(jnp.max(jnp.abs(got - ref.ssd_chunk_ref(x, aa, bb, cc))))
    rows.append(("kernels/ssd_chunk/interpret", us, err))

    # sorted-coordinate intersection (ExTensor skip-ahead -> TPU)
    ac = ops.pad_sorted(np.sort(rng.choice(100000, 2000,
                                           replace=False)).astype(
                            np.int32), 512)
    bc = ops.pad_sorted(np.sort(rng.choice(100000, 4000,
                                           replace=False)).astype(
                            np.int32), 512)
    us, got = _t(lambda a_, b_: ops.intersect_sorted(a_, b_, block=512),
                 jnp.asarray(ac), jnp.asarray(bc))
    err = float(jnp.max(jnp.abs(
        got - ref.intersect_sorted_ref(ac, bc))))
    rows.append(("kernels/intersect_sorted/interpret", us, err))

    # sorted-union / merge-path kernel (interpret) vs numpy merge
    am = ops.pad_sorted(np.sort(rng.choice(50000, 1500,
                                           replace=False)).astype(np.int32),
                        256)
    bm = ops.pad_sorted(np.sort(rng.choice(50000, 2500,
                                           replace=False)).astype(np.int32),
                        256)
    interpret = jax.default_backend() != "tpu"
    us, (merged, _src) = _t(
        lambda a_, b_: ops.merge_sorted(a_, b_, block=256,
                                        interpret=interpret),
        jnp.asarray(am), jnp.asarray(bm))
    want = np.sort(np.concatenate([am, bm]))
    err = float(np.max(np.abs(np.asarray(merged) - want)))
    rows.append(("kernels/merge_sorted/interpret", us, err))

    # execution-backend co-iteration micro-bench (real call path of the
    # intersect/union primitives)
    from repro.core.generator import CascadeSimulator
    from repro.core.trace import CollectingInstr
    from repro.accelerators.zoo import rowwise_spmspm
    n = 256
    a = rng.random((n, n)) * (rng.random((n, n)) < 0.05)
    b = rng.random((n, n)) * (rng.random((n, n)) < 0.05)
    ci = CollectingInstr()
    sim = CascadeSimulator(rowwise_spmspm(), model=False, extra_instr=ci,
                           backend=backend)
    t0 = time.time()
    sim.run({"A": a, "B": b}, {"m": n, "k": n, "n": n})
    dt = time.time() - t0
    muls = int(ci.compute_counts[("Z", "mul")])
    rows.append((f"kernels/spmspm_coiter/{backend}", dt * 1e6,
                 round(muls / max(dt, 1e-9), 1)))
    return rows
