"""Pallas kernel micro-bench: interpret-mode correctness latency vs the
jnp reference (CPU container; TPU wall-clock is out of scope -- the
roofline table carries the performance story).

``backend`` additionally drives a small SpMSpM loop nest through the
selected execution backend (python | vector), so the offset-keyed
co-iteration primitives (intersect_keys / union_keys) are exercised on
their real call path.

``seam_rates`` measures the four dispatch seams of the kernel-backend
registry (intersect / union-k / lookup / segmented-reduce) in keys per
second per backend; ``--record`` merges them into BENCH_backend.json
under ``kernel_rates``."""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.backends import KERNEL_BACKENDS, resolve_kernel_backend

BENCH_JSON = Path(__file__).resolve().parent.parent / "BENCH_backend.json"


def _t(fn, *args, reps=3) -> Tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6, out


def run(backend: str = "vector") -> List[Tuple[str, float, float]]:
    rows = []
    rng = np.random.default_rng(0)

    # flash attention
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    us, got = _t(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v)
    err = float(jnp.max(jnp.abs(got - ref.attention_ref(q, k, v))))
    rows.append(("kernels/flash_attention/interpret", us, err))
    us_ref, _ = _t(ref.attention_ref, q, k, v)
    rows.append(("kernels/flash_attention/jnp_ref", us_ref, 0.0))

    # block-sparse matmul
    a = rng.standard_normal((256, 256)).astype(np.float32)
    mask = rng.random((4, 4)) < 0.4
    a = a * np.kron(mask, np.ones((64, 64), np.float32))
    b = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    tiles, rws, cls = ops.compact_tiles(a, 64, 64)
    us, got = _t(lambda t_, r_, c_, b_: ops.block_sparse_matmul(
        t_, r_, c_, b_, m=256, bn=64), tiles, rws, cls, b)
    err = float(jnp.max(jnp.abs(
        got - ref.block_sparse_matmul_ref(jnp.asarray(a), b))))
    rows.append(("kernels/block_sparse_matmul/interpret", us, err))

    # ssd chunk
    x = jnp.asarray(rng.standard_normal((1, 2, 128, 4, 64)), jnp.float32)
    aa = -jnp.abs(jnp.asarray(rng.standard_normal((1, 4, 2, 128)),
                              jnp.float32)) * 0.1
    bb = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    us, got = _t(ops.ssd_chunk, x, aa, bb, cc)
    err = float(jnp.max(jnp.abs(got - ref.ssd_chunk_ref(x, aa, bb, cc))))
    rows.append(("kernels/ssd_chunk/interpret", us, err))

    # sorted-coordinate intersection (ExTensor skip-ahead -> TPU)
    ac = ops.pad_sorted(np.sort(rng.choice(100000, 2000,
                                           replace=False)).astype(
                            np.int32), 512)
    bc = ops.pad_sorted(np.sort(rng.choice(100000, 4000,
                                           replace=False)).astype(
                            np.int32), 512)
    us, got = _t(lambda a_, b_: ops.intersect_sorted(a_, b_, block=512),
                 jnp.asarray(ac), jnp.asarray(bc))
    err = float(jnp.max(jnp.abs(
        got - ref.intersect_sorted_ref(ac, bc))))
    rows.append(("kernels/intersect_sorted/interpret", us, err))

    # sorted-union / merge-path kernel (interpret) vs numpy merge
    am = ops.pad_sorted(np.sort(rng.choice(50000, 1500,
                                           replace=False)).astype(np.int32),
                        256)
    bm = ops.pad_sorted(np.sort(rng.choice(50000, 2500,
                                           replace=False)).astype(np.int32),
                        256)
    interpret = jax.default_backend() != "tpu"
    us, (merged, _src) = _t(
        lambda a_, b_: ops.merge_sorted(a_, b_, block=256,
                                        interpret=interpret),
        jnp.asarray(am), jnp.asarray(bm))
    want = np.sort(np.concatenate([am, bm]))
    err = float(np.max(np.abs(np.asarray(merged) - want)))
    rows.append(("kernels/merge_sorted/interpret", us, err))

    # execution-backend co-iteration micro-bench (real call path of the
    # intersect/union primitives)
    from repro.core.generator import CascadeSimulator
    from repro.core.trace import CollectingInstr
    from repro.accelerators.zoo import rowwise_spmspm
    n = 256
    a = rng.random((n, n)) * (rng.random((n, n)) < 0.05)
    b = rng.random((n, n)) * (rng.random((n, n)) < 0.05)
    ci = CollectingInstr()
    sim = CascadeSimulator(rowwise_spmspm(), model=False, extra_instr=ci,
                           backend=backend)
    t0 = time.time()
    sim.run({"A": a, "B": b}, {"m": n, "k": n, "n": n})
    dt = time.time() - t0
    muls = int(ci.compute_counts[("Z", "mul")])
    rows.append((f"kernels/spmspm_coiter/{backend}", dt * 1e6,
                 round(muls / max(dt, 1e-9), 1)))
    return rows


# ---------------------------------------------------------------------- #
# dispatch-seam microbenchmarks (kernel-backend registry)
# ---------------------------------------------------------------------- #
def _seam_inputs(n: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    dom = 8 * n
    a = np.sort(rng.choice(dom, size=n, replace=False)).astype(np.int64)
    b = np.sort(rng.choice(dom, size=n, replace=False)).astype(np.int64)
    c = np.sort(rng.choice(dom, size=n // 2, replace=False)).astype(
        np.int64)
    probes = rng.integers(0, dom, size=n).astype(np.int64)
    vals = rng.random(n) + 0.1
    gids = np.sort(rng.integers(0, max(n // 8, 1), size=n)).astype(
        np.int64)
    gids = np.cumsum(np.diff(gids, prepend=gids[0:1]) > 0).astype(np.int64)
    starts = np.flatnonzero(np.diff(gids, prepend=-1) > 0)
    return a, b, c, probes, vals, starts, gids


def seam_rates(kernel_backend: str = "numpy", n: int = 1 << 20,
               reps: int = 3) -> Dict[str, float]:
    """Keys per second through each registry dispatch seam (best of
    ``reps``), on sorted unique key arrays of ``n`` elements."""
    from repro.core.einsum import Semiring

    kb = resolve_kernel_backend(kernel_backend)
    a, b, c, probes, vals, starts, gids = _seam_inputs(n)
    sr = Semiring.arithmetic()
    seams = {
        "intersect": (lambda: kb.intersect_keys(a, b), n),
        "union_k": (lambda: kb.union_k_keys([a, b, c]), n * 5 // 2),
        "lookup": (lambda: kb.lookup_keys(a, probes), n),
        "segmented_reduce": (
            lambda: kb.segmented_reduce(vals, starts, sr, group_ids=gids),
            n),
    }
    out: Dict[str, float] = {}
    for name, (fn, keys) in seams.items():
        fn()                                  # warm (jit compile etc.)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        out[name] = round(keys / best, 1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--record", action="store_true",
                    help=f"merge kernel_rates into {BENCH_JSON.name}")
    ap.add_argument("--kernel-backends", default="numpy,jax-jit",
                    help="comma-separated registry backends to measure")
    ap.add_argument("--n", type=int, default=1 << 20)
    args = ap.parse_args()
    names = [s for s in args.kernel_backends.split(",") if s]
    bad = [s for s in names if s not in KERNEL_BACKENDS]
    if bad:
        ap.error(f"unknown kernel backends {bad}; choose from "
                 f"{KERNEL_BACKENDS}")
    rates = {name: seam_rates(name, n=args.n) for name in names}
    summary = {"metric": "keys per second", "n_keys": args.n,
               "backends": rates}
    print(json.dumps(summary, indent=2))
    if args.record:
        doc = {}
        if BENCH_JSON.exists():
            doc = json.loads(BENCH_JSON.read_text())
        doc["kernel_rates"] = summary
        BENCH_JSON.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
