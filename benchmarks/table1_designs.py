"""Table 1: the five SpMSpM accelerators on one workload -- the
'apples-to-apples comparison' the paper's formalism enables (Sec. 2.4:
'we present a formalism to resolve this imprecision').

Every design runs the same A^T B on the same matrices; the derived
column is modeled seconds.  The claim row checks that all five produce
the identical functional result (same cascade semantics, different
mappings/bindings)."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.workloads import uniform_pair
from repro.accelerators import extensor, gamma, matraptor, outerspace, sigma
from repro.core.generator import CascadeSimulator


def run() -> List[Tuple[str, float, float]]:
    rows = []
    a, b = uniform_pair(m=192, k=192, n=192, da=0.08, db=0.08, seed=3)
    shapes = {"m": 192, "k": 192, "n": 192}
    designs = [("OuterSPACE", outerspace.spec(), None),
               ("ExTensor", extensor.spec(), extensor.DEFAULT_PARAMS),
               ("Gamma", gamma.spec(), None),
               ("SIGMA", sigma.spec(), None),
               ("MatRaptor", matraptor.spec(), None)]
    outputs = []
    for name, spec, params in designs:
        t0 = time.time()
        sim = CascadeSimulator(spec, params=params)
        res = sim.run({"A": a, "B": b}, shapes)
        us = (time.time() - t0) * 1e6
        rows.append((f"table1/{name}/seconds", us, res.report.seconds))
        rows.append((f"table1/{name}/dram_MB", 0.0,
                     round(res.report.dram_bytes / 1e6, 3)))
        outputs.append(res.tensors["Z"].to_dense())
    agree = all(np.allclose(outputs[0], z) for z in outputs[1:])
    rows.append(("table1/claim/all_designs_agree_functionally", 0.0,
                 float(agree)))
    return rows
