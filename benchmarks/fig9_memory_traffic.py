"""Fig. 9: memory traffic of ExTensor / Gamma / OuterSPACE on the five
evaluation matrices, normalized to the algorithmic minimum.

Paper claims validated (at simulator scale, see workloads.py):
  * every design's traffic >= the algorithmic minimum (sanity),
  * Gamma's fused multiply-merge keeps partial-product traffic near
    zero -> lowest normalized traffic of the three,
  * OuterSPACE's materialized linked-list T pays the most traffic.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.workloads import PAPER_MATRICES, synth_matrix
from repro.accelerators import extensor, gamma, outerspace
from repro.core.generator import CascadeSimulator


def algorithmic_minimum_bytes(a: np.ndarray, b: np.ndarray,
                              word: int = 4) -> float:
    """Read A and B once (compressed coord+payload), write Z once."""
    z = (a @ b) != 0
    nnz = int(np.count_nonzero(a)) + int(np.count_nonzero(b)) \
        + int(np.count_nonzero(z))
    return nnz * 2 * word


def run() -> List[Tuple[str, float, float]]:
    rows = []
    designs = [("ExTensor", extensor, extensor.DEFAULT_PARAMS),
               ("Gamma", gamma, None),
               ("OuterSPACE", outerspace, None)]
    per_design = {}
    for mat in PAPER_MATRICES:
        a = synth_matrix(mat)
        k, n = a.shape[1], a.shape[1]
        rng = np.random.default_rng(1)
        b = (rng.random((k, n)) < 0.02) * rng.random((k, n))
        algmin = algorithmic_minimum_bytes(a, b)
        shapes = {"m": a.shape[0], "k": k, "n": n}
        for name, mod, params in designs:
            t0 = time.time()
            sim = CascadeSimulator(mod.spec(), params=params)
            rep = sim.run({"A": a, "B": b}, shapes).report
            us = (time.time() - t0) * 1e6
            norm = rep.dram_bytes / algmin
            rows.append((f"fig9/{name}/{mat}", us, round(norm, 3)))
            per_design.setdefault(name, []).append(norm)

    # claim checks (derived=1.0 iff claim holds)
    means = {k: float(np.mean(v)) for k, v in per_design.items()}
    rows.append(("fig9/claim/traffic>=algmin", 0.0,
                 float(all(x >= 0.99 for v in per_design.values()
                           for x in v))))
    rows.append(("fig9/claim/gamma<=outerspace", 0.0,
                 float(means["Gamma"] <= means["OuterSPACE"])))
    return rows
