"""Fig. 11: ExTensor energy model across the five matrices.

Validates: energy is dominated by DRAM + SRAM traffic (the paper's
breakdown), and total energy is monotone in memory traffic (the
mechanism behind TeAAL's 7.8%-error energy validation)."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.workloads import PAPER_MATRICES, synth_matrix
from repro.accelerators import extensor
from repro.core.generator import CascadeSimulator


def run() -> List[Tuple[str, float, float]]:
    rows = []
    traffics, energies = [], []
    for mat in PAPER_MATRICES:
        a = synth_matrix(mat)
        k, n = a.shape[1], a.shape[1]
        rng = np.random.default_rng(1)
        b = (rng.random((k, n)) < 0.02) * rng.random((k, n))
        t0 = time.time()
        sim = CascadeSimulator(extensor.spec(),
                               params=extensor.DEFAULT_PARAMS)
        rep = sim.run({"A": a, "B": b},
                      {"m": a.shape[0], "k": k, "n": n}).report
        us = (time.time() - t0) * 1e6
        rows.append((f"fig11/energy_uJ/{mat}", us,
                     round(rep.energy_pj / 1e6, 4)))
        mem_share = (rep.energy_breakdown_pj.get("dram", 0)
                     + rep.energy_breakdown_pj.get("sram", 0)) \
            / rep.energy_pj
        rows.append((f"fig11/mem_share/{mat}", 0.0, round(mem_share, 3)))
        traffics.append(rep.dram_bytes)
        energies.append(rep.energy_pj)

    corr = float(np.corrcoef(traffics, energies)[0, 1])
    rows.append(("fig11/claim/energy_tracks_traffic_corr", 0.0,
                 round(corr, 3)))
    return rows
