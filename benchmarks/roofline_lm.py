"""LM-fleet roofline rows for the benchmark CSV (reads the dry-run
artifacts; full table in EXPERIMENTS.md via repro.launch.roofline)."""
from __future__ import annotations

from typing import List, Tuple

from repro.launch.roofline import full_table


def run() -> List[Tuple[str, float, float]]:
    rows = []
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        cells = full_table(mesh)
        n_ok = sum(c.status == "ok" for c in cells)
        n_skip = sum(c.status == "skipped" for c in cells)
        n_err = sum(c.status == "error" for c in cells)
        rows.append((f"roofline/{mesh}/cells_ok", 0.0, float(n_ok)))
        rows.append((f"roofline/{mesh}/cells_skipped", 0.0,
                     float(n_skip)))
        rows.append((f"roofline/{mesh}/cells_error", 0.0, float(n_err)))
        for c in cells:
            if c.status != "ok":
                continue
            rows.append((
                f"roofline/{mesh}/{c.arch}/{c.shape}/"
                f"{c.dominant}-bound", c.step_seconds * 1e6,
                round(c.roofline_fraction, 4)))
    return rows
