"""Quickstart: specify a sparse accelerator in TeAAL, simulate it on a
real sparse matrix, and read the performance report.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.generator import CascadeSimulator, check_against_dense
from repro.core.spec import load_spec

# ---------------------------------------------------------------------- #
# 1. declare the computation (a cascade of Einsums) and its mapping
#    -- this is the paper's Figure-3 language, inline
# ---------------------------------------------------------------------- #
SPEC = load_spec({
    "name": "quickstart-spmspm",
    "einsum": {
        "declaration": {
            "A": ["K", "M"],          # stationary operand, [k, m] indexed
            "B": ["K", "N"],
            "Z": ["M", "N"],
        },
        "expressions": ["Z[m, n] = A[k, m] * B[k, n]"],
    },
    "mapping": {
        "rank-order": {"A": ["M", "K"], "B": ["K", "N"], "Z": ["M", "N"]},
        "partitioning": {"Z": {"M": ["uniform_occupancy(A.8)"]}},
        "loop-order": {"Z": ["M1", "M0", "K", "N"]},
        "spacetime": {"Z": {"space": ["M1"], "time": ["M0", "K", "N"]}},
    },
    "format": {
        "A": {"default": {"M": {"format": "C", "cbits": 32, "pbits": 32},
                          "K": {"format": "C", "cbits": 32, "pbits": 64}}},
        "B": {"default": {"K": {"format": "C", "cbits": 32, "pbits": 32},
                          "N": {"format": "C", "cbits": 32, "pbits": 64}}},
    },
    "architecture": {
        "clock_ghz": 1.0,
        "topologies": {"main": {
            "name": "chip", "num": 1,
            "local": [
                {"name": "DRAM", "class": "DRAM", "bandwidth": 68.0},
                {"name": "Buf", "class": "Buffer", "type": "cache",
                 "width": 64, "depth": 4096},
            ],
            "subtree": [{
                "name": "PE", "num": 8,
                "local": [
                    {"name": "ALU", "class": "Compute", "type": "mul"},
                ],
            }],
        }},
    },
    "binding": {
        "Z": {"topology": "main",
              "storage": [{"component": "Buf", "tensor": "B", "rank": "N",
                           "type": "elem", "style": "lazy"}],
              "compute": [{"component": "ALU", "op": "mul"}]},
    },
})

# ---------------------------------------------------------------------- #
# 2. run it on real data
# ---------------------------------------------------------------------- #
rng = np.random.default_rng(0)
K = M = N = 64
A = rng.random((K, M)) * (rng.random((K, M)) < 0.15)   # [k, m] indexed
B = rng.random((K, N)) * (rng.random((K, N)) < 0.15)

sim = CascadeSimulator(SPEC)
result = sim.run({"A": A, "B": B}, {"m": M, "k": K, "n": N})

print(result.report.summary())
print("\naction counts:", {k: int(v) for k, v in
                           result.report.action_counts.items()})

# ---------------------------------------------------------------------- #
# 3. the functional result is always cross-checked against a dense oracle
# ---------------------------------------------------------------------- #
ok = check_against_dense(SPEC, {"A": A, "B": B},
                         {"m": M, "k": K, "n": N})
print("\nmatches dense einsum oracle:", ok)
assert ok
