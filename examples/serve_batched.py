"""End-to-end serving driver: continuous-batching decode of a small LM
with batched requests (the framework's serve path on local devices).

    PYTHONPATH=src python examples/serve_batched.py [--arch qwen2-7b]
"""
import argparse
import time

import numpy as np

import repro.configs as C
from repro.launch.serve import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCH_IDS, default="olmo-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    server = Server(cfg, batch=args.batch, max_len=128)
    rng = np.random.default_rng(0)

    reqs = []
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=int(rng.integers(4, 16))).tolist()
        r = Request(rid, prompt, args.max_new)
        reqs.append(r)
        server.submit(r)

    t0 = time.time()
    server.drain()
    dt = time.time() - t0

    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"arch={cfg.name}  requests={done}/{len(reqs)}  "
          f"tokens={toks}  wall={dt:.2f}s  {toks / dt:.1f} tok/s")
    print("sample output (req 0):", reqs[0].out[:8])
    assert done == len(reqs)


if __name__ == "__main__":
    main()
