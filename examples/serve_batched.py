"""Sweep-service demo: concurrent what-if queries, micro-batched.

N client threads fire design-space queries at one persistent
:class:`~repro.dse.service.SweepService` -- duplicate and repeat
queries included, the access pattern of an interactive exploration
session.  The service coalesces concurrent duplicates, groups points
that share a mapping signature into one batched analytic evaluation,
and serves repeats from the content-addressed result cache.

    PYTHONPATH=src python examples/serve_batched.py [--clients 8]
"""
import argparse
import random
import threading
import time

import numpy as np

from repro.dse import DesignSpace, ResultCache, SweepEngine, SweepService


def workload(m: int = 96, k: int = 96, n: int = 96,
             da: float = 0.12, db: float = 0.12):
    rng = np.random.default_rng(0)
    a = rng.random((k, m)) * (rng.random((k, m)) < da)
    b = rng.random((k, n)) * (rng.random((k, n)) < db)
    return ({"A": a, "B": b},
            {"M": m, "K": k, "N": n})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--queries", type=int, default=12,
                    help="queries per client")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    inputs, shapes = workload()
    space = DesignSpace("gamma", axes={
        "fibercache_mb": [0.01, 0.05, 0.1, 0.5, 1.0, 2.0, 4.0]})
    points = space.grid()

    cache = ResultCache()
    engine = SweepEngine(inputs, shapes, backend="analytic",
                         result_cache=cache)
    engine.prime(points[0])

    results = {}
    lock = threading.Lock()

    def client(cid: int, svc: SweepService) -> None:
        rng = random.Random(args.seed + cid)
        for _ in range(args.queries):
            res = svc.what_if(rng.choice(points), timeout=60)
            assert res.ok, res.error
            with lock:
                results.setdefault(res.label, set()).add(
                    (res.seconds, res.energy_pj, res.dram_bytes))
            time.sleep(rng.random() * 0.002)

    t0 = time.perf_counter()
    with SweepService(engine, max_batch=32,
                      batch_window_s=0.005) as svc:
        threads = [threading.Thread(target=client, args=(i, svc))
                   for i in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = svc.stats()
    dt = time.perf_counter() - t0

    total = args.clients * args.queries
    # every client observed bit-identical objectives per configuration
    assert all(len(v) == 1 for v in results.values())
    cs = cache.stats()
    print(f"queries      {total} from {args.clients} clients "
          f"in {dt:.2f}s ({total / dt:.0f} qps)")
    print(f"batches      {stats['batches']} "
          f"(mean {total / max(stats['batches'], 1):.1f} requests/batch, "
          f"{stats['coalesced']} coalesced in-flight)")
    print(f"result cache {cs['hits']} hits / {cs['misses']} misses "
          f"({cs['entries']} entries) -- "
          f"{total - cs['misses']} of {total} queries served "
          f"without the analytic backend")
    print(f"distinct configurations evaluated: {len(results)}")


if __name__ == "__main__":
    main()
