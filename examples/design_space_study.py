"""Design-space study (the paper's Sec.-8 workflow): sweep point
changes to an accelerator's TeAAL spec and compare modeled designs --
now driven by the DSE engine (``repro.dse``), which evaluates sweep
points through the analytic backend by default: density calibration is
done once per workload, and every sweep point after that is a
closed-form evaluation (~100-200x the points/sec of execution-based
simulation, see BENCH_dse.json).

Three studies on the same SpMSpM workload:
  1. Gamma's FiberCache capacity (locality vs area) + Pareto frontier,
  2. Gamma's merger radix (swizzle throughput vs comparator area),
  3. the OuterSPACE-vs-Gamma-vs-ExTensor cross-design comparison --
all from declarative specs, no simulator code written.

    PYTHONPATH=src python examples/design_space_study.py [--backend B]
"""
import argparse

import numpy as np

from repro.dse import DesignPoint, DesignSpace, SweepEngine, pareto_front


def workload(seed=0, m=96, k=96, n=96, da=0.12, db=0.12):
    rng = np.random.default_rng(seed)
    a = rng.random((k, m)) * (rng.random((k, m)) < da)
    b = rng.random((k, n)) * (rng.random((k, n)) < db)
    return {"A": a, "B": b}, {"m": m, "k": k, "n": n}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="analytic",
                    choices=["analytic", "vector", "python"],
                    help="evaluation engine (analytic = closed-form)")
    args = ap.parse_args()

    inputs, shapes = workload()
    engine = SweepEngine(inputs, shapes, backend=args.backend)

    print(f"=== sweep 1: Gamma FiberCache capacity "
          f"[backend={args.backend}] ===")
    print("  (below ~0.005 MB the B rows stop fitting: traffic rises)")
    space = DesignSpace("gamma", axes={
        "fibercache_mb": [0.001, 0.002, 0.005, 0.02, 0.25, 3.0]})
    results = engine.sweep(space.grid())
    for r in results:
        print("  " + r.row())
    front = pareto_front([r for r in results if r.ok])
    print("  pareto frontier (time/energy/traffic): "
          + ", ".join(r.label for r in front))

    print("\n=== sweep 2: Gamma merger radix ===")
    print("  (radix trades comparator area against K1 round "
          "parallelism: the radix is also the K-fiber group size, "
          "paper Fig. 8a)")
    radix_space = DesignSpace("gamma", axes={"merge_radix": [2, 8, 64]})
    for r in engine.sweep(radix_space.grid()):
        print("  " + r.row())

    print("\n=== cross-design comparison (same workload) ===")
    designs = [DesignPoint.make("outerspace"),
               DesignPoint.make("gamma"),
               DesignPoint.make("extensor")]
    results = engine.sweep(designs)
    for r in results:
        note = ""
        if r.ok and r.fallback_reasons:
            note = ("  [oracle fallback: "
                    + "; ".join(f"{k}: {v}"
                                for k, v in r.fallback_reasons.items())
                    + "]")
        print("  " + r.row() + note)
    front = pareto_front([r for r in results if r.ok])
    print("  pareto frontier: " + ", ".join(r.label for r in front))
    print(f"\n  {engine.points_evaluated} points evaluated, "
          f"{engine.plan_cache_hits} plan-cache hits")


if __name__ == "__main__":
    main()
