"""Design-space study (the paper's Sec.-8 workflow): sweep point
changes to an accelerator's TeAAL spec and compare modeled designs.

Two sweeps on the same SpMSpM workload:
  1. Gamma's FiberCache capacity (locality vs area),
  2. Gamma's merger radix (swizzle throughput vs comparator area),
then the OuterSPACE-vs-Gamma-vs-ExTensor cross-design comparison --
all from declarative specs, no simulator code written.

    PYTHONPATH=src python examples/design_space_study.py
"""
import numpy as np

from repro.accelerators import extensor, gamma, outerspace
from repro.core.generator import CascadeSimulator


def workload(seed=0, m=96, k=96, n=96, da=0.12, db=0.12):
    rng = np.random.default_rng(seed)
    a = rng.random((k, m)) * (rng.random((k, m)) < da)
    b = rng.random((k, n)) * (rng.random((k, n)) < db)
    return a, b, {"m": m, "k": k, "n": n}


def run(spec, a, b, shapes, params=None):
    sim = CascadeSimulator(spec, params=params)
    return sim.run({"A": a, "B": b}, shapes).report


def main() -> None:
    a, b, shapes = workload()

    print("=== sweep 1: Gamma FiberCache capacity ===")
    print("  (below ~0.005 MB the B rows stop fitting: traffic rises)")
    for mb in (0.001, 0.002, 0.005, 3.0):
        rep = run(gamma.spec(fibercache_mb=mb), a, b, shapes)
        print(f"  fibercache={mb:5.3f} MB  time={rep.seconds:.3e}s "
              f"traffic={rep.dram_bytes / 1e3:8.1f} KB "
              f"energy={rep.energy_pj / 1e6:7.2f} uJ")

    print("\n=== sweep 2: Gamma merger radix ===")
    print("  (radix trades comparator area against K1 round "
          "parallelism: the radix is also the K-fiber group size, "
          "paper Fig. 8a)")
    for radix in (2, 8, 64):
        rep = run(gamma.spec(merge_radix=radix), a, b, shapes)
        print(f"  radix={radix:3d}  time={rep.seconds:.3e}s")

    print("\n=== cross-design comparison (same workload) ===")
    designs = [("OuterSPACE", outerspace.spec(), None),
               ("Gamma", gamma.spec(), None),
               ("ExTensor", extensor.spec(), extensor.DEFAULT_PARAMS)]
    for name, spec, params in designs:
        rep = run(spec, a, b, shapes, params)
        bn = max(rep.blocks, key=lambda blk: blk.seconds)
        print(f"  {name:11s} time={rep.seconds:.3e}s "
              f"traffic={rep.dram_bytes / 1e3:8.1f} KB "
              f"bottleneck={bn.bottleneck}")


if __name__ == "__main__":
    main()
