"""End-to-end training driver with fault tolerance: trains an LM with
the full runtime stack (sharded data -> jit train step -> async
checkpoints -> crash recovery), then kills and resumes it to prove
restart correctness.

Default is a fast smoke config; ``--full-100m`` trains a ~110M-param
model (slow on CPU -- intended for a real device).

    PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse
import dataclasses
import shutil
import tempfile

import repro.configs as C
from repro.configs.base import ModelConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def config_100m() -> ModelConfig:
    """~110M-param dense transformer."""
    return dataclasses.replace(
        C.get("olmo-1b"), name="lm-100m", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32768, head_dim=64,
        scan_layers=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCH_IDS, default="olmo-1b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    cfg = config_100m() if args.full_100m else C.get_smoke(args.arch)
    workdir = tempfile.mkdtemp(prefix="repro_train_")
    try:
        tcfg = TrainerConfig(total_steps=args.steps,
                             checkpoint_every=max(args.steps // 3, 1),
                             checkpoint_dir=workdir, log_every=5,
                             seq_len=128, global_batch=8,
                             async_checkpoint=True)

        # ---- phase 1: train the first 2/3, then "crash"
        t1 = Trainer(cfg, tcfg)
        t1.tcfg.total_steps = 2 * args.steps // 3
        state = t1.run_with_recovery()
        print(f"phase 1 stopped at step {state.step} "
              f"(loss {t1.metrics_log[-1]['loss']:.3f})")

        # ---- phase 2: a fresh process restores and finishes
        t2 = Trainer(cfg, dataclasses.replace(tcfg,
                                              total_steps=args.steps))
        state = t2.run_with_recovery()
        print(f"phase 2 resumed and finished at step {state.step}")
        for rec in t2.metrics_log[-3:]:
            print(" ", rec)
        assert state.step == args.steps
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
