"""Einsum parser + dense oracle tests (paper Sec. 2.2)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or seeded fallback

from repro.core.einsum import (BinOp, Einsum, Literal, Semiring, Take,
                               TensorAccess, dense_reference, parse_einsum)


# ---------------------------------------------------------------------- #
# parser
# ---------------------------------------------------------------------- #
def test_parse_matmul():
    e = parse_einsum("Z[m, n] = A[m, k] * B[k, n]")
    assert e.output.tensor == "Z"
    assert e.out_vars == ("m", "n")
    assert e.reduced_vars == ("k",)
    assert e.input_names == ["A", "B"]


def test_parse_take():
    e = parse_einsum("T[k, m, n] = take(A[k, m], B[k, n], 1)")
    assert isinstance(e.expr, Take)
    assert e.expr.which == 1
    assert [a.tensor for a in e.inputs] == ["A", "B"]


def test_parse_affine_conv():
    e = parse_einsum("O[q] = I[q + s] * F[s]")
    acc = e.inputs[0]
    assert acc.tensor == "I"
    idx = acc.indices[0]
    assert sorted(idx.vars) == ["q", "s"]


def test_parse_bare_copy():
    e = parse_einsum("P1 = P0")
    assert e.output.indices == ()
    assert isinstance(e.expr, TensorAccess)


def test_parse_sub_and_plus():
    e = parse_einsum("Y1[k0] = E[0, k0] - T[k0]")
    assert isinstance(e.expr, BinOp) and e.expr.op == "-"
    const_idx = e.inputs[0].indices[0]
    assert const_idx.terms == () and const_idx.const == 0


def test_parse_error():
    with pytest.raises(SyntaxError):
        parse_einsum("Z[m] = A[m } * B")


# ---------------------------------------------------------------------- #
# dense oracle vs numpy
# ---------------------------------------------------------------------- #
def test_dense_matmul_oracle(rng, spmat):
    a, b = spmat(rng, 6, 5), spmat(rng, 5, 7)
    e = parse_einsum("Z[m, n] = A[m, k] * B[k, n]")
    got = dense_reference(e, {"A": a, "B": b}, {"M": 6, "K": 5, "N": 7})
    assert np.allclose(got, a @ b)


def test_dense_conv_oracle(rng):
    i = rng.random(10)
    f = rng.random(3)
    e = parse_einsum("O[q] = I[q + s] * F[s]")
    got = dense_reference(e, {"I": i, "F": f}, {"Q": 8, "S": 3})
    want = np.array([sum(i[q + s] * f[s] for s in range(3))
                     for q in range(8)])
    assert np.allclose(got, want)


def test_dense_take_oracle(rng, spmat):
    a, b = spmat(rng, 4, 3, 0.5), spmat(rng, 3, 5, 0.5)
    e = parse_einsum("T[k, m, n] = take(A[k, m], B[k, n], 1)")
    got = dense_reference(e, {"A": a.T, "B": b},
                          {"K": 3, "M": 4, "N": 5})
    for k in range(3):
        for m in range(4):
            for n in range(5):
                want = b[k, n] if (a.T[k, m] != 0 and b[k, n] != 0) else 0
                assert got[k, m, n] == want


def test_min_plus_semiring():
    # one SSSP relaxation: dist'[d] = min_s (G[d,s] + dist[s])
    g = np.array([[0, 3.0], [2.0, 0]])
    dist = np.array([1.0, 5.0])
    e = parse_einsum("R[d] = G[d, s] * A[s]")
    got = dense_reference(e, {"G": g, "A": dist}, {"D": 2, "S": 2},
                          Semiring.min_plus())
    assert got[0] == 5.0 + 3.0          # via s=1
    assert got[1] == 1.0 + 2.0          # via s=0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 5),
       k=st.integers(1, 5), n=st.integers(1, 5))
def test_property_matmul_matches_numpy(seed, m, k, n):
    rng = np.random.default_rng(seed)
    a = rng.random((m, k)) * (rng.random((m, k)) < 0.5)
    b = rng.random((k, n)) * (rng.random((k, n)) < 0.5)
    e = parse_einsum("Z[m, n] = A[m, k] * B[k, n]")
    got = dense_reference(e, {"A": a, "B": b},
                          {"M": m, "K": k, "N": n})
    assert np.allclose(got, a @ b)
