"""MatRaptor (Table 1): the row-wise design expressed as a point change
to Gamma's spec -- functional + model sanity."""
import numpy as np

from repro.accelerators import gamma, matraptor
from repro.core.generator import CascadeSimulator, check_against_dense


def test_matraptor_matches_dense(rng, spmat):
    M = K = N = 40
    a, b = spmat(rng, K, M, 0.15), spmat(rng, K, N, 0.15)
    assert check_against_dense(matraptor.spec(), {"A": a, "B": b},
                               {"m": M, "k": K, "n": N})


def test_matraptor_report(rng, spmat):
    M = K = N = 32
    a, b = spmat(rng, K, M, 0.2), spmat(rng, K, N, 0.2)
    sim = CascadeSimulator(matraptor.spec())
    r = sim.run({"A": a, "B": b}, {"m": M, "k": K, "n": N}).report
    assert r.seconds > 0 and r.dram_bytes > 0
    # its queue array does real merge work (row-wise partial sums)
    assert r.action_counts.get("merge_elem", 0) >= 0


def test_matraptor_vs_gamma_same_function(rng, spmat):
    """Two row-wise designs, one cascade: identical functional output
    (they differ only in mapping/format/architecture)."""
    M = K = N = 32
    a, b = spmat(rng, K, M, 0.2), spmat(rng, K, N, 0.2)
    shapes = {"m": M, "k": K, "n": N}
    z1 = CascadeSimulator(matraptor.spec(), model=False).run(
        {"A": a, "B": b}, shapes).tensors["Z"].to_dense()
    z2 = CascadeSimulator(gamma.spec(), model=False).run(
        {"A": a, "B": b}, shapes).tensors["Z"].to_dense()
    assert np.allclose(z1, z2)
