"""Semiring algebra properties and their preservation through the
vectorized reduction pipeline: scalar/vector form agreement, identity
and annihilator laws, idempotence, bit-exact segmented reduction, and
the affine-shifted key kernels."""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.einsum import Semiring
from repro.kernels import ops

SEMIRINGS = {
    "arith": Semiring.arithmetic,
    "min_plus": Semiring.min_plus,
    "or_and": Semiring.or_and,
}


def _vals(rng, n):
    # positive payloads: 0.0 is the universal "empty payload" value
    return np.round(rng.random(n) * 8 + 0.5, 3)


# ---------------------------------------------------------------------- #
# algebraic laws
# ---------------------------------------------------------------------- #
@settings(max_examples=30)
@given(name=st.sampled_from(sorted(SEMIRINGS)), seed=st.integers(0, 10**6))
def test_scalar_vector_forms_agree(name, seed):
    sr = SEMIRINGS[name]()
    assert sr.has_vector_forms
    rng = np.random.default_rng(seed)
    a, b = _vals(rng, 16), _vals(rng, 16)
    for scalar, vec in ((sr.add, sr.add_vec), (sr.mul, sr.mul_vec),
                        (sr.sub, sr.sub_vec)):
        want = np.array([scalar(x, y) for x, y in zip(a, b)])
        assert np.array_equal(np.asarray(vec(a, b), dtype=float), want)


@settings(max_examples=30)
@given(name=st.sampled_from(sorted(SEMIRINGS)),
       x=st.floats(min_value=0.25, max_value=9.0))
def test_add_identity_and_idempotence(name, x):
    sr = SEMIRINGS[name]()
    if name == "or_and":
        x = float(bool(x))           # boolean carrier
    assert sr.add(x, sr.add_identity) == x
    assert sr.add(sr.add_identity, x) == x
    if sr.is_idempotent:
        assert sr.add(x, x) == x


@settings(max_examples=30)
@given(name=st.sampled_from(sorted(SEMIRINGS)),
       x=st.floats(min_value=0.25, max_value=9.0))
def test_annihilator_matches_empty_payload(name, x):
    """`annihilator` is the fibertree's empty-payload encoding: the
    vector leaf compute masks absent operands to it instead of calling
    `mul_vec`, so mul against it must never produce a spurious
    nonzero on the or-and (boolean) carrier, and equals the masked
    result by construction elsewhere."""
    sr = SEMIRINGS[name]()
    assert sr.annihilator == 0.0
    if name != "min_plus":           # min-plus 'zero' is by-convention
        assert sr.mul(x, sr.annihilator) == 0.0
        assert sr.mul(sr.annihilator, x) == 0.0


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
def test_add_ufunc_matches_sequential_fold(name):
    """An `add_ufunc` may only be declared when `ufunc.reduceat` is
    bit-identical to the interpreter's sequential left fold."""
    sr = SEMIRINGS[name]()
    if sr.add_ufunc is None:
        return
    rng = np.random.default_rng(0)
    vals = _vals(rng, 64)
    got = sr.add_ufunc.reduce(vals)
    want = vals[0]
    for v in vals[1:]:
        want = sr.add(want, v)
    assert got == want


# ---------------------------------------------------------------------- #
# segmented reduction (the Reduce kernel)
# ---------------------------------------------------------------------- #
@settings(max_examples=40)
@given(name=st.sampled_from(sorted(SEMIRINGS)),
       seed=st.integers(0, 10**6), n=st.integers(1, 80))
def test_segmented_reduce_bit_exact(name, seed, n):
    """kernels.ops.segmented_reduce == sequential scalar left fold per
    group, bit-for-bit, for every semiring (ufunc fast path and
    step-loop fallback)."""
    sr = SEMIRINGS[name]()
    rng = np.random.default_rng(seed)
    vals = _vals(rng, n)
    if name == "or_and":
        vals = (vals > 4).astype(np.float64)
    nseg = int(rng.integers(1, n + 1))
    starts = np.unique(np.concatenate(
        [[0], rng.integers(0, n, size=nseg - 1)])).astype(np.int64)
    got = ops.segmented_reduce(vals, starts, sr)
    bounds = np.append(starts, n)
    for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        want = vals[lo]
        for v in vals[lo + 1:hi]:
            want = sr.add(want, v)
        assert got[i] == want, (name, i)


def test_segmented_reduce_empty_and_default():
    assert len(ops.segmented_reduce(np.array([]), np.array([],
                                                          dtype=np.int64))) \
        == 0
    vals = np.array([1.0, 2.0, 3.0])
    out = ops.segmented_reduce(vals, np.array([0, 2], dtype=np.int64))
    assert np.array_equal(out, [3.0, 3.0])   # default arith fold


# ---------------------------------------------------------------------- #
# affine-shifted key kernels
# ---------------------------------------------------------------------- #
@settings(max_examples=25)
@given(seed=st.integers(0, 10**6), shift=st.integers(-6, 6))
def test_lookup_keys_shifted(seed, shift):
    rng = np.random.default_rng(seed)
    hay = np.unique(rng.integers(0, 40, size=12)).astype(np.int64)
    probes = rng.integers(0, 40, size=20).astype(np.int64)
    got = ops.lookup_keys_shifted(hay, probes, shift=shift)
    for p, g in zip(probes, got):
        q = p + shift
        if q < 0 or q not in hay:
            assert g == -1
        else:
            assert hay[g] == q


@settings(max_examples=25)
@given(seed=st.integers(0, 10**6), shift=st.integers(-6, 6))
def test_intersect_keys_shifted(seed, shift):
    rng = np.random.default_rng(seed)
    a = np.unique(rng.integers(0, 40, size=12)).astype(np.int64)
    b = np.unique(rng.integers(0, 40, size=12)).astype(np.int64)
    got = ops.intersect_keys_shifted(a, b, shift=shift)
    for x, g in zip(a, got):
        q = x + shift
        if q < 0 or q not in b:
            assert g == -1
        else:
            assert b[g] == q


# ---------------------------------------------------------------------- #
# semiring laws through Reduce: end-to-end tropical / boolean matmul
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["min_plus", "or_and"])
def test_semiring_through_reduce_backend_equivalence(name, rng, spmat):
    """A full SpMSpM under min-plus (tropical) / or-and (reachability):
    the vector path's semiring-parameterized Reduce must match the
    interpreter's sequential scalar fold bit-for-bit."""
    from repro.accelerators.zoo import ZOO
    from repro.core.generator import CascadeSimulator

    sr = SEMIRINGS[name]()
    a, b = spmat(rng, 24, 24, 0.3), spmat(rng, 24, 24, 0.3)
    if name == "or_and":
        a, b = (a != 0).astype(float), (b != 0).astype(float)
    shapes = {"m": 24, "k": 24, "n": 24}
    outs = {}
    for bk in ("python", "vector"):
        sim = CascadeSimulator(ZOO["rowwise-spmspm"](), semiring=sr,
                               model=False, backend=bk)
        res = sim.run({"A": a.copy(), "B": b.copy()}, dict(shapes))
        assert res.fallback_reasons == {}, bk
        outs[bk] = res["Z"].to_dense()
    assert np.array_equal(outs["python"], outs["vector"])
