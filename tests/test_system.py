"""End-to-end system behaviour: spec -> simulator -> report, the
format/binding machinery, and the performance-model invariants the
paper's validation (Sec. 7) relies on."""
import numpy as np
import pytest

from repro.accelerators import extensor, gamma, outerspace
from repro.core.generator import CascadeSimulator
from repro.core.spec import load_spec


def _run(mod, a, b, params=None):
    spec = mod.spec()
    sim = CascadeSimulator(spec, params=params)
    shapes = {"m": a.shape[0], "k": a.shape[1], "n": b.shape[1]}
    return sim.run({"A": a, "B": b}, shapes).report


def test_traffic_scales_with_nnz(rng, spmat):
    """More nonzeros -> more DRAM traffic, monotonically (the
    data-dependence that distinguishes TeAAL from analytical models)."""
    M = K = N = 48
    sparse_a = spmat(rng, M, K, 0.05)
    dense_a = spmat(rng, M, K, 0.4)
    b = spmat(rng, K, N, 0.2)
    t_sparse = _run(gamma, sparse_a, b).dram_bytes
    t_dense = _run(gamma, dense_a, b).dram_bytes
    assert t_dense > t_sparse


def test_empty_input_costs_little(rng, spmat):
    M = K = N = 32
    a0 = np.zeros((M, K))
    a1 = spmat(rng, M, K, 0.3)
    b = spmat(rng, K, N, 0.3)
    r0 = _run(outerspace, a0, b)
    r1 = _run(outerspace, a1, b)
    assert r0.dram_bytes < r1.dram_bytes
    assert r0.action_counts.get("mul", 0) == 0


def test_mul_count_equals_effectual_products(rng, spmat):
    """The model's multiply count must equal the exact number of
    effectual scalar products sum_k nnz(A[k,:]) * nnz(B[k,:]).

    NB the specs declare A: [K, M] (paper Fig. 3) -- the input array is
    indexed [k, m], so the kernel computes Z = A^T B in raw-array terms.
    """
    M = K = N = 24
    a, b = spmat(rng, K, M, 0.2), spmat(rng, K, N, 0.2)
    want = sum(int(np.count_nonzero(a[k]) * np.count_nonzero(b[k]))
               for k in range(K))
    r = _run(outerspace, a, b)
    assert r.action_counts.get("mul", 0) == want


def test_energy_tracks_traffic(rng, spmat):
    M = K = N = 32
    a1 = spmat(rng, M, K, 0.05)
    a2 = spmat(rng, M, K, 0.4)
    b = spmat(rng, K, N, 0.2)
    e1 = _run(extensor, a1, b, extensor.DEFAULT_PARAMS).energy_pj
    e2 = _run(extensor, a2, b, extensor.DEFAULT_PARAMS).energy_pj
    assert e2 > e1


def test_spec_loader_roundtrips_figure3():
    """The OuterSPACE spec (paper Fig. 3) loads with the published
    partitioning/loop-order/spacetime structure."""
    spec = outerspace.spec()
    t_map = spec.mapping.einsum_mapping("T")
    assert t_map.loop_order == ["KM2", "KM1", "KM0", "N"]
    assert t_map.spacetime.space == ["KM1", "KM0"]
    z_map = spec.mapping.einsum_mapping("Z")
    assert z_map.loop_order == ["M2", "M1", "M0", "N", "K"]
    assert spec.mapping.rank_order["T"] == ["M", "K", "N"]


def test_bottleneck_component_identified(rng, spmat):
    a, b = spmat(rng, 32, 32, 0.2), spmat(rng, 32, 32, 0.2)
    r = _run(gamma, a, b)
    for blk in r.blocks:
        assert blk.bottleneck in blk.component_seconds
        assert blk.seconds == max(blk.component_seconds.values())
    assert r.seconds == pytest.approx(sum(b.seconds for b in r.blocks))
