"""End-to-end trainer behaviour: loss goes down, checkpoint/restart
resumes the exact stream, crash recovery restores and continues."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.runtime.trainer import Trainer, TrainerConfig


def _trainer(tmp_path, arch="olmo-1b", steps=8, every=4):
    cfg = C.get_smoke(arch)
    tcfg = TrainerConfig(total_steps=steps, checkpoint_every=every,
                         checkpoint_dir=str(tmp_path), log_every=1,
                         seq_len=32, global_batch=4,
                         async_checkpoint=False)
    return Trainer(cfg, tcfg)


def test_train_runs_and_checkpoints(tmp_path):
    tr = _trainer(tmp_path, steps=6, every=3)
    state = tr.train()
    assert state.step == 6
    assert tr.ckpt.latest_step() == 6
    losses = [m["loss"] for m in tr.metrics_log]
    assert all(np.isfinite(losses))


def test_restart_resumes_from_checkpoint(tmp_path):
    tr1 = _trainer(tmp_path, steps=4, every=2)
    s1 = tr1.train()
    assert s1.step == 4

    # continue to 8 in a fresh Trainer (simulated process restart)
    tr2 = _trainer(tmp_path, steps=8, every=2)
    s2 = tr2.train()
    assert s2.step == 8
    # it resumed, not restarted: first logged step is past 4
    assert tr2.metrics_log[0]["step"] > 4


def test_resume_bitwise_matches_uninterrupted(tmp_path):
    """Checkpoint/restore mid-run reproduces the uninterrupted loss."""
    straight = _trainer(tmp_path / "a", steps=6, every=6)
    s_state = straight.train()
    ref_loss = straight.metrics_log[-1]["loss"]

    part1 = _trainer(tmp_path / "b", steps=3, every=3)
    part1.train()
    part2 = _trainer(tmp_path / "b", steps=6, every=3)
    part2.train()
    got_loss = part2.metrics_log[-1]["loss"]
    assert got_loss == pytest.approx(ref_loss, rel=1e-4)


def test_recovery_restores_after_failure(tmp_path):
    tr = _trainer(tmp_path, steps=6, every=2)
    state = tr.train()

    # poison the params and run with recovery: it must reload the
    # checkpoint rather than propagate NaNs
    calls = {"n": 0}
    orig_restore = tr.restore_or_init

    def sabotage():
        st = orig_restore()
        if calls["n"] == 0:
            calls["n"] += 1
            bad = jax.tree_util.tree_map(
                lambda x: jnp.full_like(x, jnp.nan), st.params)
            st.params = bad
        return st

    tr.tcfg.total_steps = 8
    tr.restore_or_init = sabotage
    final = tr.run_with_recovery(max_restarts=2)
    assert final.step == 8
    assert calls["n"] == 1
