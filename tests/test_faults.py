"""The resilient execution layer, provoked: deterministic fault
injection across every degradation path.

Three layers under test, bottom-up:

  * guarded kernel dispatch (``kernels/backends.py``) -- injected seam
    faults on a backend must downgrade along the chain with every
    action recorded as a ``DowngradeEvent``, transients retried with
    capped backoff, repeat offenders demoted for the process;
  * execution guard-rails (``core/vectorized.py``) -- chain exhaustion
    or a guard violation on one Einsum falls back to the interpreter
    oracle for that Einsum only, bit-exact;
  * sweep fault-tolerance (``dse/engine.py``) -- failing points land
    structured on ``PointResult``, timeouts are bounded, a mid-sweep
    crash leaves a checkpoint whose resumed Pareto front is
    bit-identical to an uninterrupted run.

Everything is deterministic: a failing configuration replays exactly.
"""
import numpy as np
import pytest

from repro.accelerators import extensor, gamma, matraptor, outerspace, sigma
from repro.core.generator import CascadeSimulator
from repro.core.trace import CollectingInstr
from repro.core.vectorized import VectorBackend
from repro.kernels import backends as kbk
from repro.testing.faults import (FaultInjector, FaultSpec, InjectedFault,
                                  SimulatedCrash, clear_injector,
                                  install_injector, parse_faults,
                                  verify_no_silent_downgrades)

COUNTERS = ("touch_counts", "iter_counts", "compute_counts",
            "isect_steps", "isect_matches", "advances")


@pytest.fixture(autouse=True)
def _clean_slate(monkeypatch):
    """Every test starts with no injector, no demotions, no events and
    guards at the default level."""
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    monkeypatch.delenv("REPRO_GUARDS", raising=False)
    clear_injector()
    kbk.reset_guard_state()
    yield
    clear_injector()
    kbk.reset_guard_state()


def _spmm(rng, n=24, d=0.25):
    a = rng.random((n, n)) * (rng.random((n, n)) < d)
    b = rng.random((n, n)) * (rng.random((n, n)) < d)
    return {"A": a, "B": b}, {"m": n, "k": n, "n": n}


# ---------------------------------------------------------------------- #
# fault-spec semantics
# ---------------------------------------------------------------------- #
def test_parse_faults_roundtrip():
    specs = parse_faults(
        "seam=intersect_keys,backend=jax-jit,kind=raise,at=2,times=3;"
        "seam=*,kind=nan,every=5;kind=point-delay,delay_s=0.25,point=gamma")
    assert [s.kind for s in specs] == ["raise", "nan", "point-delay"]
    assert specs[0].seam == "intersect_keys"
    assert specs[0].backend == "jax-jit"
    assert (specs[0].at, specs[0].times) == (2, 3)
    assert specs[1].every == 5
    assert specs[2].delay_s == 0.25 and specs[2].point == "gamma"
    with pytest.raises(ValueError):
        parse_faults("kind=raise,bogus=1")
    with pytest.raises(ValueError):
        parse_faults("kind=no-such-kind")


def test_fault_firing_is_deterministic():
    sp = FaultSpec(kind="raise", at=2, times=2)
    rng = np.random.default_rng(0)
    fired = [sp._should_fire(rng) for _ in range(6)]
    assert fired == [False, True, True, False, False, False]
    sp = FaultSpec(kind="raise", at=1, every=3)
    fired = [sp._should_fire(rng) for _ in range(7)]
    assert fired == [True, False, False, True, False, False, True]


def test_seeded_probabilistic_faults_replay():
    def fire_seq(seed):
        inj = FaultInjector([FaultSpec(kind="raise", p=0.5)], seed=seed)
        out = []
        for _ in range(20):
            try:
                inj.before_seam("intersect_keys", "numpy")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out
    assert fire_seq(7) == fire_seq(7)
    assert fire_seq(7) != fire_seq(8)


def test_env_var_installs_injector(monkeypatch):
    from repro.testing.faults import active_injector
    monkeypatch.setenv("REPRO_FAULTS",
                       "seam=lookup_keys,kind=raise,at=1")
    inj = active_injector()
    assert inj is not None
    assert inj.specs[0].seam == "lookup_keys"
    # explicit install wins over the env var
    mine = install_injector(FaultInjector([]))
    assert active_injector() is mine


# ---------------------------------------------------------------------- #
# guarded dispatch: downgrade / retry / demote mechanics
# ---------------------------------------------------------------------- #
def test_downgrade_records_event_and_result_is_correct():
    install_injector(FaultInjector(
        [FaultSpec(kind="raise", seam="intersect_keys",
                   backend="jax-jit", at=1)]))
    gk = kbk.GuardedKernels("jax-jit", sleep=lambda s: None)
    a = np.array([1, 3, 5, 9], dtype=np.int64)
    b = np.array([3, 4, 9], dtype=np.int64)
    out = gk.intersect_keys(a, b)          # positions of a's keys in b
    assert np.array_equal(out, [-1, 0, -1, 2])
    evs = gk.pop_events()
    assert [e.action for e in evs] == ["downgrade"]
    assert evs[0].seam == "intersect_keys"
    assert evs[0].backend == "jax-jit"
    assert evs[0].fallback == "numpy"
    assert evs[0].exc_type == "InjectedFault"
    assert kbk.events_recorded() == 1
    # the next call (no fault) stays on the primary: no new events
    assert np.array_equal(gk.intersect_keys(a, b), [-1, 0, -1, 2])
    assert gk.pop_events() == []


def test_transient_retry_backoff_sequence():
    """A transient fault is retried on the SAME backend with capped
    exponential backoff, then succeeds -- recorded as retry events,
    not a downgrade."""
    install_injector(FaultInjector(
        [FaultSpec(kind="transient", seam="lookup_keys",
                   backend="jax-jit", at=1, times=2)]))
    naps = []
    gk = kbk.GuardedKernels("jax-jit", max_retries=2, backoff_base=0.05,
                            backoff_cap=1.0, sleep=naps.append)
    hay = np.array([2, 4, 8], dtype=np.int64)
    out = gk.lookup_keys(hay, np.array([4, 8], dtype=np.int64))
    assert np.array_equal(out, [1, 2])
    assert naps == [0.05, 0.1]                      # base * 2^(n-1)
    evs = gk.pop_events()
    assert [e.action for e in evs] == ["retry", "retry"]
    assert [e.attempts for e in evs] == [1, 2]
    assert all(e.backend == "jax-jit" and e.fallback == "" for e in evs)


def test_transient_exhausts_retries_then_downgrades():
    install_injector(FaultInjector(
        [FaultSpec(kind="transient", seam="lookup_keys",
                   backend="jax-jit", at=1, times=99)]))
    gk = kbk.GuardedKernels("jax-jit", max_retries=2,
                            sleep=lambda s: None)
    hay = np.array([2, 4, 8], dtype=np.int64)
    out = gk.lookup_keys(hay, np.array([4], dtype=np.int64))
    assert np.array_equal(out, [1])                 # numpy served it
    actions = [e.action for e in gk.pop_events()]
    assert actions == ["retry", "retry", "downgrade"]


def test_demotion_after_threshold_is_process_wide():
    install_injector(FaultInjector(
        [FaultSpec(kind="raise", seam="intersect_keys",
                   backend="jax-jit", at=1, times=999)]))
    gk = kbk.GuardedKernels("jax-jit", demote_after=3,
                            sleep=lambda s: None)
    a = np.array([1, 2], dtype=np.int64)
    for _ in range(3):
        gk.intersect_keys(a, a)
    evs = gk.pop_events()
    assert [e.action for e in evs] == ["downgrade", "downgrade",
                                       "downgrade", "demote"]
    # demoted: later calls skip jax-jit entirely, even from a FRESH
    # wrapper (demotion is process state, not instance state)
    inj = install_injector(FaultInjector([]))       # no more faults
    gk2 = kbk.GuardedKernels("jax-jit", sleep=lambda s: None)
    assert np.array_equal(gk2.intersect_keys(a, a), [0, 1])
    assert gk2.pop_events() == []                   # went straight past
    assert inj.seam_faults_fired == 0
    # ...but only for that seam: lookup_keys still uses jax-jit
    hay = np.array([2, 4], dtype=np.int64)
    assert np.array_equal(gk2.lookup_keys(hay, hay), [0, 1])


def test_chain_exhaustion_raises_with_history():
    install_injector(FaultInjector(
        [FaultSpec(kind="raise", seam="segmented_reduce",
                   backend="*", at=1, times=999)]))
    gk = kbk.GuardedKernels("numpy", sleep=lambda s: None)
    with pytest.raises(kbk.KernelChainExhausted, match="segmented_reduce"):
        gk.segmented_reduce(np.ones(4), np.array([0, 2]))
    evs = gk.pop_events()
    assert evs and evs[-1].action == "downgrade"
    assert evs[-1].fallback == ""                   # end of the chain


def test_corrupted_output_caught_by_postcondition():
    """A NaN-poisoned reduction (guard-level warn/strict) is caught by
    the seam postcondition and converted into a downgrade -- the final
    result is still numerically correct."""
    install_injector(FaultInjector(
        [FaultSpec(kind="nan", seam="segmented_reduce",
                   backend="jax-jit", at=1)]))
    gk = kbk.GuardedKernels("jax-jit", sleep=lambda s: None)
    vals = np.array([1.0, 2.0, 3.0, 4.0])
    out = gk.segmented_reduce(vals, np.array([0, 2]))
    assert np.array_equal(out, [3.0, 7.0])
    evs = gk.pop_events()
    assert [e.action for e in evs] == ["downgrade"]
    assert evs[0].exc_type == "SeamPostconditionError"


def test_corrupted_union_caught_by_postcondition():
    install_injector(FaultInjector(
        [FaultSpec(kind="corrupt-pos", seam="union_keys",
                   backend="jax-jit", at=1)]))
    gk = kbk.GuardedKernels("jax-jit", sleep=lambda s: None)
    a = np.array([1, 3], dtype=np.int64)
    b = np.array([2, 3], dtype=np.int64)
    u, pa, pb = gk.union_keys(a, b)
    assert np.array_equal(u, [1, 2, 3])
    evs = gk.pop_events()
    assert evs and evs[0].exc_type == "SeamPostconditionError"


def test_guards_off_lets_corruption_through(monkeypatch):
    """REPRO_GUARDS=off disables postconditions (the documented escape
    hatch): the corrupted output flows through un-checked."""
    monkeypatch.setenv("REPRO_GUARDS", "off")
    install_injector(FaultInjector(
        [FaultSpec(kind="nan", seam="segmented_reduce",
                   backend="numpy", at=1)]))
    gk = kbk.GuardedKernels("numpy", sleep=lambda s: None)
    out = gk.segmented_reduce(np.ones(4), np.array([0, 2]))
    assert np.isnan(out[0])
    assert gk.pop_events() == []


def test_silent_downgrade_accounting():
    """verify_no_silent_downgrades: every injected seam fault must be
    covered by a recorded event."""
    inj = install_injector(FaultInjector(
        [FaultSpec(kind="raise", seam="intersect_keys",
                   backend="jax-jit", at=1)]))
    gk = kbk.GuardedKernels("jax-jit", sleep=lambda s: None)
    a = np.array([1, 2], dtype=np.int64)
    gk.intersect_keys(a, a)
    verify_no_silent_downgrades()                   # 1 fired, 1 recorded
    # simulate a silent swallow: another fault fired with no event
    inj.seam_faults_fired += 1
    with pytest.raises(AssertionError, match="silent downgrade"):
        verify_no_silent_downgrades()


# ---------------------------------------------------------------------- #
# end-to-end: zoo accelerators + graph designs stay bit-exact under
# injected failure of a backend at any seam
# ---------------------------------------------------------------------- #
ACCELS = [
    ("outerspace", outerspace, None),
    ("extensor", extensor, extensor.DEFAULT_PARAMS),
    ("gamma", gamma, None),
    ("sigma", sigma, None),
    ("matraptor", matraptor, None),
]


def _assert_equivalent_under_faults(spec, inputs, shapes, params=None):
    """python-oracle vs faulted vector backend: bit-identical tensors
    and matching aggregate instrumentation counts."""
    outs, cis, res_v = {}, {}, None
    for bk in ("python", "vector"):
        ci = CollectingInstr()
        backend = bk if bk == "python" else VectorBackend(
            kernel_backend=kbk.GuardedKernels("jax-jit",
                                              sleep=lambda s: None))
        sim = CascadeSimulator(spec, params=params, model=False,
                               extra_instr=ci, backend=backend)
        res = sim.run(dict(inputs), shapes)
        outs[bk] = {n: res[n].to_dense() for n in res.tensors}
        cis[bk] = ci
        if bk == "vector":
            res_v = res
    for n in outs["python"]:
        assert np.array_equal(outs["python"][n], outs["vector"][n]), \
            f"{spec.name}:{n} differs under injected faults"
    for attr in COUNTERS:
        assert getattr(cis["python"], attr) == getattr(cis["vector"],
                                                       attr), attr
    return res_v


@pytest.mark.parametrize("name,mod,params", ACCELS,
                         ids=[a[0] for a in ACCELS])
def test_accelerators_bit_exact_with_failing_backend(name, mod, params,
                                                     rng, spmat):
    """Every seam call on the primary backend fails permanently; the
    whole cascade must complete bit-exact vs the oracle, with the
    downgrades surfaced on the SimResult (never silent)."""
    install_injector(FaultInjector(
        [FaultSpec(kind="raise", seam="*", backend="jax-jit",
                   at=1, times=10**6)]))
    M = K = N = 24
    inputs = {"A": spmat(rng, M, K, 0.2), "B": spmat(rng, K, N, 0.2)}
    res = _assert_equivalent_under_faults(
        mod.spec(), inputs, {"m": M, "k": K, "n": N}, params)
    assert res.downgrade_events, f"{name}: downgrades not surfaced"
    verify_no_silent_downgrades()


@pytest.mark.parametrize("seam", kbk.GUARDED_SEAMS)
def test_single_seam_failure_bit_exact(seam, rng, spmat):
    """Failing exactly one seam (all others healthy) downgrades only
    that seam and stays bit-exact.  MatRaptor's row-wise dataflow plus
    sparse-add exercises every one of the five seams."""
    install_injector(FaultInjector(
        [FaultSpec(kind="raise", seam=seam, backend="jax-jit",
                   at=1, times=10**6)]))
    from repro.accelerators.zoo import ZOO
    inputs = {"A": spmat(rng, 20, 20, 0.3), "B": spmat(rng, 20, 20, 0.3)}
    for zname in ("rowwise-spmspm", "sparse-add", "elementwise-3way"):
        z_inputs = dict(inputs)
        shapes = {"m": 20, "k": 20, "n": 20}
        if zname in ("elementwise-3way",):
            z_inputs["C"] = spmat(rng, 20, 20, 0.3)
            shapes = {"m": 20, "n": 20}
        elif zname == "sparse-add":
            shapes = {"m": 20, "n": 20}
        _assert_equivalent_under_faults(ZOO[zname](), z_inputs, shapes)
    verify_no_silent_downgrades()


@pytest.mark.parametrize("design", ["graphicionado", "graphdyns", "ours"])
def test_graph_designs_bit_exact_with_failing_backend(design):
    """The three vertex-centric graph designs (min-plus, iterative,
    update-in-place) complete BFS bit-exact vs the oracle while the
    primary kernel backend fails at every seam."""
    from benchmarks.workloads import grid_graph
    from repro.accelerators import graphicionado as G
    from repro.core.einsum import Semiring

    adj = grid_graph(5, extra=4)
    v = adj.shape[0]
    spec = {
        "graphicionado": lambda: G.graphicionado_spec(weighted=False),
        "graphdyns": lambda: G.graphdyns_spec(weighted=False,
                                              n_vertices=v),
        "ours": lambda: G.improved_spec(weighted=False),
    }[design]()
    a0 = np.zeros(v)
    a0[0] = 1.0
    p0 = np.zeros(v)
    p0[0] = 1.0
    outs = {}
    for bk in ("python", "vector"):
        clear_injector()
        if bk == "vector":
            install_injector(FaultInjector(
                [FaultSpec(kind="raise", seam="*", backend="jax-jit",
                           at=1, times=10**6)]))
        backend = bk if bk == "python" else VectorBackend(
            kernel_backend=kbk.GuardedKernels("jax-jit",
                                              sleep=lambda s: None))
        sim = CascadeSimulator(spec, semiring=Semiring.min_plus(),
                               model=False, backend=backend)
        res, _ = sim.run_iterative(
            {"G": adj.copy(), "A0": a0.copy(), "P0": p0.copy()},
            carry={"A0": "A1", "P0": "P1"}, done_when_empty="A1",
            max_iters=60, var_shapes={"d": v, "s": v})
        outs[bk] = {n: res[n].to_dense() for n in res.tensors}
    for n in outs["python"]:
        assert np.array_equal(outs["python"][n], outs["vector"][n]), n
    verify_no_silent_downgrades()


def test_chain_exhaustion_isolated_per_einsum(rng, spmat):
    """When the WHOLE chain fails (terminal numpy included) the
    affected Einsum falls back to the interpreter oracle -- outputs
    still bit-exact, reason surfaced, nothing silent."""
    install_injector(FaultInjector(
        [FaultSpec(kind="raise", seam="intersect_keys", backend="*",
                   at=1, times=10**6)]))
    from repro.accelerators.zoo import ZOO
    inputs, shapes = _spmm(rng)
    vb = VectorBackend(kernel_backend=kbk.GuardedKernels(
        "numpy", sleep=lambda s: None))
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), model=False,
                           backend=vb)
    res = sim.run(dict(inputs), shapes)
    # oracle result for comparison
    sim_p = CascadeSimulator(ZOO["rowwise-spmspm"](), model=False,
                             backend="python")
    res_p = sim_p.run(dict(inputs), shapes)
    for n in res_p.tensors:
        assert np.array_equal(res_p[n].to_dense(), res[n].to_dense()), n
    assert res.fallback_reasons, "isolation must surface a reason"
    reason = next(iter(res.fallback_reasons.values()))
    assert "KernelChainExhausted" in reason
    verify_no_silent_downgrades()


def test_downgrade_events_surfaced_on_report(rng, spmat):
    install_injector(FaultInjector(
        [FaultSpec(kind="raise", seam="intersect_keys",
                   backend="jax-jit", at=1)]))
    from repro.accelerators.zoo import ZOO
    inputs, shapes = _spmm(rng)
    vb = VectorBackend(kernel_backend=kbk.GuardedKernels(
        "jax-jit", sleep=lambda s: None))
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), backend=vb)
    res = sim.run(dict(inputs), shapes)
    assert res.downgrade_events
    evs = next(iter(res.downgrade_events.values()))
    assert evs[0].seam == "intersect_keys"
    assert evs[0].action == "downgrade"
    assert res.report.downgrade_events == res.downgrade_events


# ---------------------------------------------------------------------- #
# sweep fault-tolerance
# ---------------------------------------------------------------------- #
def _sweep_fixture(rng, **engine_kw):
    from repro.dse import DesignSpace, SweepEngine
    inputs, shapes = _spmm(rng, n=24, d=0.2)
    space = DesignSpace("gamma", axes={
        "fibercache_mb": [0.25, 0.5, 1.0, 2.0, 3.0, 4.0]})
    eng = SweepEngine(inputs, shapes, **engine_kw)
    return eng, space.grid()


def test_point_failures_are_structured_and_partial_front_works(rng):
    from repro.dse import pareto_front
    eng, pts = _sweep_fixture(rng)
    install_injector(FaultInjector(
        [FaultSpec(kind="point-error", point=pts[1].label, at=1,
                   times=99),
         FaultSpec(kind="point-error", point=pts[4].label, at=1,
                   times=99)]))
    results = eng.sweep(pts)
    assert len(results) == len(pts)
    bad = [r for r in results if not r.ok]
    assert {r.label for r in bad} == {pts[1].label, pts[4].label}
    for r in bad:
        assert r.error_type == "InjectedFault"
        assert "injected point failure" in r.error
        assert r.traceback and "InjectedFault" in r.traceback
        assert r.status == "failed"
    cov = eng.last_coverage
    assert cov["total"] == 6 and cov["ok"] == 4 and cov["failed"] == 2
    front = pareto_front([r for r in results if r.ok])
    assert front and all(r.ok for r in front)
    assert "4/6 ok" in eng.summarize(results)


def test_point_retry_recovers_transient_failure(rng):
    eng, pts = _sweep_fixture(rng, point_retries=2)
    install_injector(FaultInjector(
        [FaultSpec(kind="point-error", point=pts[0].label, at=1,
                   times=1)]))
    res = eng.evaluate(pts[0])
    assert res.ok and res.attempts == 2


def test_point_timeout_is_bounded(rng):
    eng, pts = _sweep_fixture(rng, point_timeout_s=0.25)
    install_injector(FaultInjector(
        [FaultSpec(kind="point-delay", delay_s=30.0,
                   point=pts[0].label, at=1)]))
    res = eng.evaluate(pts[0])
    assert res.timed_out and res.error_type == "TimeoutError"
    assert res.status == "timeout"
    assert res.wall_seconds <= 1.0


def test_crash_checkpoint_resume_identical_pareto(rng, tmp_path):
    """A sweep killed mid-flight by SimulatedCrash leaves an atomic
    checkpoint; resuming completes the remaining points and the Pareto
    front is bit-identical to an uninterrupted run."""
    from repro.dse import pareto_front

    # ground truth: uninterrupted sweep
    eng0, pts = _sweep_fixture(np.random.default_rng(0))
    truth = eng0.sweep(pts)
    truth_front = [(r.label, r.seconds, r.energy_pj, r.dram_bytes)
                   for r in pareto_front(truth)]

    # crashing sweep: dies at the 4th point, checkpointing every
    # completion
    eng1, pts = _sweep_fixture(np.random.default_rng(0))
    install_injector(FaultInjector(
        [FaultSpec(kind="crash", point=pts[3].label, at=1)]))
    ckpt = tmp_path / "sweep"
    with pytest.raises(SimulatedCrash):
        eng1.sweep(pts, checkpoint_dir=str(ckpt), checkpoint_every=1)
    assert (ckpt / "LATEST").exists()

    # resumed sweep: restores the checkpointed points, evaluates the
    # rest
    clear_injector()
    eng2, pts = _sweep_fixture(np.random.default_rng(0))
    results = eng2.sweep(pts, checkpoint_dir=str(ckpt), resume=True)
    assert len(results) == len(pts)
    restored = [r for r in results if r.restored]
    assert restored and len(restored) < len(pts)
    assert eng2.last_coverage["skipped"] == len(restored)
    got_front = [(r.label, r.seconds, r.energy_pj, r.dram_bytes)
                 for r in pareto_front(results)]
    assert got_front == truth_front                 # bit-identical


def test_resume_after_completion_restores_everything(rng, tmp_path):
    eng, pts = _sweep_fixture(rng)
    ckpt = tmp_path / "sweep"
    r1 = eng.sweep(pts, checkpoint_dir=str(ckpt))
    eng2, pts = _sweep_fixture(np.random.default_rng(0))
    r2 = eng2.sweep(pts, checkpoint_dir=str(ckpt), resume=True)
    assert all(r.restored for r in r2)
    assert eng2.points_evaluated == 0
    for a, b in zip(r1, r2):
        assert (a.label, a.seconds, a.energy_pj, a.dram_bytes) == \
            (b.label, b.seconds, b.energy_pj, b.dram_bytes)


def test_checkpoint_preserves_structured_errors(rng, tmp_path):
    eng, pts = _sweep_fixture(rng)
    install_injector(FaultInjector(
        [FaultSpec(kind="point-error", point=pts[2].label, at=1,
                   times=99)]))
    ckpt = tmp_path / "sweep"
    eng.sweep(pts, checkpoint_dir=str(ckpt))
    clear_injector()
    eng2, pts = _sweep_fixture(np.random.default_rng(0))
    results = eng2.sweep(pts, checkpoint_dir=str(ckpt), resume=True)
    bad = [r for r in results if not r.ok]
    assert len(bad) == 1 and bad[0].restored
    assert bad[0].error_type == "InjectedFault"
    assert "injected point failure" in bad[0].error


def test_parallel_sweep_with_faults_matches_serial(rng):
    install_injector(FaultInjector(
        [FaultSpec(kind="point-error", point="fibercache_mb=1.0",
                   at=1, times=99)]))
    eng_s, pts = _sweep_fixture(np.random.default_rng(0))
    serial = eng_s.sweep(pts)
    install_injector(FaultInjector(
        [FaultSpec(kind="point-error", point="fibercache_mb=1.0",
                   at=1, times=99)]))
    eng_p, pts = _sweep_fixture(np.random.default_rng(0),
                                max_workers=4)
    par = eng_p.sweep(pts)
    for a, b in zip(serial, par):
        assert a.label == b.label and a.ok == b.ok
        if a.ok:
            assert (a.seconds, a.energy_pj, a.dram_bytes) == \
                (b.seconds, b.energy_pj, b.dram_bytes)
