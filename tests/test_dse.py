"""Design-space exploration engine: space generation, evaluation,
caching, parallel sweeps, and Pareto extraction."""
import numpy as np
import pytest

from repro.dse import (DesignPoint, DesignSpace, PointResult, SweepEngine,
                       dominates, pareto_front)


def _workload(rng, n=48, d=0.15):
    a = rng.random((n, n)) * (rng.random((n, n)) < d)
    b = rng.random((n, n)) * (rng.random((n, n)) < d)
    return {"A": a, "B": b}, {"m": n, "k": n, "n": n}


# ---------------------------------------------------------------------- #
# space generation
# ---------------------------------------------------------------------- #
def test_grid_is_cartesian_product():
    space = DesignSpace("gamma", axes={"fibercache_mb": [0.5, 3.0],
                                       "merge_radix": [8, 64]})
    pts = space.grid()
    assert len(pts) == len(space) == 4
    combos = {(p.spec_kwargs["fibercache_mb"], p.spec_kwargs["merge_radix"])
              for p in pts}
    assert combos == {(0.5, 8), (0.5, 64), (3.0, 8), (3.0, 64)}
    # hashable + labeled
    assert len({hash(p) for p in pts}) == 4
    assert all(p.label.startswith("gamma(") for p in pts)


def test_random_subsample_deterministic():
    space = DesignSpace("gamma", axes={
        "fibercache_mb": [0.1 * i for i in range(1, 11)],
        "merge_radix": [2, 4, 8, 16, 32, 64]})
    r1 = space.random(5, seed=7)
    r2 = space.random(5, seed=7)
    assert r1 == r2
    assert len(set(r1)) == 5


def test_param_axes_and_overrides():
    space = DesignSpace("extensor", param_axes={"K0": [64, 128]},
                        base_params={"K1": 1024, "M1": 1024, "M0": 128,
                                     "N1": 1024, "N0": 128})
    pts = space.grid()
    assert len(pts) == 2
    assert {p.param_dict["K0"] for p in pts} == {64, 128}
    assert all(p.param_dict["K1"] == 1024 for p in pts)
    ov = space.overrides([{"params": {"K0": 32}}])
    assert ov[0].param_dict["K0"] == 32


def test_point_builds_spec():
    pt = DesignPoint.make("gamma", {"fibercache_mb": 1.5})
    spec = pt.build_spec()
    comp, _ = spec.arch.find("main", "FiberCache")
    assert comp.attrs["depth"] == int(1.5 * 1024 * 1024 / 64)


# ---------------------------------------------------------------------- #
# pareto
# ---------------------------------------------------------------------- #
class _R:
    def __init__(self, s, e, d):
        self.seconds, self.energy_pj, self.dram_bytes = s, e, d


def test_dominates():
    assert dominates((1, 1), (2, 2))
    assert dominates((1, 2), (1, 3))
    assert not dominates((1, 3), (2, 1))
    assert not dominates((1, 1), (1, 1))


def test_pareto_front_filters_dominated():
    rs = [_R(1, 5, 5), _R(2, 2, 2), _R(3, 3, 3), _R(1, 5, 5)]
    front = pareto_front(rs)
    assert front == [rs[0], rs[1]]        # rs[2] dominated, rs[3] dup


def test_pareto_single_objective():
    rs = [_R(3, 0, 0), _R(1, 0, 0), _R(2, 0, 0)]
    front = pareto_front(rs, objectives=("seconds",))
    assert front == [rs[1]]


# ---------------------------------------------------------------------- #
# the engine
# ---------------------------------------------------------------------- #
def test_engine_analytic_sweep_and_caches(rng):
    inputs, shapes = _workload(rng)
    eng = SweepEngine(inputs, shapes, backend="analytic")
    space = DesignSpace("gamma", axes={
        "fibercache_mb": [0.002, 0.02, 3.0]})
    results = eng.sweep(space.grid())
    assert all(r.ok for r in results), [r.error for r in results]
    assert all(r.fallback_reasons == {} for r in results)
    # arch-only sweep: plans lowered once, reused for the rest
    assert eng.plan_cache_hits == len(results) - 1
    # objectives populated and capacity trend preserved
    assert results[0].dram_bytes >= results[-1].dram_bytes
    assert all(r.seconds > 0 and r.energy_pj > 0 for r in results)


def test_engine_calibration_cache_speeds_up_later_points(rng):
    inputs, shapes = _workload(rng)
    eng = SweepEngine(inputs, shapes, backend="analytic")
    pts = DesignSpace("gamma", axes={
        "fibercache_mb": [0.01 * i for i in range(1, 9)]}).grid()
    results = eng.sweep(pts)
    assert all(r.ok for r in results)
    # the first point pays transform + calibration; the tail must be
    # clearly cheaper (closed-form only)
    tail = [r.wall_seconds for r in results[2:]]
    assert min(tail) < results[0].wall_seconds


def test_engine_parallel_matches_serial(rng):
    inputs, shapes = _workload(rng)
    pts = DesignSpace("gamma", axes={
        "fibercache_mb": [0.002, 0.02, 0.2, 3.0]}).grid()
    serial = SweepEngine(inputs, shapes).sweep(pts)
    threaded = SweepEngine(inputs, shapes, max_workers=4).sweep(pts)
    for s, t in zip(serial, threaded):
        assert s.point == t.point
        assert s.seconds == pytest.approx(t.seconds)
        assert s.dram_bytes == pytest.approx(t.dram_bytes)


def test_engine_drives_execution_backends(rng):
    inputs, shapes = _workload(rng, n=24)
    pts = [DesignPoint.make("gamma")]
    for backend in ("python", "vector"):
        res = SweepEngine(inputs, shapes, backend=backend).sweep(pts)
        assert res[0].ok, res[0].error
        assert res[0].seconds > 0


def test_engine_vector_vs_analytic_trend_agreement(rng):
    """Analytic and execution-based evaluation must agree on the
    cross-capacity ordering of DRAM traffic (what a DSE ranks on)."""
    inputs, shapes = _workload(rng, n=32)
    pts = DesignSpace("gamma", axes={
        "fibercache_mb": [0.001, 3.0]}).grid()
    ana = SweepEngine(inputs, shapes, backend="analytic").sweep(pts)
    exe = SweepEngine(inputs, shapes, backend="python").sweep(pts)
    assert all(r.ok for r in ana + exe)
    assert (ana[0].dram_bytes > ana[1].dram_bytes) == \
        (exe[0].dram_bytes > exe[1].dram_bytes)


def test_engine_records_errors_instead_of_raising(rng):
    inputs, shapes = _workload(rng, n=16)
    eng = SweepEngine(inputs, shapes)
    res = eng.evaluate(DesignPoint.make("no-such-design"))
    assert not res.ok and "no-such-design" in res.error


def test_engine_failed_points_excluded_from_pareto(rng):
    inputs, shapes = _workload(rng, n=16)
    eng = SweepEngine(inputs, shapes)
    results = [eng.evaluate(DesignPoint.make("gamma")),
               eng.evaluate(DesignPoint.make("no-such-design"))]
    front = pareto_front([r for r in results if r.ok])
    assert len(front) == 1 and front[0].ok
