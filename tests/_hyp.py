"""Property-testing shim: real ``hypothesis`` when installed, otherwise
a seeded random-sampling fallback with the same decorator surface, so
the property tests still run (as deterministic seeded loops) without
the optional dependency.

Usage in test modules::

    from _hyp import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import numpy as np

    FALLBACK_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(
                lambda rng: opts[int(rng.integers(0, len(opts)))])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def settings(max_examples=FALLBACK_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # zero-arg wrapper: strategy params must not read as pytest
            # fixtures (hypothesis hides them the same way)
            def wrapper():
                n = getattr(wrapper, "_max_examples", FALLBACK_EXAMPLES)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
