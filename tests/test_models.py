"""Per-architecture smoke tests (REDUCED same-family configs, one
forward/train step + one decode step on CPU, shapes + no NaNs) plus
family-specific consistency checks."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import api, encdec, layers as L, moe as MOE, ssm as SSM


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_smoke_train_and_decode(arch):
    cfg = C.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key)
    batch = api.make_batch(cfg, key, 2, 16)

    loss = api.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))

    cache = api.init_cache(cfg, 2, 32)
    if cfg.family == "encdec":
        cache = encdec.prime_cache(cfg, params, cache, batch["frames"])
    logits, cache2 = api.serve_step(
        cfg, params, cache, jnp.zeros((2,), jnp.int32),
        jnp.zeros((2,), jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(cache2))


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_full_config_matches_brief(arch):
    """The full (non-smoke) configs carry the exact assigned dims."""
    cfg = C.get(arch)
    expected = {
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_moe_expert_counts():
    grok = C.get("grok-1-314b")
    assert (grok.moe.n_experts, grok.moe.top_k) == (8, 2)
    q = C.get("qwen2-moe-a2.7b")
    assert (q.moe.n_experts, q.moe.top_k, q.moe.n_shared) == (60, 4, 4)
    j = C.get("jamba-1.5-large-398b")
    assert (j.moe.n_experts, j.moe.top_k) == (16, 2)
    assert j.hybrid_block == 8
    m = C.get("mamba2-1.3b")
    assert m.ssm.d_state == 128


# ---------------------------------------------------------------------- #
# SSD consistency: chunked prefill == token-by-token recurrence
# ---------------------------------------------------------------------- #
def test_ssd_prefill_matches_decode():
    cfg = C.get_smoke("mamba2-1.3b")
    key = jax.random.PRNGKey(0)
    pr = SSM.init_mamba_layer(cfg, key)
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32) * 0.1

    full = SSM.mamba_layer(cfg, pr, x)             # chunked SSD

    ss, cs = SSM.init_layer_cache(cfg, B)
    outs = []
    for t in range(S):
        y, ss, cs = SSM.mamba_decode(cfg, pr, x[:, t:t + 1], ss, cs)
        outs.append(y)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq),
                               rtol=2e-3, atol=2e-3)


def test_transformer_prefill_matches_decode():
    """Dense GQA: forward logits at position t == decode-step logits."""
    cfg = dataclasses.replace(C.get_smoke("qwen2-7b"), attn_chunk=None)
    from repro.models import transformer as T
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    full_logits = T.forward(cfg, params, toks)     # [B, S, V]

    cache = api.init_cache(cfg, B, S + 1, dtype=jnp.float32)
    for t in range(S):
        logits, cache = api.serve_step(cfg, params, cache, toks[:, t],
                                       jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full_logits[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------- #
# MoE dispatch invariants
# ---------------------------------------------------------------------- #
def test_moe_route_capacity_and_positions():
    rng = np.random.default_rng(0)
    t, e, k, cap = 64, 4, 2, 16
    logits = jnp.asarray(rng.standard_normal((t, e)), jnp.float32)
    eid, slot, keep, gate = MOE.route(logits, k, cap)
    eid, slot, keep = (np.asarray(eid), np.asarray(slot),
                       np.asarray(keep))
    # every kept (expert, slot) pair unique; slots < capacity
    pairs = set()
    for i in range(t * k):
        if keep[i]:
            assert slot[i] < cap
            assert (eid[i], slot[i]) not in pairs
            pairs.add((eid[i], slot[i]))
    # gates positive, normalized per token
    g = np.asarray(gate).reshape(t, k)
    assert np.allclose(g.sum(-1), 1.0, atol=1e-5)


def test_moe_ffn_matches_manual_expert_apply():
    """With capacity ample and top-1 routing, moe_ffn equals applying
    each token's argmax expert directly."""
    cfg = C.get_smoke("grok-1-314b")
    m = dataclasses.replace(cfg.moe, top_k=1, capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, moe=m)
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe_layer(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.float32) * 0.1
    out, _aux = MOE.moe_ffn(cfg, p, x)

    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    eids = np.asarray(jnp.argmax(logits, -1))
    de = cfg.moe.d_expert
    for t in range(8):
        e = int(eids[t])
        xt = x[0, t]
        h = xt @ p["experts"]["w_in"][e]
        if cfg.act in ("swiglu", "geglu"):
            g = xt @ p["experts"]["w_gate"][e]
            gate = jax.nn.silu(g) if cfg.act == "swiglu" \
                else jax.nn.gelu(g)
            h = gate * h
        else:
            h = jax.nn.gelu(h)
        want = h @ p["experts"]["w_out"][e]
        np.testing.assert_allclose(np.asarray(out[0, t]),
                                   np.asarray(want), rtol=2e-2,
                                   atol=2e-2)


# ---------------------------------------------------------------------- #
# chunked attention == unchunked (ragged tail covered)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("s,chunk", [(64, 16), (50, 16), (33, 32)])
def test_chunked_attention_equivalence(s, chunk):
    cfg = dataclasses.replace(C.get_smoke("qwen3-14b"), attn_chunk=chunk)
    cfg_u = dataclasses.replace(cfg, attn_chunk=None)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, nh, nkv, h = 2, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, nh, h), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, nkv, h), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, nkv, h), jnp.float32)
    for causal in (True, False):
        a = L.mha(cfg, q, k, v, causal=causal)
        bu = L.mha(cfg_u, q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(bu),
                                   atol=2e-5)


def test_param_count_scales():
    """param_count sanity: the published sizes are the right order."""
    from repro.configs.base import param_count
    assert 0.8e9 < param_count(C.get("olmo-1b")) < 2.5e9
    assert 250e9 < param_count(C.get("grok-1-314b")) < 400e9
    assert 300e9 < param_count(C.get("jamba-1.5-large-398b")) < 500e9
    assert 10e9 < param_count(C.get("qwen3-14b")) < 18e9
