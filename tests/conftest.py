import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def sparse_matrix(rng, m, n, density=0.1):
    return rng.random((m, n)) * (rng.random((m, n)) < density)


@pytest.fixture
def spmat():
    return sparse_matrix
