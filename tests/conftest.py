import os
import sys

import numpy as np
import pytest

# src-layout shim: make `python -m pytest` work without PYTHONPATH=src.
# The repo root is needed too (benchmarks/ imports in several tests).
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def sparse_matrix(rng, m, n, density=0.1):
    return rng.random((m, n)) * (rng.random((m, n)) < density)


@pytest.fixture
def spmat():
    return sparse_matrix


def pytest_sessionfinish(session, exitstatus):
    """Chaos-run gate: when a suite runs under ``$REPRO_FAULTS``, every
    seam fault the injector fired must be covered by a recorded
    DowngradeEvent.  A shortfall is a *silent* downgrade and fails the
    session even if every individual test passed."""
    if not os.environ.get("REPRO_FAULTS"):
        return
    from repro.testing.faults import verify_no_silent_downgrades
    verify_no_silent_downgrades()
