"""Coverage for core/formats.py: reference lowerings (CSR / CSC / COO /
bitmap / linked lists) round-trip against dense, and touch_bytes /
footprint accounting for U / C / B rank formats."""
import numpy as np
import pytest

from repro.core.fibertree import FTensor
from repro.core.formats import (CSR, algorithmic_min_traffic, subtree_bytes,
                                tensor_bytes, to_bitmap, to_coo, to_csc,
                                to_csr, to_linked_lists, touch_bytes)
from repro.core.spec import FormatSpec, RankFormat, TensorFormat


def _mat(seed=0, m=6, n=8, density=0.4):
    rng = np.random.default_rng(seed)
    return rng.random((m, n)) * (rng.random((m, n)) < density)


def _ft(a, name="A", ranks=("M", "N")):
    return FTensor.from_dense(name, list(ranks), a)


# ---------------------------------------------------------------------- #
# reference lowerings round-trip against dense
# ---------------------------------------------------------------------- #
def test_csr_roundtrip():
    a = _mat(1)
    csr = to_csr(_ft(a))
    assert csr.nnz == int(np.count_nonzero(a))
    back = np.zeros_like(a)
    for r in range(a.shape[0]):
        for p in range(csr.indptr[r], csr.indptr[r + 1]):
            back[r, csr.indices[p]] = csr.data[p]
    assert np.array_equal(back, a)
    # indptr is monotone and covers all of data
    assert np.all(np.diff(csr.indptr) >= 0)
    assert csr.indptr[-1] == csr.nnz


def test_csc_is_csr_of_transpose():
    a = _mat(2)
    csc = to_csc(_ft(a))
    csr_t = to_csr(_ft(a.T, ranks=("N", "M")))
    assert np.array_equal(csc.indptr, csr_t.indptr)
    assert np.array_equal(csc.indices, csr_t.indices)
    assert np.array_equal(csc.data, csr_t.data)


def test_coo_roundtrip():
    a = _mat(3)
    pts, vals = to_coo(_ft(a))
    back = np.zeros_like(a)
    back[pts[:, 0], pts[:, 1]] = vals
    assert np.array_equal(back, a)
    # flattened tuple coordinates expand to full points
    fl = _ft(a).flatten_ranks("M", "N")
    pts2, vals2 = to_coo(fl)
    assert pts2.shape == pts.shape
    back2 = np.zeros_like(a)
    back2[pts2[:, 0], pts2[:, 1]] = vals2
    assert np.array_equal(back2, a)


def test_coo_empty():
    pts, vals = to_coo(_ft(np.zeros((3, 4))))
    assert pts.shape == (0, 2) and vals.shape == (0,)


def test_bitmap_roundtrip():
    a = _mat(4)
    mask, packed = to_bitmap(_ft(a))
    assert mask.sum() == np.count_nonzero(a)
    back = np.zeros_like(a)
    back[mask] = packed
    assert np.array_equal(back, a)


def test_linked_lists_roundtrip():
    a = _mat(5)
    ll = to_linked_lists(_ft(a))
    assert ll.nnz == int(np.count_nonzero(a))
    back = np.zeros_like(a)
    for r, head in enumerate(ll.heads):
        p = int(head)
        while p != -1:
            c, v, nxt = ll.nodes[p]
            back[r, c] = v
            p = nxt
    assert np.array_equal(back, a)
    # empty rows have no list
    empty_rows = ~np.any(a != 0, axis=1)
    assert np.all(ll.heads[empty_rows] == -1)


# ---------------------------------------------------------------------- #
# byte accounting for U / C / B rank formats
# ---------------------------------------------------------------------- #
def _fmt(kind, cbits=32, pbits=64, fhbits=0):
    return TensorFormat("t", {
        "M": RankFormat(format="C", cbits=32, pbits=32),
        "N": RankFormat(format=kind, cbits=cbits, pbits=pbits,
                        fhbits=fhbits),
    })


def test_touch_bytes_compressed():
    f = _fmt("C")
    assert touch_bytes(f, "N", "coord") == 4.0
    assert touch_bytes(f, "N", "payload") == 8.0
    assert touch_bytes(f, "N", "elem") == 12.0


def test_touch_bytes_uncompressed_coords_free():
    f = _fmt("U")
    assert touch_bytes(f, "N", "coord") == 0.0    # positional
    assert touch_bytes(f, "N", "payload") == 8.0
    assert touch_bytes(f, "N", "elem") == 8.0


def test_touch_bytes_bitmap_coords_one_bit():
    """B ranks store coordinates as a bitmask: touching one coordinate
    moves one bit, matching subtree_bytes' shape/8 mask accounting."""
    f = _fmt("B")
    assert touch_bytes(f, "N", "coord") == 1 / 8
    assert touch_bytes(f, "N", "elem") == 8 + 1 / 8


def test_touch_bytes_unknown_rank_defaults():
    f = TensorFormat("t", {})
    assert touch_bytes(f, "Q", "coord") == 4.0    # RankFormat defaults
    assert touch_bytes(f, "Q", "payload") == 4.0
    with pytest.raises(ValueError):
        touch_bytes(f, "Q", "banana")


def test_tensor_bytes_c_format_counts_occupancy():
    a = np.zeros((4, 8))
    a[1, :3] = 1.0
    a[3, 5] = 2.0
    ft = _ft(a)
    f = _fmt("C", cbits=32, pbits=64)
    # M rank: 2 coords * 4B + 2 fiber refs * 4B; N rank: 4 coords * 4B
    # + 4 payloads * 8B
    assert tensor_bytes(ft, f) == 2 * 4 + 2 * 4 + 4 * 4 + 4 * 8


def test_tensor_bytes_u_format_counts_shape():
    a = np.zeros((4, 8))
    a[1, :3] = 1.0
    f = _fmt("U", pbits=64)
    # uncompressed N fibers store all 8 positions regardless of occupancy
    assert tensor_bytes(_ft(a), f) == 1 * 4 + 1 * 4 + 8 * 8


def test_tensor_bytes_b_format_adds_bitmask():
    a = np.zeros((4, 8))
    a[1, :3] = 1.0
    f = _fmt("B", pbits=64)
    # bitmap: shape/8 bytes of mask + packed payloads only
    assert tensor_bytes(_ft(a), f) == 1 * 4 + 1 * 4 + 8 / 8 + 3 * 8


def test_subtree_bytes_leaf_payload():
    a = _mat(6)
    ft = _ft(a)
    f = _fmt("C")
    leaf = ft.root.payloads[0].payloads[0]
    assert subtree_bytes(ft, f, leaf, 1) == 8.0


def test_algorithmic_min_traffic_sums_tensors():
    a, b = _mat(7), _mat(8)
    fa, fb = _ft(a, "A"), _ft(b, "B")
    out = _ft(a * 0 + (a != 0), "Z")
    fmt = FormatSpec()
    got = algorithmic_min_traffic({"A": fa, "B": fb}, out, fmt)
    want = (tensor_bytes(fa, fmt.default("A"))
            + tensor_bytes(fb, fmt.default("B"))
            + tensor_bytes(out, fmt.default("Z")))
    assert got == want
