"""Columnar CSF representation: lossless FTensor round-trips and
vectorized Section-3.2 transforms equivalent to the Fiber reference
implementations."""
import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis, or seeded fallback
from repro.core.csf import CSF
from repro.core.fibertree import FTensor


def rand_dense(seed, shape, density=0.3):
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 10, size=shape).astype(float)
    return a * (rng.random(shape) < density)


def assert_same_tree(ft: FTensor, cs: CSF):
    """Structural equality: the CSF converts back to the exact tree."""
    back = cs.to_ftensor()
    assert back.ranks == ft.ranks
    assert back.root == ft.root
    assert back.upper_ranks == ft.upper_ranks


# ---------------------------------------------------------------------- #
# conversion
# ---------------------------------------------------------------------- #
def test_roundtrip_lossless():
    a = rand_dense(0, (6, 8, 5))
    ft = FTensor.from_dense("T", ["M", "K", "N"], a)
    cs = CSF.from_ftensor(ft)
    assert cs.nnz == ft.nnz
    assert np.array_equal(cs.to_dense(), a)
    assert_same_tree(ft, cs)
    assert cs.to_ftensor().rank_shapes == ft.rank_shapes


def test_from_dense_and_coo():
    a = rand_dense(1, (7, 9))
    ft = FTensor.from_dense("A", ["M", "K"], a)
    assert_same_tree(ft, CSF.from_dense("A", ["M", "K"], a))
    pts = np.argwhere(a != 0)
    cs = CSF.from_coo("A", ["M", "K"], pts, a[tuple(pts.T)],
                      {"M": 7, "K": 9})
    assert_same_tree(ft, cs)
    # unsorted + duplicate points: last value wins (insert semantics)
    cs2 = CSF.from_coo("D", ["M"], [[3], [1], [3]], [1.0, 2.0, 9.0], {"M": 5})
    assert cs2.to_ftensor().root.lookup(3) == 9.0
    assert cs2.nnz == 2


def test_empty_and_1d():
    e = FTensor.from_dense("E", ["M", "K"], np.zeros((4, 4)))
    assert_same_tree(e, CSF.from_ftensor(e))
    v = FTensor.from_dense("V", ["K"], np.array([0.0, 3.0, 0.0, 7.0]))
    cs = CSF.from_ftensor(v)
    assert cs.nnz == 2
    assert_same_tree(v, cs)


# ---------------------------------------------------------------------- #
# vectorized transforms vs Fiber reference implementations
# ---------------------------------------------------------------------- #
def test_swizzle_matches_reference():
    a = rand_dense(2, (5, 6, 4))
    ft = FTensor.from_dense("T", ["M", "K", "N"], a)
    cs = CSF.from_ftensor(ft)
    for order in (["N", "M", "K"], ["K", "N", "M"], ["M", "K", "N"]):
        assert_same_tree(ft.swizzle(order), cs.swizzle(order))


def test_partition_uniform_shape_matches_reference():
    a = rand_dense(3, (9, 11))
    ft = FTensor.from_dense("A", ["M", "K"], a)
    cs = CSF.from_ftensor(ft)
    for rank, size in (("K", 3), ("M", 4), ("K", 1)):
        fp = ft.partition_uniform_shape(rank, size)
        cp = cs.partition_uniform_shape(rank, size)
        assert cp.ranks == fp.ranks
        assert cp.upper_ranks == fp.upper_ranks
        assert_same_tree(fp, cp)


def test_partition_uniform_occupancy_matches_reference():
    a = rand_dense(4, (8, 13), density=0.5)
    ft = FTensor.from_dense("A", ["M", "K"], a)
    cs = CSF.from_ftensor(ft)
    for rank, size in (("K", 4), ("M", 3), ("K", 2)):
        assert_same_tree(ft.partition_uniform_occupancy(rank, size),
                         cs.partition_uniform_occupancy(rank, size))


def test_flatten_matches_reference():
    a = rand_dense(5, (4, 5, 3))
    ft = FTensor.from_dense("T", ["M", "K", "N"], a)
    cs = CSF.from_ftensor(ft)
    assert_same_tree(ft.flatten_ranks("M", "K"), cs.flatten_ranks("M", "K"))
    assert_same_tree(ft.flatten_ranks("K", "N"), cs.flatten_ranks("K", "N"))


def test_transform_chains_match_reference():
    """The Figure-2 pipeline on arrays: flatten then occupancy-split."""
    a = rand_dense(6, (6, 7))
    ft = FTensor.from_dense("A", ["M", "K"], a)
    cs = CSF.from_ftensor(ft)
    fp = ft.flatten_ranks("M", "K").partition_uniform_occupancy("MK", 3)
    cp = cs.flatten_ranks("M", "K").partition_uniform_occupancy("MK", 3)
    assert_same_tree(fp, cp)
    fp2 = ft.partition_uniform_shape("M", 2).swizzle(["K", "M1", "M0"])
    cp2 = cs.partition_uniform_shape("M", 2).swizzle(["K", "M1", "M0"])
    assert_same_tree(fp2, cp2)


def test_shape_partition_rejects_flattened():
    cs = CSF.from_ftensor(
        FTensor.from_dense("A", ["M", "K"], rand_dense(7, (4, 4)))
    ).flatten_ranks("M", "K")
    with pytest.raises(ValueError):
        cs.partition_uniform_shape("MK", 2)


def test_content_points_drop_partition_uppers():
    a = rand_dense(8, (8, 8))
    cs = CSF.from_dense("A", ["M", "K"], a)
    pt = cs.partition_uniform_shape("K", 3)
    pts = pt.content_points()
    base = cs.point_matrix()
    assert sorted(map(tuple, pts.tolist())) == \
        sorted(map(tuple, base.tolist()))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(2, 8),
       k=st.integers(2, 8), size=st.integers(1, 5),
       which=st.sampled_from(["swizzle", "shape", "occupancy", "flatten"]))
def test_property_csf_transforms_match(seed, m, k, size, which):
    a = rand_dense(seed, (m, k), density=0.4)
    ft = FTensor.from_dense("A", ["M", "K"], a)
    cs = CSF.from_ftensor(ft)
    if which == "swizzle":
        f, c = ft.swizzle(["K", "M"]), cs.swizzle(["K", "M"])
    elif which == "shape":
        f, c = (ft.partition_uniform_shape("K", size),
                cs.partition_uniform_shape("K", size))
    elif which == "occupancy":
        f, c = (ft.partition_uniform_occupancy("M", size),
                cs.partition_uniform_occupancy("M", size))
    else:
        f, c = ft.flatten_ranks("M", "K"), cs.flatten_ranks("M", "K")
    assert_same_tree(f, c)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(2, 7),
       k=st.integers(2, 7), n=st.integers(2, 7),
       size=st.integers(1, 5), density=st.floats(0.1, 0.8),
       chain=st.sampled_from(["flatten-occ", "shape-swizzle",
                              "occ-flatten", "shape-occ", "flatten-deep"]))
def test_property_csf_transform_chains_roundtrip(seed, m, k, n, size,
                                                 density, chain):
    """Composed Section-3.2 transforms on random 3-rank sparse tensors:
    the vectorized CSF pipeline stays tree-exact against the fibertree
    oracle, and every intermediate converts back losslessly (the
    transform-pre-pass contract of the vector backend)."""
    a = rand_dense(seed, (m, k, n), density=density)
    ft = FTensor.from_dense("T", ["M", "K", "N"], a)
    cs = CSF.from_ftensor(ft)
    if chain == "flatten-occ":
        f = ft.flatten_ranks("M", "K").partition_uniform_occupancy(
            "MK", size)
        c = cs.flatten_ranks("M", "K").partition_uniform_occupancy(
            "MK", size)
    elif chain == "shape-swizzle":
        f = ft.partition_uniform_shape("K", size).swizzle(
            ["K1", "M", "K0", "N"])
        c = cs.partition_uniform_shape("K", size).swizzle(
            ["K1", "M", "K0", "N"])
    elif chain == "occ-flatten":
        f = ft.partition_uniform_occupancy("N", size).flatten_ranks(
            "N1", "N0")
        c = cs.partition_uniform_occupancy("N", size).flatten_ranks(
            "N1", "N0")
    elif chain == "shape-occ":
        f = ft.partition_uniform_shape("M", size) \
            .partition_uniform_occupancy("M0", max(size - 1, 1))
        c = cs.partition_uniform_shape("M", size) \
            .partition_uniform_occupancy("M0", max(size - 1, 1))
    else:                        # flatten the two innermost ranks
        f = ft.swizzle(["M", "K", "N"]).flatten_ranks("K", "N")
        c = cs.swizzle(["M", "K", "N"]).flatten_ranks("K", "N")
    assert_same_tree(f, c)
    # round-trip: CSF -> FTensor -> CSF is the identity on the tree
    back = CSF.from_ftensor(c.to_ftensor())
    assert_same_tree(c.to_ftensor(), back)
