"""Sharding rules + the TeAAL mapping->PartitionSpec compiler."""
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as S
from repro.launch.mesh import make_mesh
from repro.sharding.compiler import (compile_mapping,
                                     mapping_spec_for_step,
                                     step_partition_specs)
from repro.sharding.logical import spec_for, AxisRules


# ---------------------------------------------------------------------- #
# generic param heuristic
# ---------------------------------------------------------------------- #
def test_param_pspec_tp_last_divisible():
    assert S.param_pspec((512, 1024), tp=16, dp=8) == P("data", "model")
    # last dim not divisible -> TP moves to an earlier dim
    assert S.param_pspec((512, 1000), tp=16, dp=8) == P("model", "data")


def test_param_pspec_scan_leading_dim_skipped():
    # [L, d, f]: the layer-stack dim never takes TP; FSDP picks the
    # largest remaining divisible dim (512 here, not the 48-layer dim)
    sp = S.param_pspec((48, 512, 1024), tp=16, dp=8)
    assert sp == P(None, "data", "model")


def test_param_pspec_indivisible_stays_replicated():
    assert S.param_pspec((7, 5), tp=16, dp=16) == P(None, None)


def test_embedding_path_aware():
    mesh = jax.sharding.AbstractMesh((("data", 4), ("model", 4)))
    params = {"embed": {"tok": jnp.zeros((1024, 64))},
              "blocks": {"w": jnp.zeros((64, 256))}}
    specs = S.param_pspecs(params, mesh)
    # vocab dim sharded over model (so tied-lm-head logits shard)
    assert specs["embed"]["tok"] == P("model", "data")


def test_divisibility_fallback_in_rules():
    mesh = jax.sharding.AbstractMesh((("data", 4), ("model", 4)))
    rules = AxisRules({"batch": ("data",), "heads": ("model",)})
    # 6 heads % 4 != 0 -> replicated, batch 8 % 4 == 0 -> sharded
    sp = spec_for((8, 6), ("batch", "heads"), mesh=mesh)
    import repro.sharding.logical as L
    L.set_rules(rules)
    try:
        sp = spec_for((8, 6), ("batch", "heads"), mesh=mesh)
        assert sp == P("data", None)
    finally:
        L.set_rules(None)


# ---------------------------------------------------------------------- #
# TeAAL mapping -> PartitionSpec compiler
# ---------------------------------------------------------------------- #
def test_compile_mapping_spatial_ranks_shard():
    spec = mapping_spec_for_step(dp=4, tp=4)
    out = compile_mapping(spec, "H", {"B1": "data", "F1": "model"},
                          params={"B0S": 2, "F0S": 8})
    assert out["X"] == P("data", None)         # B sharded, D local
    assert out["Wi"] == P(None, "model")       # F sharded
    assert out["H"] == P("data", "model")


def test_step_partition_specs_end_to_end():
    out = step_partition_specs(global_batch=64, d_model=128, d_ff=512,
                               dp=4, tp=4)
    assert out["H"] == P("data", "model")


def test_compile_mapping_unbound_spatial_rank_raises():
    spec = mapping_spec_for_step(dp=4, tp=4)
    with pytest.raises(ValueError):
        compile_mapping(spec, "H", {"B1": "data"},
                        params={"B0S": 2, "F0S": 8})


# ---------------------------------------------------------------------- #
# cache specs
# ---------------------------------------------------------------------- #
def test_cache_pspecs_shard_kv_seq():
    import repro.configs as C
    mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 2)))
    cfg = C.get_smoke("qwen3-14b")
    specs = S.cache_pspecs(cfg, batch=4, max_len=64, mesh=mesh)
    # [L, b, s, kv, h]: batch over pod(data), seq over (data, model)
    assert specs["k"][1] is not None or specs["k"][2] is not None


# ---------------------------------------------------------------------- #
# real multi-device lowering (subprocess: needs its own XLA_FLAGS)
# ---------------------------------------------------------------------- #
SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import repro.configs as C
from repro.launch import sharding as S, steps as ST
from repro.sharding import logical
import dataclasses

cfg = dataclasses.replace(C.get_smoke("olmo-1b"), scan_layers=True)
mesh = jax.make_mesh((4, 2), ("data", "model"))
logical.set_mesh(mesh); logical.set_rules(S.rules_for("train"))
step = ST.make_train_step(cfg)
import repro.optim.optimizers as opt
specs = {
    "params": ST.param_specs(cfg),
    "opt_state": ST.opt_state_specs(cfg, opt.for_config(cfg)),
}
from repro.configs.base import ShapeSpec
shape = ShapeSpec("t", 64, 8, "train")
specs["batch"] = ST.batch_specs(cfg, shape)
p_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                              S.param_pspecs(specs["params"], mesh))
o_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                              S.param_pspecs(specs["opt_state"], mesh))
b_p = S.batch_pspecs(cfg, shape, mesh)
b_sh = {k: NamedSharding(mesh, b_p[k]) for k in specs["batch"]}
with mesh:
    lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
        specs["params"], specs["opt_state"], specs["batch"])
    compiled = lowered.compile()
ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, (list, tuple)) else ca
assert float(ca.get("flops", 0)) > 0
txt = compiled.as_text()
assert ("all-reduce" in txt) or ("all-gather" in txt) or \
       ("reduce-scatter" in txt)
print("SUBPROCESS_OK")
"""


def test_multi_device_train_step_compiles():
    """8 virtual devices, 4x2 mesh, smoke olmo: lower+compile must
    succeed and emit collectives (run in a subprocess so the main
    pytest process keeps its single-device view)."""
    r = subprocess.run([sys.executable, "-c", SUBPROC],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "SUBPROCESS_OK" in r.stdout, r.stderr[-2000:]
