"""The telemetry layer, end to end: hierarchical spans, the metrics
registry, Perfetto export, and the instrumented seams.

Contract under test (DESIGN.md "Telemetry contract"):

  * spans nest ``cascade -> einsum -> stage / seam`` across
    ``execute_batch``, with each span's parent recorded in
    ``args["parent"]``;
  * the disabled path is free -- ``maybe_span`` returns the shared
    ``NULL_SPAN`` and a guarded seam call allocates **nothing** in
    ``obs/spans.py`` (asserted with ``tracemalloc``);
  * the Chrome-trace export round-trips through ``json.loads`` with
    valid ``ph``/``ts``/``dur`` fields and Perfetto-required instant
    markers;
  * injected faults (``REPRO_FAULTS`` syntax) surface as ``downgrade``
    instant events, and every ``DowngradeEvent`` carries a monotonic
    ``ts_us`` plus the active Einsum tag;
  * ``stage_seconds`` ride ``SimResult``/``Report`` as per-request
    deltas (benchmarks no longer reach into the backend);
  * ``TeeInstr``/``CollectingInstr`` aggregate (n-weighted) and
    per-element emission produce identical totals, with the ``unique``
    hint passed through the tee verbatim.
"""
import json

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis, or seeded fallback
from repro.accelerators import gamma
from repro.core.generator import CascadeSimulator
from repro.core.trace import CollectingInstr, Instrumentation, TeeInstr
from repro.core.vectorized import VectorBackend
from repro.kernels import backends as kbk
from repro.obs import (NULL_SPAN, MetricsRegistry, Tracer, active_tracer,
                       chrome_trace, maybe_span, metrics, summarize_trace,
                       to_jsonl, trace_session, write_trace)
from repro.testing.faults import (FaultInjector, clear_injector,
                                  install_injector, parse_faults)


@pytest.fixture(autouse=True)
def _clean_slate(monkeypatch):
    """No tracer, no injector, no demotions, fresh metrics."""
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    monkeypatch.delenv("REPRO_GUARDS", raising=False)
    clear_injector()
    kbk.reset_guard_state()
    metrics().reset()
    yield
    clear_injector()
    kbk.reset_guard_state()
    metrics().reset()
    assert active_tracer() is None, "a test leaked an installed tracer"


def _spmm(rng, n=24, d=0.25):
    a = rng.random((n, n)) * (rng.random((n, n)) < d)
    b = rng.random((n, n)) * (rng.random((n, n)) < d)
    return {"A": a, "B": b}, {"m": n, "k": n, "n": n}


def _vector_sim(spec=None, model=False, **kw):
    vb = VectorBackend(kernel_backend=kbk.GuardedKernels(
        "numpy", sleep=lambda s: None))
    return CascadeSimulator(spec if spec is not None else gamma.spec(),
                            model=model, backend=vb, **kw), vb


# ---------------------------------------------------------------------- #
# tracer / span primitives
# ---------------------------------------------------------------------- #
def test_span_nesting_records_parent():
    tr = Tracer()
    with tr.span("outer", "a"):
        with tr.span("inner", "b"):
            pass
    inner = next(e for e in tr.spans() if e["name"] == "inner")
    outer = next(e for e in tr.spans() if e["name"] == "outer")
    assert inner["args"]["parent"] == "outer"
    assert "args" not in outer or "parent" not in outer.get("args", {})
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0


def test_span_error_annotation_and_set():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("boom", "t") as sp:
            sp.set("k", 3)
            raise ValueError("x")
    ev = tr.spans()[0]
    assert ev["args"]["error"] == "ValueError"
    assert ev["args"]["k"] == 3


def test_trace_session_installs_and_restores():
    assert active_tracer() is None
    with trace_session() as tr:
        assert active_tracer() is tr
        with trace_session() as tr2:
            assert active_tracer() is tr2
        assert active_tracer() is tr
    assert active_tracer() is None


def test_maybe_span_disabled_is_null_singleton():
    assert active_tracer() is None
    s1 = maybe_span("einsum:x", "einsum")
    s2 = maybe_span("seam:y", "seam", {"a": 1})
    assert s1 is NULL_SPAN and s2 is NULL_SPAN
    with s1 as s:               # context protocol is a no-op
        s.set("k", "v")


def test_disabled_seam_path_allocates_nothing_in_spans():
    """The committed ``vector_rate`` rides on this: with no tracer
    installed, a guarded seam call must not allocate a single object
    in ``obs/spans.py`` (one cached-global read + ``None`` check)."""
    import tracemalloc

    import repro.obs.spans as spans_mod
    assert active_tracer() is None
    gk = kbk.GuardedKernels("numpy", sleep=lambda s: None)
    a = np.array([1, 3, 5, 7, 9], dtype=np.int64)
    b = np.array([3, 7, 11], dtype=np.int64)
    gk.intersect_keys(a, b)     # warm resolution + caches
    tracemalloc.start()
    try:
        for _ in range(64):
            gk.intersect_keys(a, b)
            maybe_span("seam:intersect_keys", "seam")
        snap = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    stats = snap.filter_traces(
        [tracemalloc.Filter(True, spans_mod.__file__)]
    ).statistics("filename")
    assert sum(s.size for s in stats) == 0, stats


# ---------------------------------------------------------------------- #
# metrics registry
# ---------------------------------------------------------------------- #
def test_metrics_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2.5)
    reg.gauge("g").set(7.0)
    h = reg.histogram("h", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3.5
    assert snap["gauges"]["g"] == 7.0
    hs = snap["histograms"]["h"]
    assert hs["count"] == 3
    assert hs["buckets"] == [0.1, 1.0, "+Inf"]
    assert hs["counts"] == [1, 1, 1]
    assert hs["sum"] == pytest.approx(5.55)
    table = reg.summary_table()
    assert "c" in table and "h" in table
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_metrics_registry_same_instrument_identity():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.histogram("y") is reg.histogram("y")


# ---------------------------------------------------------------------- #
# spans across the execution layer
# ---------------------------------------------------------------------- #
def test_spans_nest_across_execute_batch(rng):
    """Gamma's two-Einsum cascade through the vector backend: one
    cascade span, one einsum span per Einsum parented to it, seam and
    stage spans parented to their einsum."""
    inputs, shapes = _spmm(rng)
    sim, _ = _vector_sim()
    with trace_session() as tr:
        res = sim.run(dict(inputs), shapes)
    assert not res.fallback_reasons
    cascades = tr.spans("cascade")
    assert len(cascades) == 1
    cname = cascades[0]["name"]
    einsums = tr.spans("einsum")
    assert {e["name"] for e in einsums} == {"einsum:T", "einsum:Z"}
    for e in einsums:
        assert e["args"]["parent"] == cname
        assert e["args"]["path"] == "vector"
    # stage spans always belong to an einsum; seam spans may also fire
    # at cascade level (CSF construction), never unparented here
    for e in tr.spans("stage"):
        assert e["args"]["parent"] in {"einsum:T", "einsum:Z"}, e
    seams = tr.spans("seam")
    assert seams, "guarded seam calls must produce spans"
    parents = {e["args"]["parent"] for e in seams}
    assert parents <= {cname, "einsum:T", "einsum:Z"}
    assert parents & {"einsum:T", "einsum:Z"}, parents
    stages = tr.spans("stage")
    assert stages and all(e["args"]["synthetic"] for e in stages)
    # every span inside its einsum's wall-clock window (synthetic stage
    # spans are laid out inside it by construction)
    win = {e["name"]: (e["ts"], e["ts"] + e["dur"]) for e in einsums}
    for e in stages:
        lo, hi = win[e["args"]["parent"]]
        assert e["ts"] >= lo - 1.0 and e["ts"] + e["dur"] <= hi + 1.0


def test_seam_spans_carry_backend_and_histogram(rng):
    inputs, shapes = _spmm(rng)
    sim, _ = _vector_sim()
    with trace_session() as tr:
        sim.run(dict(inputs), shapes)
    seams = tr.spans("seam")
    assert all(e["args"]["backend"] == "numpy" for e in seams)
    snap = metrics().snapshot()
    hists = [k for k in snap["histograms"]
             if k.startswith("kernel.seam_seconds/")]
    assert hists, snap
    assert all(k.endswith("/numpy") for k in hists)
    total = sum(snap["histograms"][k]["count"] for k in hists)
    assert total == len(seams)


def test_stage_seconds_on_simresult_and_report(rng):
    inputs, shapes = _spmm(rng)
    sim, vb = _vector_sim(model=True)
    with trace_session():
        res = sim.run(dict(inputs), shapes)
    assert set(res.stage_seconds) == {"T", "Z"}
    for per in res.stage_seconds.values():
        assert per and all(v > 0 for v in per.values())
    # the report aggregate is the per-Einsum sum (execute() resets the
    # profile counters per request, so each dict is that Einsum alone)
    agg = {}
    for per in res.stage_seconds.values():
        for k, v in per.items():
            agg[k] = agg.get(k, 0.0) + v
    assert res.report.stage_seconds == pytest.approx(agg)
    # the backend's own counters hold the last-executed request (Z)
    assert vb.stage_seconds == pytest.approx(res.stage_seconds["Z"])
    snap = metrics().snapshot()
    assert any(k.startswith("vector.stage_seconds/")
               for k in snap["counters"])


def test_stage_seconds_absent_when_disabled(rng):
    inputs, shapes = _spmm(rng)
    sim, vb = _vector_sim(model=True)
    assert active_tracer() is None
    res = sim.run(dict(inputs), shapes)
    assert res.stage_seconds == {}
    assert res.report.stage_seconds == {}
    assert vb.profile is False


# ---------------------------------------------------------------------- #
# export round-trip
# ---------------------------------------------------------------------- #
def _traced_run(rng):
    inputs, shapes = _spmm(rng)
    sim, _ = _vector_sim()
    with trace_session() as tr:
        tr.instant("downgrade:x", "downgrade", {"seam": "s"})
        sim.run(dict(inputs), shapes)
    return tr


def test_chrome_trace_round_trips_json(rng):
    tr = _traced_run(rng)
    doc = json.loads(json.dumps(chrome_trace(tr)))
    evs = doc["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["args"]["name"] == "repro"
    assert doc["displayTimeUnit"] == "ms"
    assert "metrics" in doc["otherData"]
    phs = {e["ph"] for e in evs}
    assert phs <= {"M", "X", "i"}
    last_ts = -1.0
    for e in evs[1:]:
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        assert e["ts"] >= last_ts      # exporter time-orders events
        last_ts = e["ts"]
        assert e["pid"] and e["name"] and e["cat"]
        if e["ph"] == "X":
            assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        elif e["ph"] == "i":
            assert e["s"] == "t"       # Perfetto requires a scope


def test_write_trace_formats(tmp_path, rng):
    tr = _traced_run(rng)
    pj = write_trace(tmp_path / "t.json", tr)
    doc = json.loads(pj.read_text())
    assert doc["traceEvents"]
    pl = write_trace(tmp_path / "t.jsonl", tr)
    lines = [json.loads(ln) for ln in pl.read_text().splitlines()]
    assert lines[-1]["kind"] == "metrics"
    assert all("ph" in ln for ln in lines[:-1])
    assert len(lines) - 1 == len(tr.events)
    text = summarize_trace(tr)
    assert "einsum:" in text and "downgrade:x" in text


# ---------------------------------------------------------------------- #
# chaos leg: injected faults in the trace
# ---------------------------------------------------------------------- #
def test_injected_faults_appear_as_instant_events(rng):
    """A REPRO_FAULTS-syntax spec fires mid-run; the resulting
    downgrade must surface as a trace instant carrying the event's
    fields, and the recorded DowngradeEvent must be stamped with a
    timestamp and the active Einsum."""
    install_injector(FaultInjector(parse_faults(
        "kind=raise,seam=intersect_keys,backend=numpy,at=1")))
    inputs, shapes = _spmm(rng)
    sim, vb = _vector_sim()
    with trace_session() as tr:
        res = sim.run(dict(inputs), shapes)
    assert res.downgrade_events, "the fault must be recorded"
    insts = tr.instants("downgrade")
    assert insts, "every recorded downgrade emits a trace instant"
    evs = [e for per in res.downgrade_events.values() for e in per]
    by_name = {}
    for i in insts:
        by_name.setdefault(i["name"], []).append(i)
    for ev in evs:
        assert "downgrade:" + ev.action in by_name
    args = insts[0]["args"]
    assert args["seam"] == "intersect_keys"
    assert args["backend"] == "numpy"
    assert args["ts_us"] > 0 and args["einsum"]
    snap = metrics().snapshot()
    assert sum(v for k, v in snap["counters"].items()
               if k.startswith("kernel.downgrade/")) >= len(evs)


def test_downgrade_events_timestamped_and_monotonic(rng):
    """Satellite (c): ``ts_us`` is stamped at record time (tracer or
    not) and orders events monotonically; the Einsum tag names the
    Einsum that was executing."""
    install_injector(FaultInjector(parse_faults(
        "kind=raise,seam=intersect_keys,backend=numpy,every=1")))
    inputs, shapes = _spmm(rng)
    sim, _ = _vector_sim()
    assert active_tracer() is None   # stamping must not need a tracer
    res = sim.run(dict(inputs), shapes)
    evs = [e for per in res.downgrade_events.values() for e in per]
    assert evs
    assert all(e.ts_us > 0 for e in evs)
    assert [e.ts_us for e in evs] == sorted(e.ts_us for e in evs)
    for einsum, per in res.downgrade_events.items():
        assert all(e.einsum == einsum for e in per), (einsum, per)
    d = evs[0].as_dict()
    assert d["ts_us"] == evs[0].ts_us and d["einsum"] == evs[0].einsum


# ---------------------------------------------------------------------- #
# DSE sweep telemetry
# ---------------------------------------------------------------------- #
def test_dse_sweep_point_spans_and_tallies(rng):
    from repro.dse import DesignSpace, SweepEngine
    inputs, shapes = _spmm(rng, n=32, d=0.15)
    points = DesignSpace(
        "gamma", axes={"fibercache_mb": [0.01, 1.0]}).grid()
    eng = SweepEngine(inputs, shapes, backend="analytic")
    with trace_session() as tr:
        results = eng.sweep(points)
    assert all(r.ok for r in results)
    spans = tr.spans("dse")
    assert len(spans) == len(points)
    assert {s["args"]["status"] for s in spans} == {"ok"}
    snap = metrics().snapshot()
    assert snap["counters"]["dse.point/ok"] == len(points)
    assert snap["counters"]["dse.point_attempts"] == len(points)
    cache = {k: v for k, v in snap["counters"].items()
             if k.startswith("dse.plan_cache/")}
    assert sum(cache.values()) == len(points)
    assert cache.get("dse.plan_cache/miss", 0) >= 1


# ---------------------------------------------------------------------- #
# TeeInstr / CollectingInstr parity (satellite b)
# ---------------------------------------------------------------------- #
class _RecordingSink(Instrumentation):
    """Captures raw call args -- CollectingInstr drops ``unique``, so
    pass-through can only be asserted on a sink that keeps it."""

    def __init__(self):
        self.touches = []
        self.computes = []

    def touch(self, einsum, tensor, rank, path, kind, rw, n=1,
              unique=None):
        self.touches.append((einsum, tensor, rank, kind, rw, n, unique))

    def compute(self, einsum, op, n=1):
        self.computes.append((einsum, op, n))


COUNTERS = ("touch_counts", "iter_counts", "compute_counts",
            "isect_steps", "isect_matches", "advances")


@settings(max_examples=20)
@given(n_events=st.integers(min_value=1, max_value=12),
       seed=st.integers(min_value=0, max_value=2**16))
def test_tee_aggregate_matches_per_element(n_events, seed):
    """n-weighted aggregate emission and element-by-element emission
    drive identical collected totals through a tee, and the ``unique``
    hint reaches every sink verbatim."""
    r = np.random.default_rng(seed)
    tensors = ("A", "B", "Z")
    ranks = ("m", "k", "n")
    events = []
    for _ in range(n_events):
        n = int(r.integers(1, 9))
        events.append((
            tensors[r.integers(0, 3)], ranks[r.integers(0, 3)],
            ("coord", "payload")[r.integers(0, 2)],
            ("read", "write")[r.integers(0, 2)], n,
            None if r.integers(0, 2) else int(r.integers(0, n + 1)),
            ("mul", "add")[r.integers(0, 2)],
        ))
    agg_c, agg_r = CollectingInstr(), _RecordingSink()
    ele_c, ele_r = CollectingInstr(), _RecordingSink()
    agg, ele = TeeInstr(agg_c, agg_r), TeeInstr(ele_c, ele_r)
    for tensor, rank, kind, rw, n, unique, op in events:
        agg.touch("Z", tensor, rank, (), kind, rw, n=n, unique=unique)
        agg.compute("Z", op, n=n)
        agg.iterate("Z", rank, n=n)
        agg.advance("Z", rank, n=n)
        agg.isect_step("Z", rank, tensor, n=n)
        agg.isect_match("Z", rank, n=n)
        for _ in range(n):
            ele.touch("Z", tensor, rank, (), kind, rw)
            ele.compute("Z", op)
            ele.iterate("Z", rank)
            ele.advance("Z", rank)
            ele.isect_step("Z", rank, tensor)
            ele.isect_match("Z", rank)
    for name in COUNTERS:
        assert getattr(agg_c, name) == getattr(ele_c, name), name
    # unique pass-through: the tee forwards the kwarg untouched
    assert [t[-1] for t in agg_r.touches] == [e[5] for e in events]
    assert [t[5] for t in agg_r.touches] == [e[4] for e in events]
    # per-element emission cannot carry an aggregate hint
    assert all(t[-1] is None for t in ele_r.touches)


# ---------------------------------------------------------------------- #
# bench_compare gate logic
# ---------------------------------------------------------------------- #
def test_bench_compare_gate_semantics():
    from benchmarks.bench_compare import Gate
    g = Gate()
    g.rate("fast-enough", 100.0, 80.0, 0.25)     # 80 >= 75: ok
    g.rate("faster", 100.0, 500.0, 0.25)         # one-sided: ok
    g.rate("too-slow", 100.0, 74.0, 0.25)        # 74 < 75: regression
    g.exact("same", 5, 5)
    g.exact("drifted", 5, 6)
    g.skip("leg", "missing")
    assert g.failures == 2
    rep = g.report()
    assert "2 regression(s)" in rep
    assert rep.count("REGRESSION") == 2 and "skipped" in rep


def test_bench_compare_committed_baselines_self_consistent():
    """The committed BENCH files must pass their own gate: dse compared
    against itself and the graph structural claims."""
    import benchmarks.bench_compare as bc
    committed = bc._load(bc.BENCH_DSE)
    if committed is None:
        pytest.skip("no committed BENCH_dse.json")
    g = bc.Gate()
    bc.compare_dse(g, tolerance=0.25, fresh_summary=committed)
    bc.compare_graph(g)
    assert g.failures == 0, g.report()
