"""Backend equivalence: VectorBackend must produce bit-identical output
tensors and matching aggregate instrumentation action counts vs
PythonBackend (the oracle) for every accelerator spec and zoo cascade,
whether an Einsum takes the columnar fast path or falls back."""
import numpy as np
import pytest

from repro.accelerators import (extensor, gamma, matraptor, outerspace,
                                sigma)
from repro.accelerators.zoo import ZOO
from repro.core.generator import CascadeSimulator
from repro.core.trace import CollectingInstr
from repro.core.vectorized import VectorBackend

COUNTERS = ("touch_counts", "iter_counts", "compute_counts",
            "isect_steps", "isect_matches", "advances")


def _run(spec, inputs, shapes, params, backend):
    ci = CollectingInstr()
    sim = CascadeSimulator(spec, params=params, model=False,
                           extra_instr=ci, backend=backend)
    res = sim.run(dict(inputs), shapes)
    return res, ci


def assert_equivalent(spec, inputs, shapes, params=None,
                      backend=None) -> str:
    vb = backend or VectorBackend()
    res_p, ci_p = _run(spec, inputs, shapes, params, "python")
    res_v, ci_v = _run(spec, inputs, shapes, params, vb)
    for name in res_p.tensors:
        dp = res_p[name].to_dense()
        dv = res_v[name].to_dense()
        assert dp.shape == dv.shape, name
        assert np.array_equal(dp, dv), \
            f"{spec.name}:{name} output differs (not bit-identical)"
    for attr in COUNTERS:
        assert getattr(ci_p, attr) == getattr(ci_v, attr), \
            f"{spec.name}: aggregate {attr} differ"
    return vb.last_path


# ---------------------------------------------------------------------- #
# the four validated designs (+ MatRaptor)
# ---------------------------------------------------------------------- #
ACCELS = [
    ("outerspace", outerspace, None),
    ("extensor", extensor, extensor.DEFAULT_PARAMS),
    ("gamma", gamma, None),
    ("sigma", sigma, None),
    ("matraptor", matraptor, None),
]


@pytest.mark.parametrize("name,mod,params", ACCELS,
                         ids=[a[0] for a in ACCELS])
def test_accelerator_backend_equivalence(name, mod, params, rng, spmat):
    M = K = N = 32
    a, b = spmat(rng, M, K, 0.2), spmat(rng, K, N, 0.2)
    path = assert_equivalent(mod.spec(), {"A": a, "B": b},
                             {"m": M, "k": K, "n": N}, params)
    assert path == "vector", f"{name} left the vector path"


# ---------------------------------------------------------------------- #
# the full zoo
# ---------------------------------------------------------------------- #
def _zoo_inputs(name, rng):
    if name in ("eyeriss-conv", "toeplitz-conv"):
        return ({"I": rng.random((2, 3, 6, 6)) *
                 (rng.random((2, 3, 6, 6)) < .5),
                 "F": rng.random((3, 4, 3, 3))},
                {"b": 2, "c": 3, "h": 6, "w": 6, "m": 4, "r": 3, "s": 3,
                 "p": 4, "q": 4})
    if name in ("tensaurus-mttkrp", "factorized-mttkrp"):
        return ({"T": rng.random((5, 4, 3)) * (rng.random((5, 4, 3)) < .4),
                 "A": rng.random((3, 6)), "B": rng.random((4, 6))},
                {"i": 5, "j": 4, "k": 3, "r": 6})
    if name == "fft-step":
        return ({"P": rng.random((1, 4, 2, 2)), "X": rng.random((2, 2))},
                {"u": 1, "k0": 4, "n1": 2, "v": 2})
    if name in ("elementwise-3way", "sparse-add-3way"):
        return ({"A": rng.random((20, 20)) * (rng.random((20, 20)) < 0.3),
                 "B": rng.random((20, 20)) * (rng.random((20, 20)) < 0.4),
                 "C": rng.random((20, 20)) * (rng.random((20, 20)) < 0.3)},
                {"m": 20, "n": 20})
    if name == "broadcast-outer":
        return ({"A": rng.random(20) * (rng.random(20) < 0.5),
                 "B": rng.random(20) * (rng.random(20) < 0.5)},
                {"m": 20, "n": 7})
    return ({"A": rng.random((20, 20)) * (rng.random((20, 20)) < 0.25),
             "B": rng.random((20, 20)) * (rng.random((20, 20)) < 0.25)},
            {"m": 20, "k": 20, "n": 20})


@pytest.mark.parametrize("name", sorted(ZOO))
def test_zoo_backend_equivalence(name):
    inputs, shapes = _zoo_inputs(name, np.random.default_rng(7))
    assert_equivalent(ZOO[name](), inputs, shapes)


#: zoo cascades that must run fully native on the vector path -- the
#: feature coverage of the VectorPlan IR: plain two-driver SpMSpM,
#: two- and three-way unions, >2-driver intersections, driverless
#: dense ranks, affine (conv im2col) and constant (FFT) index maps
NATIVE_ZOO = ("rowwise-spmspm", "sparse-add", "tensaurus-mttkrp",
              "factorized-mttkrp", "elementwise-3way", "sparse-add-3way",
              "broadcast-outer", "eyeriss-conv", "toeplitz-conv",
              "fft-step")


@pytest.mark.parametrize("name", NATIVE_ZOO)
def test_zoo_vector_native_paths(name):
    """The cascades the columnar engine is built for must actually run
    vectorized, not through the fallback."""
    inputs, shapes = _zoo_inputs(name, np.random.default_rng(3))
    sim = CascadeSimulator(ZOO[name](), model=False, backend="vector")
    res = sim.run(dict(inputs), shapes)
    assert res.fallback_reasons == {}, name
    assert_equivalent(ZOO[name](), inputs, shapes)


def test_partitioned_specs_run_native():
    """Partitioned (Gamma-style occupancy) plans now lower to the
    VectorPlan IR instead of falling back to the interpreter."""
    rng = np.random.default_rng(5)
    a = rng.random((24, 24)) * (rng.random((24, 24)) < 0.2)
    b = rng.random((24, 24)) * (rng.random((24, 24)) < 0.2)
    path = assert_equivalent(gamma.spec(), {"A": a, "B": b},
                             {"m": 24, "k": 24, "n": 24})
    assert path == "vector"


def test_accelerator_cascades_run_native(rng, spmat):
    """Full-zoo coverage (the point of the vector-plan pipeline): the
    SIGMA, OuterSPACE and MatRaptor cascades -- flattened ranks,
    catch-up lookups, leaf-bound output ranks, take() filters,
    leader-follower probing -- plus Gamma and ExTensor all execute on
    the vector path with no recorded fallbacks."""
    a, b = spmat(rng, 24, 24, 0.2), spmat(rng, 24, 24, 0.2)
    shapes = {"m": 24, "k": 24, "n": 24}
    for name, mod, params in ACCELS:
        sim = CascadeSimulator(mod.spec(), params=params, model=False,
                               backend="vector")
        res = sim.run({"A": a, "B": b}, shapes)
        assert res.fallback_reasons == {}, \
            f"{name}: {res.fallback_reasons}"


def test_fallback_reasons_surfaced(rng, spmat):
    """The per-Einsum oracle fallback must not be silent: the run
    result (and Report) records why each Einsum left the fast path,
    and is empty when the whole cascade ran native."""
    from repro.core.einsum import Semiring

    a, b = spmat(rng, 24, 24, 0.2), spmat(rng, 24, 24, 0.2)
    shapes = {"m": 24, "k": 24, "n": 24}

    # Rowwise-SpMSpM is the vector backend's canonical workload: it
    # must run fully vectorized, with no recorded fallbacks.
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), backend="vector")
    res = sim.run({"A": a, "B": b}, shapes)
    assert res.fallback_reasons == {}
    assert res.report.fallback_reasons == {}

    # an interpreter-only semiring (no vectorized forms) stays outside
    # the IR: every Einsum surfaces a reason, mirrored onto the Report,
    # and the scalar oracle still produces the cascade output.
    scalar_only = Semiring(add=min, mul=lambda x, y: x + y,
                           add_identity=float("inf"), name="scalar_min")
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), backend="vector",
                           semiring=scalar_only)
    res = sim.run({"A": a, "B": b}, shapes)
    assert set(res.fallback_reasons) == {"Z"}
    assert "scalar_min" in res.fallback_reasons["Z"]
    assert res.report.fallback_reasons == res.fallback_reasons

    # the oracle itself never "falls back"
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), backend="python")
    res = sim.run({"A": a, "B": b}, shapes)
    assert res.fallback_reasons == {}


# ---------------------------------------------------------------------- #
# graph accelerators (Sec. 8): min-plus + update-in-place on the IR
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("design", ["graphicionado", "graphdyns", "ours"])
@pytest.mark.parametrize("algo", ["bfs", "sssp"])
def test_graph_accelerators_native_and_equivalent(design, algo):
    """The three vertex-centric designs run iterative BFS/SSSP under
    the min-plus semiring fully on the vector path (no fallbacks --
    including GraphDynS's partitioned bitmap and update-in-place P0),
    bit-exact against the oracle with matching aggregate counts."""
    from benchmarks.workloads import grid_graph
    from repro.accelerators import graphicionado as G
    from repro.core.einsum import Semiring

    weighted = algo == "sssp"
    adj = grid_graph(6, extra=6, weighted=weighted)
    v = adj.shape[0]
    spec = {
        "graphicionado": lambda: G.graphicionado_spec(weighted=weighted),
        "graphdyns": lambda: G.graphdyns_spec(weighted=weighted,
                                              n_vertices=v),
        "ours": lambda: G.improved_spec(weighted=weighted),
    }[design]()
    a0 = np.zeros(v)
    a0[0] = 1.0
    p0 = np.zeros(v)
    p0[0] = 1.0
    outs, cis = {}, {}
    for bk in ("python", "vector"):
        ci = CollectingInstr()
        sim = CascadeSimulator(spec, semiring=Semiring.min_plus(),
                               model=False, extra_instr=ci, backend=bk)
        res, _ = sim.run_iterative(
            {"G": adj.copy(), "A0": a0.copy(), "P0": p0.copy()},
            carry={"A0": "A1", "P0": "P1"}, done_when_empty="A1",
            max_iters=60, var_shapes={"d": v, "s": v})
        if bk == "vector":
            assert res.fallback_reasons == {}, res.fallback_reasons
        outs[bk] = {n: res[n].to_dense() for n in res.tensors}
        cis[bk] = ci
    for n in outs["python"]:
        assert np.array_equal(outs["python"][n], outs["vector"][n]), n
    for attr in COUNTERS:
        assert getattr(cis["python"], attr) == getattr(cis["vector"],
                                                       attr), attr


# ---------------------------------------------------------------------- #
# chunked execution and edge shapes
# ---------------------------------------------------------------------- #
def test_chunked_execution_matches(rng, spmat):
    a, b = spmat(rng, 40, 40, 0.2), spmat(rng, 40, 40, 0.2)
    vb = VectorBackend(chunk_items=3)
    path = assert_equivalent(ZOO["rowwise-spmspm"](), {"A": a, "B": b},
                             {"m": 40, "k": 40, "n": 40}, backend=vb)
    assert path == "vector"


def test_empty_inputs(rng):
    z = np.zeros((8, 8))
    nz = rng.random((8, 8)) * (rng.random((8, 8)) < 0.3)
    assert_equivalent(ZOO["rowwise-spmspm"](), {"A": z, "B": z},
                      {"m": 8, "k": 8, "n": 8})
    # one-sided empties: a non-empty frontier intersecting an empty
    # operand must not escape the vector path as an IndexError
    path = assert_equivalent(ZOO["rowwise-spmspm"](), {"A": nz, "B": z},
                             {"m": 8, "k": 8, "n": 8})
    assert path == "vector"
    assert_equivalent(ZOO["rowwise-spmspm"](), {"A": z, "B": nz},
                      {"m": 8, "k": 8, "n": 8})
    assert_equivalent(ZOO["sparse-add"](), {"A": z, "B": nz},
                      {"m": 8, "n": 8})


def test_vector_backend_report_sane(rng, spmat):
    """With the performance model on, the vector backend still drives a
    plausible report through the n-weighted aggregate event path."""
    a, b = spmat(rng, 32, 32, 0.2), spmat(rng, 32, 32, 0.2)
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), backend="vector")
    res = sim.run({"A": a, "B": b}, {"m": 32, "k": 32, "n": 32})
    # the zoo spec binds no components: the report exists and DRAM
    # traffic covers at least both operand reads
    assert res.report is not None
    nnz = int(np.count_nonzero(a)) + int(np.count_nonzero(b))
    assert res.report.dram_bytes >= nnz * 4


def test_mapped_workloads_equivalent_and_native(rng, spmat):
    """The throughput benchmark's flattened (SIGMA-style) and
    partitioned (OuterSPACE-style) SpMSpM mappings: bit-exact + count
    parity vs the oracle, with no fallback."""
    from benchmarks.backend_throughput import (flattened_spmspm,
                                               partitioned_spmspm)
    a, b = spmat(rng, 40, 40, 0.2), spmat(rng, 40, 40, 0.2)
    shapes = {"m": 40, "k": 40, "n": 40}
    for factory, inputs in (
            (flattened_spmspm, {"A": a.T.copy(), "B": b}),
            (partitioned_spmspm, {"A": a, "B": b})):
        spec = factory(k_tile=8, stationary=32) \
            if factory is flattened_spmspm else factory(rows=8, k_tile=16)
        path = assert_equivalent(spec, inputs, shapes)
        assert path == "vector", spec.name
        sim = CascadeSimulator(spec, model=False, backend="vector")
        res = sim.run(dict(inputs), shapes)
        assert res.fallback_reasons == {}, spec.name


def test_execute_csf_pre_pass_transforms(rng, spmat):
    """execute_csf on *raw* (storage-form) CSFs: the Section-3.2
    transform pre-pass (flatten / partition / swizzle on arrays) must
    produce the same product as the dense reference."""
    from benchmarks.backend_throughput import (flattened_spmspm,
                                               partitioned_spmspm)
    from repro.core.csf import CSF
    from repro.core.generator import restore_declared
    from repro.core.mapping import MappingResolver

    a, b = spmat(rng, 36, 36, 0.25), spmat(rng, 36, 36, 0.25)
    want = a @ b
    for spec, a_ranks, a_mat in (
            (flattened_spmspm(k_tile=8, stationary=32), ["K", "M"],
             a.T.copy()),
            (partitioned_spmspm(rows=8, k_tile=16), ["M", "K"], a)):
        plan = MappingResolver(spec).plan("Z")
        vb = VectorBackend()
        out_csf, stats = vb.execute_csf(
            plan, {"A": CSF.from_dense("A", a_ranks, a_mat),
                   "B": CSF.from_dense("B", ["K", "N"], b)})
        ft = restore_declared(out_csf.to_ftensor(), plan, ["M", "N"],
                              {"M": 36, "N": 36})
        got = np.zeros_like(want)
        for path, val in ft.iter_leaves():
            got[path] = val
        assert np.allclose(got, want), spec.name
        assert stats["muls"] > 0


def test_execute_csf_skips_materialization(rng, spmat):
    """Benchmark entry point: columnar in, columnar out."""
    from repro.core.csf import CSF
    from repro.core.mapping import MappingResolver

    a, b = spmat(rng, 30, 30, 0.2), spmat(rng, 30, 30, 0.2)
    spec = ZOO["rowwise-spmspm"]()
    plan = MappingResolver(spec).plan("Z")
    vb = VectorBackend()
    out_csf, stats = vb.execute_csf(
        plan, {"A": CSF.from_dense("A", ["M", "K"], a),
               "B": CSF.from_dense("B", ["K", "N"], b)})
    want = a @ b
    got = np.zeros_like(want)
    d = out_csf.to_dense()
    got[:d.shape[0], :d.shape[1]] = d
    assert np.allclose(got, want)
    assert stats["muls"] > 0 and stats["out_nnz"] == out_csf.nnz


# ---------------------------------------------------------------------- #
# the two remaining vector-path fallback reasons, encoded
# ---------------------------------------------------------------------- #
def _non_atomic_sum_spec():
    from repro.core.spec import load_spec
    return load_spec({
        "name": "NonAtomicSum",
        "einsum": {
            "declaration": {"A": ["M", "K"], "B": ["K", "N"],
                            "C": ["M", "N"], "Z": ["M", "N"]},
            "expressions": ["Z[m, n] = A[m, k] * B[k, n] + C[m, n]"],
        },
        "mapping": {"loop-order": {"Z": ["M", "K", "N"]}},
    })


def _update_in_place_swapped_spec():
    from repro.core.spec import load_spec
    return load_spec({
        "name": "UpdateInPlaceSwapped",
        "einsum": {
            "declaration": {"B": ["M", "N"], "Z": ["M", "N"]},
            "expressions": ["Z[m, n] = B[m, n]"],
        },
        # Z arrives pre-seeded (a run input, GraphDynS-style filtered
        # write) but the write executes N-major while the seed stays
        # M-major in storage -> out_initial is not in execution form
        "mapping": {"rank-order": {"B": ["M", "N"], "Z": ["M", "N"]},
                    "loop-order": {"Z": ["N", "M"]}},
    })


def _update_in_place_backend_call(rng, spmat, backend):
    """Drive the backend seam directly with a declared-order (M-major)
    seed while the Einsum executes N-major.  The generator's
    ``transform_tensor`` re-swizzles every spec-reachable seed into
    execution form, so this remaining vplan fallback class has no zoo
    representative (see benchmarks/run.py REMAINING_REASONS) -- it is
    only observable at the ``execute(out_initial=...)`` API."""
    from repro.core.fibertree import FTensor
    from repro.core.mapping import MappingResolver

    spec = _update_in_place_swapped_spec()
    resolver = MappingResolver(spec)
    plan = resolver.plan("Z")
    b = spmat(rng, 12, 12, 0.4)
    z = spmat(rng, 12, 12, 0.4)
    exec_forms = resolver.transform_all(
        "Z", {"B": FTensor.from_dense("B", ["M", "N"], b)})
    seed = FTensor.from_dense("Z", ["M", "N"], z)   # declared order
    assert list(seed.ranks) != plan.tensors["Z"].exec_order
    backend.execute(plan, exec_forms, {"m": 12, "n": 12},
                    out_initial=seed)
    return backend


def _fallback_inputs(rng, spmat):
    a, b = spmat(rng, 12, 12, 0.4), spmat(rng, 12, 12, 0.4)
    return {"A": a, "B": b, "C": spmat(rng, 12, 12, 0.4)}, \
        {"m": 12, "k": 12, "n": 12}


def test_remaining_fallback_reasons_surfaced(rng, spmat):
    """The two plans still outside the VectorPlan IR fall back loudly,
    with their reason strings recorded (and outputs still bit-exact
    via the oracle -- assert_equivalent covers that)."""
    inputs, shapes = _fallback_inputs(rng, spmat)
    assert_equivalent(_non_atomic_sum_spec(), inputs, shapes)
    sim = CascadeSimulator(_non_atomic_sum_spec(), model=False,
                           backend="vector")
    res = sim.run(dict(inputs), shapes)
    assert "sum of non-atomic terms" in res.fallback_reasons.get("Z", "")

    # through the simulator the seed is re-formed, so the cascade runs
    # native end to end (and stays bit-exact vs the oracle)
    ui = {"Z": inputs["A"], "B": inputs["B"]}
    assert_equivalent(_update_in_place_swapped_spec(), ui,
                      {"m": 12, "n": 12})
    # at the backend seam a declared-order seed falls back loudly
    vb = _update_in_place_backend_call(rng, spmat, VectorBackend())
    assert vb.last_path == "fallback"
    assert "update-in-place output not in execution form" in \
        (vb.last_fallback_reason or "")


@pytest.mark.xfail(strict=True,
                   reason="sums of non-atomic terms are not lowered to "
                          "the VectorPlan IR yet (vplan.lower)")
def test_non_atomic_sum_runs_native(rng, spmat):
    inputs, shapes = _fallback_inputs(rng, spmat)
    sim = CascadeSimulator(_non_atomic_sum_spec(), model=False,
                           backend="vector")
    res = sim.run(dict(inputs), shapes)
    assert res.fallback_reasons == {}


@pytest.mark.xfail(strict=True,
                   reason="update-in-place seeds whose stored rank order "
                          "differs from execution order are not "
                          "re-swizzled by the vector path yet")
def test_update_in_place_swapped_runs_native(rng, spmat):
    vb = _update_in_place_backend_call(rng, spmat, VectorBackend())
    assert vb.last_path == "vector"
