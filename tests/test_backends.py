"""Backend equivalence: VectorBackend must produce bit-identical output
tensors and matching aggregate instrumentation action counts vs
PythonBackend (the oracle) for every accelerator spec and zoo cascade,
whether an Einsum takes the columnar fast path or falls back."""
import numpy as np
import pytest

from repro.accelerators import (extensor, gamma, matraptor, outerspace,
                                sigma)
from repro.accelerators.zoo import ZOO
from repro.core.generator import CascadeSimulator
from repro.core.trace import CollectingInstr
from repro.core.vectorized import VectorBackend

COUNTERS = ("touch_counts", "iter_counts", "compute_counts",
            "isect_steps", "isect_matches", "advances")


def _run(spec, inputs, shapes, params, backend):
    ci = CollectingInstr()
    sim = CascadeSimulator(spec, params=params, model=False,
                           extra_instr=ci, backend=backend)
    res = sim.run(dict(inputs), shapes)
    return res, ci


def assert_equivalent(spec, inputs, shapes, params=None,
                      backend=None) -> str:
    vb = backend or VectorBackend()
    res_p, ci_p = _run(spec, inputs, shapes, params, "python")
    res_v, ci_v = _run(spec, inputs, shapes, params, vb)
    for name in res_p.tensors:
        dp = res_p[name].to_dense()
        dv = res_v[name].to_dense()
        assert dp.shape == dv.shape, name
        assert np.array_equal(dp, dv), \
            f"{spec.name}:{name} output differs (not bit-identical)"
    for attr in COUNTERS:
        assert getattr(ci_p, attr) == getattr(ci_v, attr), \
            f"{spec.name}: aggregate {attr} differ"
    return vb.last_path


# ---------------------------------------------------------------------- #
# the four validated designs (+ MatRaptor)
# ---------------------------------------------------------------------- #
ACCELS = [
    ("outerspace", outerspace, None),
    ("extensor", extensor, extensor.DEFAULT_PARAMS),
    ("gamma", gamma, None),
    ("sigma", sigma, None),
    ("matraptor", matraptor, None),
]


@pytest.mark.parametrize("name,mod,params", ACCELS,
                         ids=[a[0] for a in ACCELS])
def test_accelerator_backend_equivalence(name, mod, params, rng, spmat):
    M = K = N = 32
    a, b = spmat(rng, M, K, 0.2), spmat(rng, K, N, 0.2)
    assert_equivalent(mod.spec(), {"A": a, "B": b},
                      {"m": M, "k": K, "n": N}, params)


# ---------------------------------------------------------------------- #
# the full zoo
# ---------------------------------------------------------------------- #
def _zoo_inputs(name, rng):
    if name in ("eyeriss-conv", "toeplitz-conv"):
        return ({"I": rng.random((2, 3, 6, 6)) *
                 (rng.random((2, 3, 6, 6)) < .5),
                 "F": rng.random((3, 4, 3, 3))},
                {"b": 2, "c": 3, "h": 6, "w": 6, "m": 4, "r": 3, "s": 3,
                 "p": 4, "q": 4})
    if name in ("tensaurus-mttkrp", "factorized-mttkrp"):
        return ({"T": rng.random((5, 4, 3)) * (rng.random((5, 4, 3)) < .4),
                 "A": rng.random((3, 6)), "B": rng.random((4, 6))},
                {"i": 5, "j": 4, "k": 3, "r": 6})
    if name == "fft-step":
        return ({"P": rng.random((1, 4, 2, 2)), "X": rng.random((2, 2))},
                {"u": 1, "k0": 4, "n1": 2, "v": 2})
    return ({"A": rng.random((20, 20)) * (rng.random((20, 20)) < 0.25),
             "B": rng.random((20, 20)) * (rng.random((20, 20)) < 0.25)},
            {"m": 20, "k": 20, "n": 20})


@pytest.mark.parametrize("name", sorted(ZOO))
def test_zoo_backend_equivalence(name):
    inputs, shapes = _zoo_inputs(name, np.random.default_rng(7))
    assert_equivalent(ZOO[name](), inputs, shapes)


def test_zoo_vector_native_paths():
    """The cascades the columnar engine is built for must actually run
    vectorized, not through the fallback."""
    for name in ("rowwise-spmspm", "sparse-add", "tensaurus-mttkrp"):
        inputs, shapes = _zoo_inputs(name, np.random.default_rng(3))
        path = assert_equivalent(ZOO[name](), inputs, shapes)
        assert path == "vector", name


def test_partitioned_specs_fall_back():
    rng = np.random.default_rng(5)
    a = rng.random((24, 24)) * (rng.random((24, 24)) < 0.2)
    b = rng.random((24, 24)) * (rng.random((24, 24)) < 0.2)
    path = assert_equivalent(gamma.spec(), {"A": a, "B": b},
                             {"m": 24, "k": 24, "n": 24})
    assert path == "fallback"


def test_fallback_reasons_surfaced(rng, spmat):
    """The per-Einsum oracle fallback must not be silent: the run
    result (and Report) records why each Einsum left the fast path,
    and is empty when the whole cascade ran native."""
    a, b = spmat(rng, 24, 24, 0.2), spmat(rng, 24, 24, 0.2)
    shapes = {"m": 24, "k": 24, "n": 24}

    # Rowwise-SpMSpM is the vector backend's canonical workload: it
    # must run fully vectorized, with no recorded fallbacks.
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), backend="vector")
    res = sim.run({"A": a, "B": b}, shapes)
    assert res.fallback_reasons == {}
    assert res.report.fallback_reasons == {}

    # Gamma's partitioned plans leave the vector path: both Einsums
    # surface a reason, mirrored onto the Report.
    sim = CascadeSimulator(gamma.spec(), backend="vector")
    res = sim.run({"A": a, "B": b}, shapes)
    assert set(res.fallback_reasons) == {"T", "Z"}
    assert all(res.fallback_reasons.values())
    assert res.report.fallback_reasons == res.fallback_reasons

    # the oracle itself never "falls back"
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), backend="python")
    res = sim.run({"A": a, "B": b}, shapes)
    assert res.fallback_reasons == {}


# ---------------------------------------------------------------------- #
# chunked execution and edge shapes
# ---------------------------------------------------------------------- #
def test_chunked_execution_matches(rng, spmat):
    a, b = spmat(rng, 40, 40, 0.2), spmat(rng, 40, 40, 0.2)
    vb = VectorBackend(chunk_items=3)
    path = assert_equivalent(ZOO["rowwise-spmspm"](), {"A": a, "B": b},
                             {"m": 40, "k": 40, "n": 40}, backend=vb)
    assert path == "vector"


def test_empty_inputs(rng):
    z = np.zeros((8, 8))
    nz = rng.random((8, 8)) * (rng.random((8, 8)) < 0.3)
    assert_equivalent(ZOO["rowwise-spmspm"](), {"A": z, "B": z},
                      {"m": 8, "k": 8, "n": 8})
    # one-sided empties: a non-empty frontier intersecting an empty
    # operand must not escape the vector path as an IndexError
    path = assert_equivalent(ZOO["rowwise-spmspm"](), {"A": nz, "B": z},
                             {"m": 8, "k": 8, "n": 8})
    assert path == "vector"
    assert_equivalent(ZOO["rowwise-spmspm"](), {"A": z, "B": nz},
                      {"m": 8, "k": 8, "n": 8})
    assert_equivalent(ZOO["sparse-add"](), {"A": z, "B": nz},
                      {"m": 8, "n": 8})


def test_vector_backend_report_sane(rng, spmat):
    """With the performance model on, the vector backend still drives a
    plausible report through the n-weighted aggregate event path."""
    a, b = spmat(rng, 32, 32, 0.2), spmat(rng, 32, 32, 0.2)
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), backend="vector")
    res = sim.run({"A": a, "B": b}, {"m": 32, "k": 32, "n": 32})
    # the zoo spec binds no components: the report exists and DRAM
    # traffic covers at least both operand reads
    assert res.report is not None
    nnz = int(np.count_nonzero(a)) + int(np.count_nonzero(b))
    assert res.report.dram_bytes >= nnz * 4


def test_execute_csf_skips_materialization(rng, spmat):
    """Benchmark entry point: columnar in, columnar out."""
    from repro.core.csf import CSF
    from repro.core.mapping import MappingResolver

    a, b = spmat(rng, 30, 30, 0.2), spmat(rng, 30, 30, 0.2)
    spec = ZOO["rowwise-spmspm"]()
    plan = MappingResolver(spec).plan("Z")
    vb = VectorBackend()
    out_csf, stats = vb.execute_csf(
        plan, {"A": CSF.from_dense("A", ["M", "K"], a),
               "B": CSF.from_dense("B", ["K", "N"], b)})
    want = a @ b
    got = np.zeros_like(want)
    d = out_csf.to_dense()
    got[:d.shape[0], :d.shape[1]] = d
    assert np.allclose(got, want)
    assert stats["muls"] > 0 and stats["out_nnz"] == out_csf.nnz
