"""Runtime substrate: data determinism, checkpoint atomicity/restore,
optimizers, health/straggler decisions, elastic planning, gradient
compression (property: EF residual + transmitted == original)."""
import os
import threading
import time

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or seeded fallback

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, save_checkpoint
from repro.data import DataConfig, ShardedSyntheticDataset
from repro.optim import optimizers as opt
from repro.runtime import (ElasticPlan, ErrorFeedback, HeartbeatMonitor,
                           int8_dequantize, int8_quantize, plan_mesh,
                           topk_compress, topk_decompress)
from repro.runtime.health import HostState


# ---------------------------------------------------------------------- #
# data pipeline
# ---------------------------------------------------------------------- #
def _dcfg(**kw):
    base = dict(vocab=100, seq_len=16, global_batch=8, seed=3)
    base.update(kw)
    return DataConfig(**base)


def test_data_deterministic():
    d1 = ShardedSyntheticDataset(_dcfg())
    d2 = ShardedSyntheticDataset(_dcfg())
    b1 = d1.global_batch_at(7)
    b2 = d2.global_batch_at(7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["labels"], b2["labels"])


def test_data_labels_shifted():
    d = ShardedSyntheticDataset(_dcfg())
    b = d.global_batch_at(0)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_host_sharding_consistent():
    """Two hosts' shards concatenate to the global batch (elastic
    contract: sharding by global example index)."""
    d = ShardedSyntheticDataset(_dcfg())
    full = d.global_batch_at(5)["tokens"]
    h0 = d.batch_slice(5, 0, 4)["tokens"]
    h1 = d.batch_slice(5, 4, 8)["tokens"]
    assert np.array_equal(np.concatenate([h0, h1]), full)


def test_data_resume_mid_stream():
    d = ShardedSyntheticDataset(_dcfg())
    it = d.iterate(start_step=9, host_id=1, n_hosts=2)
    got = next(it)["tokens"]
    want = d.batch_slice(9, 4, 8)["tokens"]
    assert np.array_equal(got, want)


def test_data_steps_differ():
    d = ShardedSyntheticDataset(_dcfg())
    assert not np.array_equal(d.global_batch_at(0)["tokens"],
                              d.global_batch_at(1)["tokens"])


# ---------------------------------------------------------------------- #
# checkpointing
# ---------------------------------------------------------------------- #
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "b": {"x": jnp.arange(5, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 3, tree)
    mgr = CheckpointManager(tmp_path)
    got, step = mgr.restore(like=tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["x"]),
                                  np.asarray(tree["b"]["x"]))


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_step() is None
    # a stale .tmp dir must never be listed as a checkpoint
    (tmp_path / "step_000000007.tmp").mkdir()
    assert mgr.steps() == []
    save_checkpoint(tmp_path, 8, _tree())
    assert mgr.latest_step() == 8


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(11, _tree())
    mgr.wait()
    assert mgr.latest_step() == 11


def test_checkpoint_resharded_restore(tmp_path):
    """Restore onto explicit shardings (elastic path on 1 device)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = _tree()
    save_checkpoint(tmp_path, 2, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P(None, None)),
          "b": {"x": NamedSharding(mesh, P(None))}}
    mgr = CheckpointManager(tmp_path)
    got, step = mgr.restore(like=tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))


# ---------------------------------------------------------------------- #
# optimizers
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("make", [
    lambda: opt.adamw(1e-1), lambda: opt.adafactor(5e-1)],
    ids=["adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(make):
    optimizer = make()
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = optimizer.init(params)
    for _ in range(60):
        grads = {"x": 2 * params["x"]}          # d/dx ||x||^2
        params, state = optimizer.update(params, grads, state, None)
    assert float(jnp.sum(params["x"] ** 2)) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)


def test_cosine_schedule_shape():
    lr = opt.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-9)


def test_for_config_selects_by_size():
    import repro.configs as C
    assert opt.for_config(C.get("olmo-1b")).name == "adamw"
    assert opt.for_config(C.get("grok-1-314b")).name == "adafactor"
    assert opt.for_config(C.get("jamba-1.5-large-398b")).name == \
        "adafactor"


# ---------------------------------------------------------------------- #
# health / straggler
# ---------------------------------------------------------------------- #
def test_heartbeat_dead_detection():
    mon = HeartbeatMonitor(n_hosts=4, dead_after_s=10.0)
    now = 1000.0
    for h in range(4):
        mon.heartbeat(h, step=1, step_latency_s=1.0, now=now)
    mon.heartbeat(0, 2, 1.0, now=now + 5)
    mon.heartbeat(1, 2, 1.0, now=now + 5)
    mon.heartbeat(2, 2, 1.0, now=now + 5)
    # host 3 last seen at t=1000; at t=1012 it is >10 s stale while the
    # others (t=1005) are only 7 s stale
    d = mon.evaluate(now=now + 12)
    assert d.dead == [3]
    assert d.should_resize
    assert d.healthy_count == 3


def test_straggler_needs_patience():
    mon = HeartbeatMonitor(n_hosts=4, straggler_factor=2.0,
                           straggler_patience=3)
    now = 0.0
    for rep in range(4):
        for h in range(4):
            lat = 10.0 if h == 2 else 1.0
            mon.heartbeat(h, rep, lat, now=now)
        d = mon.evaluate(now=now)
        now += 1.0
    assert 2 in d.stragglers
    assert mon.hosts[2].state == HostState.STRAGGLER
    assert mon.hosts[0].state == HostState.HEALTHY


# ---------------------------------------------------------------------- #
# elastic planning
# ---------------------------------------------------------------------- #
def test_plan_mesh_full_fleet():
    p = plan_mesh(512, tp=16, chips_per_pod=256)
    assert (p.pods, p.dp, p.tp) == (2, 16, 16)
    assert p.used_chips == 512 and p.idle_chips == 0


def test_plan_mesh_lost_hosts():
    # lose 40 chips from one pod: dp shrinks to the next power of two
    p = plan_mesh(512 - 40, tp=16, chips_per_pod=256)
    assert p.tp == 16
    assert p.used_chips <= 472
    assert p.dp in (8, 16)


def test_plan_mesh_scale_factor():
    old = plan_mesh(512, tp=16)
    new = plan_mesh(256, tp=16, old_plan=old)
    assert new.global_batch_scale == pytest.approx(
        (new.dp * new.pods) / (old.dp * old.pods))


# ---------------------------------------------------------------------- #
# gradient compression
# ---------------------------------------------------------------------- #
def test_topk_roundtrip_identity():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(100),
                    jnp.float32)
    vals, idx, residual = topk_compress(g, 0.1)
    rebuilt = topk_decompress(vals, idx, g.shape) + residual
    np.testing.assert_allclose(np.asarray(rebuilt), np.asarray(g),
                               atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       frac=st.sampled_from([0.01, 0.1, 0.5]))
def test_property_error_feedback_conserves_mass(seed, frac):
    """transmitted + residual == grads + old residual (nothing lost)."""
    rng = np.random.default_rng(seed)
    grads = {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
    ef = ErrorFeedback(frac=frac)
    res = ef.init(grads)
    comp, new_res = ef.compress(grads, res)
    sent = ef.decompress(comp, grads)
    np.testing.assert_allclose(
        np.asarray(sent["w"] + new_res["w"]),
        np.asarray(grads["w"] + res["w"]), atol=1e-5)


def test_int8_quantization_error_bounded():
    g = jnp.asarray(np.random.default_rng(1).standard_normal(1000),
                    jnp.float32)
    q, scale = int8_quantize(g)
    back = int8_dequantize(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.5 + 1e-6
