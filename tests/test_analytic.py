"""AnalyticBackend contract (see DESIGN.md "analytic backend"):

  * calibrated mode reproduces the oracle's aggregate action counts
    *exactly* on dense-rank / single-driver plans;
  * on random SpMSpM, intersection counts (pointer steps, matches) are
    within 10% of PythonBackend totals;
  * plans the analytic walk covers (including Gamma's partitioned,
    take-based, leader-follower cascade) run natively -- no oracle
    fallback -- and produce a plausible Report;
  * unsupported plans fall back per Einsum with the reason surfaced.
"""
import numpy as np
import pytest

from repro.accelerators import extensor, gamma
from repro.accelerators.zoo import ZOO
from repro.core.analytic import AnalyticBackend
from repro.core.density import TensorDensity, expected_distinct
from repro.core.fibertree import FTensor
from repro.core.generator import CascadeSimulator
from repro.core.spec import load_spec
from repro.core.trace import CollectingInstr

COUNTERS = ("touch_counts", "iter_counts", "compute_counts",
            "isect_steps", "isect_matches", "advances")


def _run(spec, inputs, shapes, backend, params=None, model=False):
    ci = CollectingInstr()
    sim = CascadeSimulator(spec, params=params, model=model,
                           extra_instr=ci, backend=backend)
    res = sim.run(dict(inputs), shapes)
    return ci, res


def assert_counts_exact(spec, inputs, shapes, params=None):
    ci_p, _ = _run(spec, inputs, shapes, "python", params)
    ab = AnalyticBackend()
    ci_a, res = _run(spec, inputs, shapes, ab, params)
    assert res.fallback_reasons == {}
    for attr in COUNTERS:
        assert getattr(ci_p, attr) == getattr(ci_a, attr), \
            f"{spec.name}: {attr} not exact"


# ---------------------------------------------------------------------- #
# exactness: single-driver and dense-rank plans
# ---------------------------------------------------------------------- #
def test_single_driver_reduction_exact(rng, spmat):
    spec = load_spec({
        "name": "RowSum",
        "einsum": {"declaration": {"A": ["M", "K"], "Y": ["M"]},
                   "expressions": ["Y[m] = A[m, k]"]},
        "mapping": {}})
    a = spmat(rng, 24, 24, 0.3)
    assert_counts_exact(spec, {"A": a}, {"m": 24, "k": 24})


def test_dense_rank_broadcast_exact(rng):
    spec = load_spec({
        "name": "Bcast",
        "einsum": {"declaration": {"A": ["N"], "Z": ["M", "N"]},
                   "expressions": ["Z[m, n] = A[n]"]},
        "mapping": {}})
    a = rng.random(12) * (rng.random(12) < 0.5)
    assert_counts_exact(spec, {"A": a}, {"m": 6, "n": 12})


def test_single_driver_three_rank_exact(rng):
    spec = load_spec({
        "name": "Contract",
        "einsum": {"declaration": {"T": ["I", "J", "K"], "Y": ["I"]},
                   "expressions": ["Y[i] = T[i, j, k]"]},
        "mapping": {}})
    t = rng.random((6, 5, 4)) * (rng.random((6, 5, 4)) < 0.4)
    assert_counts_exact(spec, {"T": t}, {"i": 6, "j": 5, "k": 4})


# ---------------------------------------------------------------------- #
# statistical: SpMSpM intersection counts within 10%
# ---------------------------------------------------------------------- #
def test_spmspm_intersection_counts_within_10pct(rng, spmat):
    M = K = N = 64
    a, b = spmat(rng, M, K, 0.3), spmat(rng, K, N, 0.3)
    spec = ZOO["rowwise-spmspm"]()
    shapes = {"m": M, "k": K, "n": N}
    ci_p, _ = _run(spec, {"A": a, "B": b}, shapes, "python")
    ci_a, res = _run(spec, {"A": a, "B": b}, shapes, AnalyticBackend())
    assert res.fallback_reasons == {}
    for key in set(ci_p.isect_steps) | set(ci_a.isect_steps):
        p, an = ci_p.isect_steps[key], ci_a.isect_steps[key]
        assert abs(an - p) <= 0.10 * max(p, 1), \
            f"isect_steps {key}: {p} vs {an}"
    for key in set(ci_p.isect_matches) | set(ci_a.isect_matches):
        p, an = ci_p.isect_matches[key], ci_a.isect_matches[key]
        assert abs(an - p) <= 0.10 * max(p, 1), \
            f"isect_matches {key}: {p} vs {an}"
    # compute counts ride on the same estimates: keep them honest too
    for key in set(ci_p.compute_counts) | set(ci_a.compute_counts):
        p, an = ci_p.compute_counts[key], ci_a.compute_counts[key]
        assert abs(an - p) <= 0.10 * max(p, 1), \
            f"compute {key}: {p} vs {an}"


def test_sparse_add_union_counts_close(rng, spmat):
    a, b = spmat(rng, 32, 32, 0.25), spmat(rng, 32, 32, 0.25)
    spec = ZOO["sparse-add"]()
    ci_p, _ = _run(spec, {"A": a, "B": b}, {"m": 32, "n": 32}, "python")
    ci_a, res = _run(spec, {"A": a, "B": b}, {"m": 32, "n": 32},
                     AnalyticBackend())
    assert res.fallback_reasons == {}
    for key in ci_p.iter_counts:
        p, an = ci_p.iter_counts[key], ci_a.iter_counts[key]
        assert abs(an - p) <= 0.15 * max(p, 1), f"iterate {key}"


# ---------------------------------------------------------------------- #
# native coverage of the validated designs
# ---------------------------------------------------------------------- #
def _workload(rng, n=96, d=0.12):
    a = rng.random((n, n)) * (rng.random((n, n)) < d)
    b = rng.random((n, n)) * (rng.random((n, n)) < d)
    return {"A": a, "B": b}, {"m": n, "k": n, "n": n}


def test_gamma_runs_native_with_plausible_counts(rng):
    """Gamma (partitioned ranks, take(), leader-follower) is exactly
    the plan class the vector backend cannot cover: the analytic
    engine must run it natively and land near the oracle."""
    inputs, shapes = _workload(rng)
    ab = AnalyticBackend()
    ci_a, res = _run(gamma.spec(), inputs, shapes, ab, model=True)
    assert res.fallback_reasons == {}
    ci_p, res_p = _run(gamma.spec(), inputs, shapes, "python", model=True)
    mul_p = sum(v for k, v in ci_p.compute_counts.items() if k[1] == "mul")
    mul_a = sum(v for k, v in ci_a.compute_counts.items() if k[1] == "mul")
    assert abs(mul_a - mul_p) <= 0.10 * mul_p
    assert res.report.seconds > 0
    assert res.report.energy_pj > 0
    assert res.report.dram_bytes > 0


def test_extensor_runs_native_with_plausible_counts(rng):
    inputs, shapes = _workload(rng)
    ab = AnalyticBackend()
    ci_a, res = _run(extensor.spec(), inputs, shapes, ab,
                     params=extensor.DEFAULT_PARAMS, model=True)
    assert res.fallback_reasons == {}
    ci_p, _ = _run(extensor.spec(), inputs, shapes, "python",
                   params=extensor.DEFAULT_PARAMS, model=True)
    mul_p = sum(v for k, v in ci_p.compute_counts.items() if k[1] == "mul")
    mul_a = sum(v for k, v in ci_a.compute_counts.items() if k[1] == "mul")
    assert abs(mul_a - mul_p) <= 0.10 * mul_p


def test_traffic_responds_to_cache_capacity(rng):
    """The statistical residency model must make DRAM traffic a
    monotonically non-increasing function of FiberCache capacity --
    the property the Sec.-8 capacity sweep studies."""
    inputs, shapes = _workload(rng)
    traffic = []
    for mb in (0.001, 0.005, 3.0):
        _, res = _run(gamma.spec(fibercache_mb=mb), inputs, shapes,
                      AnalyticBackend(), model=True)
        traffic.append(res.report.dram_bytes)
    assert traffic[0] > traffic[-1]
    assert all(x >= y for x, y in zip(traffic, traffic[1:]))


# ---------------------------------------------------------------------- #
# fallback behavior
# ---------------------------------------------------------------------- #
def test_affine_plan_runs_native(rng):
    """Affine (conv im2col) index maps are modeled natively: the
    halo-hit-fraction lookup model keeps aggregate counts within a few
    percent of the oracle on valid-padding conv (where the probe span
    exactly tiles the input and the fraction is 1.0)."""
    spec = ZOO["eyeriss-conv"]()
    inputs = {"I": rng.random((2, 3, 6, 6)) * (rng.random((2, 3, 6, 6)) < .5),
              "F": rng.random((3, 4, 3, 3))}
    shapes = {"b": 2, "c": 3, "h": 6, "w": 6, "m": 4, "r": 3, "s": 3,
              "p": 4, "q": 4}
    ci_a, res = _run(spec, inputs, shapes, AnalyticBackend())
    assert res.fallback_reasons == {}
    ci_p, _ = _run(spec, inputs, shapes, "python")
    mul_p = sum(v for k, v in ci_p.compute_counts.items() if k[1] == "mul")
    mul_a = sum(v for k, v in ci_a.compute_counts.items() if k[1] == "mul")
    assert abs(mul_a - mul_p) <= 0.10 * max(mul_p, 1)
    tch_p, tch_a = sum(ci_p.touch_counts.values()), \
        sum(ci_a.touch_counts.values())
    assert abs(tch_a - tch_p) <= 0.10 * max(tch_p, 1)


def test_affine_halo_hit_fraction():
    """The density-layer halo model behind affine lookups: probes
    uniform over the affine span, clipped to the target domain."""
    from repro.core.density import affine_hit_fraction, affine_span

    shapes = {"p": 4.0, "r": 3.0}
    conv = (("p", 1), ("r", 1))
    # valid padding (H = P + R - 1): span [0, 5] tiles domain 6 exactly
    assert affine_span(conv, 0, shapes) == (0.0, 5.0)
    assert affine_hit_fraction(conv, 0, shapes, 6.0) == 1.0
    # shifted window sheds the out-of-range halo: span [-1, 4] -> 5/6
    assert affine_hit_fraction(conv, -1, shapes, 6.0) == \
        pytest.approx(5.0 / 6.0)
    # constant index: in-domain hits, out-of-domain never does
    assert affine_hit_fraction((), 2, {}, 6.0) == 1.0
    assert affine_hit_fraction((), 9, {}, 6.0) == 0.0
    # negative coefficients extend the low side of the span
    assert affine_span((("p", 1), ("r", -1)), 0, shapes) == (-2.0, 3.0)


def test_fallback_disabled_raises(rng, spmat):
    from repro.core.analytic import _Unsupported
    from repro.core.einsum import Semiring

    # an interpreter-only semiring (no vectorized forms) stays outside
    # the analytic model, as does an update-in-place output
    scalar_only = Semiring(add=min, mul=lambda x, y: x + y,
                           add_identity=float("inf"), name="scalar_min")
    a, b = spmat(rng, 16, 16, 0.3), spmat(rng, 16, 16, 0.3)
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](), model=False,
                           semiring=scalar_only,
                           backend=AnalyticBackend(fallback=False))
    with pytest.raises(_Unsupported):
        sim.run({"A": a, "B": b}, {"m": 16, "k": 16, "n": 16})


# ---------------------------------------------------------------------- #
# cascades: predicted intermediates
# ---------------------------------------------------------------------- #
def test_cascade_propagates_predicted_intermediates(rng):
    """Factorized MTTKRP: the second Einsum consumes an intermediate
    the analytic engine never materialized; its counts must still be
    in the oracle's neighborhood."""
    spec = ZOO["factorized-mttkrp"]()
    t = rng.random((5, 4, 3)) * (rng.random((5, 4, 3)) < .4)
    inputs = {"T": t, "A": rng.random((3, 6)), "B": rng.random((4, 6))}
    shapes = {"i": 5, "j": 4, "k": 3, "r": 6}
    ci_a, res = _run(spec, inputs, shapes, AnalyticBackend())
    assert res.fallback_reasons == {}
    ci_p, _ = _run(spec, inputs, shapes, "python")
    mul_p = sum(v for k, v in ci_p.compute_counts.items() if k[1] == "mul")
    mul_a = sum(v for k, v in ci_a.compute_counts.items() if k[1] == "mul")
    assert mul_a > 0
    assert abs(mul_a - mul_p) <= 0.35 * max(mul_p, 1)


def test_analytic_outputs_are_empty(rng, spmat):
    """The engine's defining property: no data is ever materialized."""
    a, b = spmat(rng, 16, 16, 0.3), spmat(rng, 16, 16, 0.3)
    _, res = _run(ZOO["rowwise-spmspm"](), {"A": a, "B": b},
                  {"m": 16, "k": 16, "n": 16}, AnalyticBackend())
    assert res["Z"].nnz == 0


def test_iterative_cascades_reject_analytic(rng, spmat):
    """Empty analytic outputs must not masquerade as convergence."""
    a, b = spmat(rng, 8, 8, 0.3), spmat(rng, 8, 8, 0.3)
    sim = CascadeSimulator(ZOO["rowwise-spmspm"](),
                           backend=AnalyticBackend())
    with pytest.raises(ValueError, match="materializes no output"):
        sim.run_iterative({"A": a, "B": b}, carry={"A": "Z"},
                          done_when_empty="Z",
                          var_shapes={"m": 8, "k": 8, "n": 8})


# ---------------------------------------------------------------------- #
# density models
# ---------------------------------------------------------------------- #
def test_calibrated_density_matches_structure(rng, spmat):
    a = spmat(rng, 20, 30, 0.2)
    ft = FTensor.from_dense("A", ["M", "K"], a)
    td = TensorDensity.calibrated(ft)
    rows = int((a != 0).any(axis=1).sum())
    nnz = int(np.count_nonzero(a))
    assert td.levels[0].elems == rows
    assert td.levels[1].elems == nnz
    assert td.nnz == nnz
    assert td.occ(1) == pytest.approx(nnz / rows)


def test_statistical_models_match_expectation():
    n, d = 64, 0.1
    tu = TensorDensity.uniform("A", ["M", "K"], [n, n], d)
    th = TensorDensity.hypergeometric("A", ["M", "K"], [n, n],
                                      n * n * d)
    for td in (tu, th):
        assert td.nnz == pytest.approx(n * n * d, rel=1e-6)
        # P(row nonempty) = 1 - (1-d)^n
        exp_rows = n * (1 - (1 - d) ** n)
        assert td.levels[0].elems == pytest.approx(exp_rows, rel=0.05)


def test_expected_distinct_properties():
    assert expected_distinct(100, 0) == 0
    assert expected_distinct(1, 50) == 1
    assert expected_distinct(100, 1) == pytest.approx(1.0)
    # monotone, saturating
    assert expected_distinct(100, 500) < 100
    assert expected_distinct(100, 500) > expected_distinct(100, 100)


def test_densities_hint_enables_data_free_evaluation():
    """With declared per-tensor densities the backend models a
    workload it was never given: true Sparseloop-style what-if."""
    from repro.core.mapping import MappingResolver
    spec = ZOO["rowwise-spmspm"]()
    plan = MappingResolver(spec).plan("Z")
    ci = CollectingInstr()
    ab = AnalyticBackend(mode="uniform",
                         densities={"A": 0.1, "B": 0.1}, fallback=False)
    out = ab.execute(plan, {}, {"m": 100, "k": 100, "n": 100}, instr=ci)
    assert out.nnz == 0
    muls = ci.compute_counts[("Z", "mul")]
    # E[muls] = M*K*N * dA * dB = 1e6 * 0.01 = 1e4
    assert muls == pytest.approx(1e4, rel=0.2)


def test_uniform_mode_backend_close_on_random(rng, spmat):
    """The pure-statistical mode (no tensor scan) should still land
    near the oracle on uniform random inputs."""
    M = K = N = 48
    a, b = spmat(rng, M, K, 0.2), spmat(rng, K, N, 0.2)
    spec = ZOO["rowwise-spmspm"]()
    shapes = {"m": M, "k": K, "n": N}
    ci_p, _ = _run(spec, {"A": a, "B": b}, shapes, "python")
    ci_a, _ = _run(spec, {"A": a, "B": b}, shapes,
                   AnalyticBackend(mode="uniform"))
    mul_p = sum(v for k, v in ci_p.compute_counts.items() if k[1] == "mul")
    mul_a = sum(v for k, v in ci_a.compute_counts.items() if k[1] == "mul")
    assert abs(mul_a - mul_p) <= 0.30 * mul_p
