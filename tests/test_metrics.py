"""Direct unit tests for core/metrics.py: evaluate()'s bottleneck and
energy accounting, and the RooflineTerms three-term model -- previously
only exercised indirectly through full accelerator runs."""
import pytest

from repro.core.components import PerformanceModel
from repro.core.mapping import MappingResolver
from repro.core.metrics import (ENERGY_TABLE_PJ, Report, RooflineTerms,
                                evaluate, roofline)
from repro.core.spec import load_spec


def _spec(clock_ghz=1.0, dram_gbs=10.0):
    return load_spec({
        "name": "Unit",
        "einsum": {
            "declaration": {"A": ["M", "K"], "B": ["K", "N"],
                            "Z": ["M", "N"]},
            "expressions": ["Z[m, n] = A[m, k] * B[k, n]"],
        },
        "mapping": {"loop-order": {"Z": ["M", "K", "N"]}},
        "format": {
            "A": {"CSR": {"M": {"format": "C", "cbits": 32, "pbits": 32},
                          "K": {"format": "C", "cbits": 32, "pbits": 64}}},
        },
        "architecture": {
            "clock_ghz": clock_ghz,
            "topologies": {"main": {
                "name": "chip", "num": 1,
                "local": [
                    {"name": "Mem", "class": "DRAM",
                     "bandwidth": dram_gbs},
                    {"name": "ALU", "class": "Compute", "type": "mul"},
                    {"name": "Acc", "class": "Compute", "type": "add"},
                    {"name": "Xint", "class": "Intersection",
                     "type": "two_finger"},
                ],
            }},
        },
        "binding": {"Z": {
            "topology": "main",
            "storage": [],
            "compute": [{"component": "ALU", "op": "mul"},
                        {"component": "Acc", "op": "add"}],
        }},
    })


def _model(spec):
    plans = {"Z": MappingResolver(spec).plan("Z")}
    return PerformanceModel(spec, plans), plans


def test_evaluate_energy_accounting_exact():
    spec = _spec()
    model, plans = _model(spec)
    model.begin_einsum("Z")
    model.compute("Z", "mul", n=100)
    model.compute("Z", "add", n=40)
    model.isect_step("Z", "K", "A", n=30)
    # A payload read at K: 64-bit payloads -> 8 bytes each, unbound ->
    # straight to DRAM
    model.touch("Z", "A", "K", (), "payload", "r", n=10)
    model.end_einsum("Z")
    rep = evaluate(spec, plans, model)

    assert rep.action_counts["mul"] == 100
    assert rep.action_counts["add"] == 40
    assert rep.action_counts["isect_step"] == 30
    assert rep.dram_bytes == pytest.approx(80.0)
    assert rep.energy_breakdown_pj["mul"] == \
        pytest.approx(100 * ENERGY_TABLE_PJ["mul"])
    assert rep.energy_breakdown_pj["add"] == \
        pytest.approx(40 * ENERGY_TABLE_PJ["add"])
    assert rep.energy_breakdown_pj["isect"] == \
        pytest.approx(30 * ENERGY_TABLE_PJ["isect_step"])
    assert rep.energy_breakdown_pj["dram"] == \
        pytest.approx(80.0 * ENERGY_TABLE_PJ["dram_per_byte"])
    assert rep.energy_pj == pytest.approx(sum(
        rep.energy_breakdown_pj.values()))


def test_evaluate_bottleneck_is_max_component():
    spec = _spec(clock_ghz=1.0, dram_gbs=10.0)
    model, plans = _model(spec)
    model.begin_einsum("Z")
    model.compute("Z", "mul", n=1000)      # ALU: 1000 cycles @ 1GHz = 1us
    # DRAM: 100 bytes / 10 GB/s = 10 ns << ALU
    model.touch("Z", "A", "K", (), "payload", "r", n=12)
    model.end_einsum("Z")
    rep = evaluate(spec, plans, model)
    assert len(rep.blocks) == 1
    blk = rep.blocks[0]
    assert blk.bottleneck == "ALU"
    assert blk.seconds == pytest.approx(1000 / 1e9)
    assert rep.seconds == pytest.approx(sum(b.seconds for b in rep.blocks))
    assert blk.component_seconds["Mem"] == \
        pytest.approx(96 / 10e9)


def test_evaluate_dram_bottleneck_when_bandwidth_starved():
    spec = _spec(clock_ghz=1.0, dram_gbs=0.000001)   # 1 KB/s
    model, plans = _model(spec)
    model.begin_einsum("Z")
    model.compute("Z", "mul", n=10)
    model.touch("Z", "A", "K", (), "payload", "r", n=100)
    model.end_einsum("Z")
    rep = evaluate(spec, plans, model)
    assert rep.blocks[0].bottleneck == "Mem"
    assert rep.seconds == pytest.approx(800 / 1e3)


def test_report_fields_and_summary():
    spec = _spec()
    model, plans = _model(spec)
    model.begin_einsum("Z")
    model.compute("Z", "mul", n=5)
    model.end_einsum("Z")
    rep = evaluate(spec, plans, model)
    assert isinstance(rep, Report)
    assert rep.design == "Unit"
    assert rep.fallback_reasons == {}
    assert "design=Unit" in rep.summary()
    assert rep.dram_bytes == rep.dram_read_bytes + rep.dram_write_bytes


# ---------------------------------------------------------------------- #
# RooflineTerms / roofline()
# ---------------------------------------------------------------------- #
def test_roofline_terms_dominant_and_seconds():
    t = RooflineTerms(compute_s=3.0, memory_s=1.0, collective_s=2.0)
    assert t.dominant == "compute"
    assert t.seconds == 3.0
    t = RooflineTerms(compute_s=0.1, memory_s=5.0, collective_s=2.0)
    assert t.dominant == "memory"
    assert t.seconds == 5.0


def test_roofline_math():
    t = roofline(flops=197e12, bytes_hbm=819e9, bytes_collective=0.0,
                 chips=1)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == 0.0
    # scaling out divides every term
    t2 = roofline(flops=197e12, bytes_hbm=819e9, bytes_collective=50e9,
                  chips=2)
    assert t2.compute_s == pytest.approx(0.5)
    assert t2.memory_s == pytest.approx(0.5)
    assert t2.collective_s == pytest.approx(0.5)
    assert t2.seconds == pytest.approx(0.5)
