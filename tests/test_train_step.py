"""Train-step semantics: gradient accumulation equivalence and the
seq-parallel flag's numerical neutrality."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.launch import steps as ST
from repro.models import api
from repro.optim import optimizers as opt


def _setup(arch="olmo-1b", batch=4, seq=32):
    cfg = C.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key)
    batch_data = api.make_batch(cfg, key, batch, seq)
    return cfg, params, batch_data


def test_grad_accumulation_matches_full_batch():
    """accum_steps=2 produces the same update as accum_steps=1 (grad of
    a token-mean loss is linear in the microbatch means)."""
    cfg, params, batch = _setup()
    optimizer = opt.adamw(1e-3)
    state = optimizer.init(params)

    s1 = ST.make_train_step(cfg, optimizer, accum_steps=1)
    s2 = ST.make_train_step(cfg, optimizer, accum_steps=2)

    p1, _, m1 = s1(params, state, batch)
    p2, _, m2 = s2(params, state, batch)

    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                              rel=1e-3)
    assert float(m1["grad_norm"]) == pytest.approx(
        float(m2["grad_norm"]), rel=2e-2)
    # Adam normalizes by sqrt(vhat): near-zero grads can flip update
    # sign under fp reassociation, so allow a tiny mismatch fraction
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    total = mismatched = 0
    for a, b in zip(l1, l2):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        bad = ~np.isclose(a, b, rtol=2e-2, atol=2e-3)
        mismatched += int(bad.sum())
        total += a.size
    assert mismatched / total < 5e-3, (mismatched, total)


def test_grad_accumulation_jits():
    cfg, params, batch = _setup(batch=4, seq=16)
    optimizer = opt.adamw(1e-3)
    state = optimizer.init(params)
    step = jax.jit(ST.make_train_step(cfg, optimizer, accum_steps=4))
    p, s, m = step(params, state, batch)
    assert bool(jnp.isfinite(m["loss"]))


def test_seq_parallel_flag_is_numerically_neutral():
    """seq_parallel only changes sharding constraints (no-ops on one
    device): identical loss with the flag on and off."""
    cfg, params, batch = _setup(arch="qwen3-14b")
    cfg_sp = dataclasses.replace(cfg, seq_parallel=True)
    l0 = api.loss_fn(cfg, params, batch)
    l1 = api.loss_fn(cfg_sp, params, batch)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)
