"""Per-kernel interpret-mode allclose sweeps against the ref.py
oracles (shapes x dtypes, as the brief requires)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_chunk import ssd_chunk


# ---------------------------------------------------------------------- #
# flash attention
# ---------------------------------------------------------------------- #
ATTN_SHAPES = [
    # (b, h, hkv, sq, sk, d)
    (1, 2, 2, 128, 128, 64),       # MHA square
    (2, 4, 2, 256, 256, 64),       # GQA 2:1
    (1, 8, 1, 128, 256, 32),       # MQA, sk > sq
    (2, 2, 2, 64, 192, 128),       # blocks > sq (clamped)
]


@pytest.mark.parametrize("b,h,hkv,sq,sk,d", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, hkv, sq, sk, d, dtype, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, h, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_attention_fully_masked_rows():
    """Non-causal with sk < block: ragged tail must not produce NaNs."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 40, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 40, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                          interpret=True)
    assert bool(jnp.all(jnp.isfinite(got)))


# ---------------------------------------------------------------------- #
# block-sparse matmul (SIGMA -> TPU adaptation)
# ---------------------------------------------------------------------- #
BSMM_SHAPES = [
    # (M, K, N, bm, bk, bn, tile_density)
    (128, 128, 128, 64, 64, 64, 0.5),
    (256, 128, 192, 64, 64, 64, 0.3),
    (256, 256, 64, 128, 128, 64, 0.2),
    (128, 256, 128, 64, 128, 128, 0.0),     # fully-empty A
]


@pytest.mark.parametrize("M,K,N,bm,bk,bn,density", BSMM_SHAPES)
@pytest.mark.parametrize("dtype", [np.float32])
def test_block_sparse_matmul_sweep(M, K, N, bm, bk, bn, density, dtype):
    rng = np.random.default_rng(2)
    a = rng.standard_normal((M, K)).astype(dtype)
    mask = rng.random((M // bm, K // bk)) < density
    a = a * np.kron(mask, np.ones((bm, bk), dtype))
    b = jnp.asarray(rng.standard_normal((K, N)), dtype)
    got = ops.block_sparse_matmul_dense_a(a, b, bm, bk, bn)
    want = ref.block_sparse_matmul_ref(jnp.asarray(a), b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


def test_compact_tiles_covers_all_rows():
    a = np.zeros((256, 128))
    a[130, 5] = 1.0                          # only tile-row 2 nonzero
    tiles, rows, cols = ops.compact_tiles(a, 64, 64)
    assert set(rows.tolist()) == {0, 1, 2, 3}  # every row covered
    # exactly one real tile + three zero pads
    assert sum(np.any(t != 0) for t in tiles) == 1


# ---------------------------------------------------------------------- #
# SSD intra-chunk kernel (Mamba2)
# ---------------------------------------------------------------------- #
SSD_SHAPES = [
    # (B, nc, l, H, P, N)
    (1, 2, 64, 2, 32, 16),
    (2, 3, 128, 4, 64, 32),
    (1, 1, 256, 8, 64, 128),     # the production chunk config
]


@pytest.mark.parametrize("B,nc,l,H,P,N", SSD_SHAPES)
def test_ssd_chunk_sweep(B, nc, l, H, P, N):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((B, nc, l, H, P)), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.standard_normal((B, H, nc, l)),
                             jnp.float32)) * 0.1
    b = jnp.asarray(rng.standard_normal((B, nc, l, N)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((B, nc, l, N)), jnp.float32)
    got = ssd_chunk(x, a, b, c, interpret=True)
    want = ref.ssd_chunk_ref(x, a, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_inside_model_path():
    """models.ssm.ssd(use_kernel=True) equals the pure-jnp cascade."""
    from repro.models.ssm import ssd
    rng = np.random.default_rng(4)
    B, S, H, P, N, chunk = 2, 128, 2, 32, 16, 64
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.standard_normal((B, S, H)),
                             jnp.float32)) * 0.1
    b = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y0, f0 = ssd(x, a, b, c, chunk, use_kernel=False)
    y1, f1 = ssd(x, a, b, c, chunk, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f0), np.asarray(f1),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------- #
# sorted-coordinate intersection (ExTensor skip-ahead -> TPU)
# ---------------------------------------------------------------------- #
ISECT_CASES = [
    # (n_a, n_b, overlap_frac, block)
    (100, 400, 0.5, 64),
    (1000, 1000, 0.1, 256),
    (64, 2048, 0.9, 64),
    (5, 7, 1.0, 32),
    (0, 100, 0.0, 32),          # empty A (all padding)
]


@pytest.mark.parametrize("na,nb,frac,block", ISECT_CASES)
def test_intersect_sorted_sweep(na, nb, frac, block):
    rng = np.random.default_rng(7)
    universe = rng.choice(10 * (na + nb) + 10, size=na + nb,
                          replace=False)
    b = np.sort(universe[:nb]).astype(np.int32)
    n_common = int(na * frac)
    a_vals = list(rng.choice(b, size=min(n_common, nb), replace=False)
                  ) if nb and n_common else []
    a_vals += list(universe[nb:nb + (na - len(a_vals))])
    a = np.sort(np.asarray(a_vals, np.int32)) if a_vals else \
        np.zeros((0,), np.int32)

    ap = ops.pad_sorted(a, block)
    bp = ops.pad_sorted(b, max(len(b), 8))
    got = np.asarray(ops.intersect_sorted(jnp.asarray(ap),
                                          jnp.asarray(bp), block=block))
    want = np.asarray(ref.intersect_sorted_ref(ap, bp))
    np.testing.assert_array_equal(got, want)
    # semantic check: every hit points at the right coordinate
    for i in range(len(a)):
        if got[i] >= 0:
            assert bp[got[i]] == ap[i]
        else:
            assert ap[i] not in b


def test_intersect_matches_fibertree_intersection():
    """The kernel computes the same coordinate set as the fibertree
    two-finger intersection (the simulator's semantic authority)."""
    from repro.core.fibertree import Fiber
    rng = np.random.default_rng(11)
    a_c = np.unique(rng.integers(0, 500, size=80)).astype(np.int32)
    b_c = np.unique(rng.integers(0, 500, size=120)).astype(np.int32)
    fa = Fiber(list(map(int, a_c)), [1.0] * len(a_c))
    fb = Fiber(list(map(int, b_c)), [1.0] * len(b_c))
    want = {c for c, _, _ in fa.intersect(fb)}

    ap = ops.pad_sorted(a_c, 64)
    bp = ops.pad_sorted(b_c, 64)
    idx = np.asarray(ops.intersect_sorted(jnp.asarray(ap),
                                          jnp.asarray(bp), block=64))
    got = {int(ap[i]) for i in range(len(a_c)) if idx[i] >= 0}
    assert got == want


# ---------------------------------------------------------------------- #
# k-ary multi-merge (UnionK) and the Lookup gather path
# ---------------------------------------------------------------------- #
def _rand_sorted(rng, n, hi):
    return np.sort(rng.choice(hi, size=n, replace=False)).astype(np.int64)


@pytest.mark.parametrize("k,sizes", [
    (3, (40, 60, 25)),
    (4, (100, 1, 50, 80)),
    (3, (0, 30, 30)),            # one empty operand
    (5, (8, 8, 8, 8, 8)),
])
def test_union_k_keys_matches_reference(k, sizes):
    rng = np.random.default_rng(13)
    arrays = [_rand_sorted(rng, n, 1000) for n in sizes]
    u, pos = ops.union_k_keys(arrays)
    want = np.unique(np.concatenate([a for a in arrays if len(a)]))
    np.testing.assert_array_equal(u, want)
    assert len(pos) == k
    for a, p in zip(arrays, pos):
        hit = p >= 0
        # every union element present in a points at its position
        np.testing.assert_array_equal(u[hit], a[p[hit]])
        np.testing.assert_array_equal(np.sort(p[hit]),
                                      np.arange(len(a)))
        assert not np.isin(u[~hit], a).any()


@pytest.mark.parametrize("k,n,block", [(3, 64, 32), (4, 100, 64),
                                       (2, 256, 128), (6, 33, 16)])
def test_multi_merge_ranks_interpret(k, n, block):
    """The Pallas k-way merge-rank kernel (interpret mode) agrees with
    the stable numpy merge."""
    rng = np.random.default_rng(17)
    rows = [np.sort(rng.choice(5000, size=rng.integers(1, n),
                               replace=False)).astype(np.int32)
            for _ in range(k)]
    n_pad = max(len(ops.pad_sorted(r, block)) for r in rows)
    stacked = np.stack([
        np.concatenate([r, np.full(n_pad - len(r),
                                   np.iinfo(np.int32).max, np.int32)])
        for r in rows])
    ranks = np.asarray(ops.multi_merge_ranks(jnp.asarray(stacked),
                                             block=block, interpret=True))
    total = sum(len(r) for r in rows)
    merged = np.empty(total, dtype=np.int64)
    for i, r in enumerate(rows):
        got = ranks[i, :len(r)]
        assert got.min() >= 0 and got.max() < total
        merged[got] = r
    # stable k-way merge == plain sort of the concatenation (ties are
    # value-equal, so stability only affects which copy lands where)
    np.testing.assert_array_equal(merged,
                                  np.sort(np.concatenate(rows)))


def test_lookup_keys_probe_path():
    rng = np.random.default_rng(19)
    hay = _rand_sorted(rng, 200, 10_000)
    probes = np.concatenate([rng.choice(hay, size=50),
                             rng.integers(0, 10_000, size=50)])
    rng.shuffle(probes)
    idx = ops.lookup_keys(hay, probes)
    for p, i in zip(probes, idx):
        if i >= 0:
            assert hay[i] == p
        else:
            assert p not in hay
    assert len(ops.lookup_keys(hay, np.zeros(0, dtype=np.int64))) == 0
    assert (ops.lookup_keys(np.zeros(0, dtype=np.int64), probes)
            == -1).all()


# ---------------------------------------------------------------------- #
# kernel-backend registry: parity of the four dispatch seams
# ---------------------------------------------------------------------- #
from repro.kernels import backends as kbk
from repro.core.einsum import Semiring

CPU_BACKENDS = ["numpy", "jax-jit", "pallas-interpret"]

#: adversarial key domains: dense duplicates-across-arrays, empty
#: arrays, sparse, and keys hugging the int32 / packed-int64 boundaries
_KEY_DOMAINS = [
    ("dense", 0, 500),
    ("empty", 0, 1),
    ("sparse", 0, 1 << 20),
    ("i32_boundary", np.iinfo(np.int32).max - 400,
     np.iinfo(np.int32).max),
    ("i64_packed", (1 << 62) - 2000, (1 << 62) - 1),
]


def _keys(rng, lo, hi, n):
    n = min(n, hi - lo)
    if n <= 0:
        return np.zeros(0, dtype=np.int64)
    return np.sort(rng.choice(np.arange(lo, hi, dtype=np.int64),
                              size=n, replace=False))


@pytest.mark.parametrize("name", CPU_BACKENDS)
@pytest.mark.parametrize("dom", _KEY_DOMAINS, ids=lambda d: d[0])
def test_registry_seam_parity(name, dom):
    """Every CPU kernel backend is bit-identical to the numpy oracle on
    all four dispatch seams, including empty and boundary domains."""
    _, lo, hi = dom
    rng = np.random.default_rng(11)
    ref_kb = kbk.resolve_kernel_backend("numpy")
    kb = kbk.resolve_kernel_backend(name)
    for trial in range(5):
        a = _keys(rng, lo, hi, int(rng.integers(0, 300)))
        b = _keys(rng, lo, hi, int(rng.integers(0, 300)))
        c = _keys(rng, lo, hi, int(rng.integers(0, 300)))
        np.testing.assert_array_equal(kb.intersect_keys(a, b),
                                      ref_kb.intersect_keys(a, b))
        u, pos = kb.union_k_keys([a, b, c])
        ur, posr = ref_kb.union_k_keys([a, b, c])
        np.testing.assert_array_equal(u, ur)
        for p, pr in zip(pos, posr):
            np.testing.assert_array_equal(p, pr)
        # duplicate-heavy probes (arbitrary order)
        probes = rng.choice(np.concatenate([a, [lo, hi - 1]]),
                            size=200) if len(a) else \
            np.zeros(0, dtype=np.int64)
        np.testing.assert_array_equal(kb.lookup_keys(a, probes),
                                      ref_kb.lookup_keys(a, probes))


@pytest.mark.parametrize("name", CPU_BACKENDS)
@pytest.mark.parametrize("sr", ["arithmetic", "min_plus", "or_and"],
                         ids=str)
def test_registry_segmented_reduce_parity(name, sr):
    rng = np.random.default_rng(13)
    kb = kbk.resolve_kernel_backend(name)
    ref_kb = kbk.resolve_kernel_backend("numpy")
    semiring = getattr(Semiring, sr)()
    for n in (0, 1, 7, 1000):
        vals = (rng.random(n) * 2 - 1 if sr != "or_and"
                else (rng.random(n) < 0.5).astype(np.float64))
        gids = np.sort(rng.integers(0, max(n // 3, 1), size=n))
        gids = np.cumsum(np.diff(gids, prepend=-1) > 0) - 1
        starts = np.flatnonzero(np.diff(gids, prepend=-1) > 0)
        got = kb.segmented_reduce(vals, starts, semiring, group_ids=gids)
        want = ref_kb.segmented_reduce(vals, starts, semiring,
                                       group_ids=gids)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shift", [-3, 0, 5, 10_000])
def test_shifted_seams_vs_numpy(shift):
    """lookup_keys_shifted / intersect_keys_shifted agree with a plain
    numpy model on duplicate-heavy, empty, and i32-boundary inputs,
    whatever kernel backend is active."""
    rng = np.random.default_rng(23)
    cases = [
        (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)),
        (_keys(rng, 0, 100, 60), _keys(rng, 0, 100, 60)),
        (_keys(rng, np.iinfo(np.int32).max - 300,
               np.iinfo(np.int32).max, 100),
         _keys(rng, np.iinfo(np.int32).max - 300,
               np.iinfo(np.int32).max, 100)),
    ]
    for hay, srt in cases:
        probes = (rng.choice(hay, size=150) if len(hay)
                  else np.zeros(0, dtype=np.int64))
        got = ops.lookup_keys_shifted(hay, probes, shift=shift)
        want = np.full(len(probes), -1, dtype=np.int64)
        for i, p in enumerate(probes):
            j = np.searchsorted(hay, p + shift)
            if (p + shift >= 0 and j < len(hay)
                    and hay[j] == p + shift):
                want[i] = j
        np.testing.assert_array_equal(got, want)

        got = ops.intersect_keys_shifted(srt, hay, shift=shift)
        want = np.full(len(srt), -1, dtype=np.int64)
        for i, p in enumerate(srt):
            j = np.searchsorted(hay, p + shift)
            if (p + shift >= 0 and j < len(hay)
                    and hay[j] == p + shift):
                want[i] = j
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("k,n_max", [(3, 40), (4, 200), (6, 90)])
def test_multi_merge_ranks_adversarial(k, n_max):
    """The k-way merge-rank kernel (interpret) against the numpy stable
    merge on duplicate-heavy rows (same keys in many rows), ragged
    lengths, and keys at the int32 boundary."""
    rng = np.random.default_rng(29)
    base = np.sort(rng.choice(120, size=30, replace=False))
    hi = np.iinfo(np.int32).max
    rows = []
    for i in range(k):
        if i % 3 == 0:        # duplicate-heavy: overlaps `base` a lot
            r = np.sort(rng.choice(base, size=min(len(base), n_max),
                                   replace=False))
        elif i % 3 == 1:      # i32-boundary keys
            r = np.sort(rng.choice(np.arange(hi - 500, hi - 1),
                                   size=rng.integers(1, n_max),
                                   replace=False))
        else:
            r = np.sort(rng.choice(5000, size=rng.integers(1, n_max),
                                   replace=False))
        rows.append(r.astype(np.int32))
    n_pad = max(int(np.ceil(max(len(r) for r in rows) / 64)) * 64, 64)
    stacked = np.stack([
        np.concatenate([r, np.full(n_pad - len(r), hi, np.int32)])
        for r in rows])
    ranks = np.asarray(ops.multi_merge_ranks(jnp.asarray(stacked),
                                             block=64, interpret=True))
    total = sum(len(r) for r in rows)
    merged = np.empty(total, dtype=np.int64)
    for i, r in enumerate(rows):
        got = ranks[i, :len(r)]
        assert got.min() >= 0 and got.max() < total
        merged[got] = r
    np.testing.assert_array_equal(
        merged, np.sort(np.concatenate(rows)))


def test_env_var_selects_backend(monkeypatch):
    monkeypatch.setenv(kbk.ENV_VAR, "jax-jit")
    assert kbk.resolve_kernel_backend().name == "jax-jit"
    monkeypatch.setenv(kbk.ENV_VAR, "pallas-interpret")
    assert kbk.resolve_kernel_backend().name == "pallas-interpret"
    monkeypatch.delenv(kbk.ENV_VAR)
    assert kbk.resolve_kernel_backend("numpy").name == "numpy"
    with pytest.raises(Exception):
        kbk.resolve_kernel_backend("no-such-backend")
