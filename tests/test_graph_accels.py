"""Vertex-centric accelerators (paper Sec. 8): functional correctness
vs scipy shortest-path oracles + the design-study ordering claims."""
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.accelerators import graphicionado as G
from repro.core.einsum import Semiring
from repro.core.generator import CascadeSimulator


def random_graph(rng, v=48, density=0.08, weighted=True):
    adj = (rng.random((v, v)) < density).astype(float)
    np.fill_diagonal(adj, 0.0)
    if weighted:
        adj = adj * rng.integers(1, 8, size=(v, v)).astype(float)
    return adj


def run_vcp(spec, adj, source=0, max_iters=64):
    v = adj.shape[0]
    a0 = np.zeros(v)
    a0[source] = 1.0
    p0 = np.zeros(v)
    p0[source] = 1.0                       # distance+1 encoding
    sim = CascadeSimulator(spec, semiring=Semiring.min_plus())
    res, iters = sim.run_iterative(
        {"G": adj, "A0": a0, "P0": p0},
        carry={"A0": "A1", "P0": "P1"},
        done_when_empty="A1", max_iters=max_iters,
        var_shapes={"d": v, "s": v})
    dist = np.full(v, np.inf)
    for (d,), val in res.tensors["P1"].iter_leaves():
        dist[d] = val - 1.0                # undo the +1 encoding
    return dist, iters, res.report


DESIGNS = [G.graphicionado_spec, G.graphdyns_spec, G.improved_spec]
IDS = ["graphicionado", "graphdyns", "ours"]


@pytest.mark.parametrize("make", DESIGNS, ids=IDS)
def test_sssp_matches_scipy(make, rng):
    adj = random_graph(rng, v=40, weighted=True)
    kwargs = {"n_vertices": 40} if make is G.graphdyns_spec else {}
    spec = make(weighted=True, **kwargs)
    dist, _, _ = run_vcp(spec, adj, source=0)
    # scipy: graph[i, j] = weight of edge i -> j; our G[d, s] is s -> d
    want = csgraph.dijkstra(sp.csr_matrix(adj.T), indices=0)
    assert np.allclose(dist, want)


@pytest.mark.parametrize("make", DESIGNS, ids=IDS)
def test_bfs_matches_scipy(make, rng):
    adj = random_graph(rng, v=40, weighted=False)
    kwargs = {"n_vertices": 40} if make is G.graphdyns_spec else {}
    spec = make(weighted=False, **kwargs)
    dist, _, _ = run_vcp(spec, adj, source=0)
    want = csgraph.shortest_path(sp.csr_matrix(adj.T), indices=0,
                                 unweighted=True)
    assert np.allclose(dist, want)


def grid_graph(side, extra=0, seed=0):
    """2D grid + a few shortcut edges: BFS frontier is O(sqrt(V)) --
    the sparse-active-set regime the paper's Sec.-8 study targets."""
    v = side * side
    adj = np.zeros((v, v))
    for i in range(side):
        for j in range(side):
            u = i * side + j
            if j + 1 < side:
                adj[u + 1, u] = 1          # G[d, s]: edge s -> d
            if i + 1 < side:
                adj[u + side, u] = 1
    rng = np.random.default_rng(seed)
    for _ in range(extra):
        s, d = rng.integers(0, v, 2)
        if s != d:
            adj[d, s] = 1
    return adj


def test_design_study_ordering():
    """The Sec.-8 ordering on a sparse-frontier graph: GraphDynS beats
    Graphicionado, ours beats GraphDynS (paper Fig. 13 direction)."""
    side = 16
    adj = grid_graph(side, extra=side)
    times = {}
    for make, name in zip(DESIGNS, IDS):
        kwargs = {"n_vertices": side * side} \
            if make is G.graphdyns_spec else {}
        spec = make(weighted=False, **kwargs)
        dist, _, report = run_vcp(spec, adj, max_iters=200)
        times[name] = report.seconds
    assert times["graphdyns"] < times["graphicionado"]
    assert times["ours"] < times["graphdyns"]
