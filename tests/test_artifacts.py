"""Dry-run artifact + roofline integrity: every runnable cell compiled
on both production meshes; skips are the documented long-context set;
roofline terms are finite and positive."""
import json
from pathlib import Path

import pytest

import repro.configs as C
from repro.configs.base import SHAPES
from repro.launch import roofline as R

ART = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

pytestmark = pytest.mark.skipif(
    not ART.exists() or not any(ART.glob("*.json")),
    reason="dry-run artifacts not generated yet "
           "(python -m repro.launch.dryrun --all --mesh both)")

FULL_ATTENTION = {"granite-20b", "qwen3-14b", "qwen2-7b", "olmo-1b",
                  "grok-1-314b", "qwen2-moe-a2.7b", "whisper-small",
                  "llava-next-34b"}


def _cells():
    for arch in C.ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("pod_16x16", "multipod_2x16x16"):
                yield arch, shape, mesh


def test_every_cell_has_an_artifact():
    missing = [c for c in _cells()
               if not (ART / f"{c[0]}__{c[1]}__{c[2]}.json").exists()]
    assert not missing, missing[:8]


def test_no_error_cells_and_correct_skips():
    for arch, shape, mesh in _cells():
        p = ART / f"{arch}__{shape}__{mesh}.json"
        if not p.exists():
            continue
        rec = json.loads(p.read_text())
        assert rec["status"] != "error", (arch, shape, mesh,
                                          rec.get("error", "")[:200])
        if shape == "long_500k" and arch in FULL_ATTENTION:
            assert rec["status"] == "skipped"
        elif rec["status"] == "skipped":
            pytest.fail(f"unexpected skip: {arch} {shape} {mesh}")


def test_ok_cells_have_cost_fields():
    n = 0
    for arch, shape, mesh in _cells():
        p = ART / f"{arch}__{shape}__{mesh}.json"
        if not p.exists():
            continue
        rec = json.loads(p.read_text())
        if rec["status"] != "ok":
            continue
        n += 1
        assert rec["flops"] > 0
        assert rec["hbm_bytes"] > 0
        assert rec["collective_wire_bytes"] >= 0
        assert "flops_corrected" in rec
        assert rec["chips"] in (256, 512)
        assert rec["collective_ops"], "no collectives parsed"
    assert n >= 30


def test_roofline_table_builds():
    cells = R.full_table("pod_16x16")
    ok = [c for c in cells if c.status == "ok"]
    if not ok:
        pytest.skip("no ok cells yet")
    for c in ok:
        assert c.compute_s > 0 and c.memory_s > 0
        assert c.dominant in ("compute", "memory", "collective")
        assert 0 < c.flops_ratio < 2.0, (c.arch, c.shape, c.flops_ratio)
        assert c.model_flops > 0
