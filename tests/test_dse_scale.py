"""Production-scale DSE: batched evaluation parity, result caching,
process-pool sharding, the sweep service, and search."""
import math
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.dse import (DesignPoint, DesignSpace, PointResult, ResultCache,
                       EvolutionarySearch, HalvingSearch, SweepEngine,
                       SweepService, ServiceClosed, pareto_front,
                       result_key, workload_hash)


def _workload(rng, n=48, d=0.15):
    a = rng.random((n, n)) * (rng.random((n, n)) < d)
    b = rng.random((n, n)) * (rng.random((n, n)) < d)
    return {"A": a, "B": b}, {"m": n, "k": n, "n": n}


def _space(values=(0.002, 0.01, 0.05, 0.25, 1.0, 3.0)):
    return DesignSpace("gamma", axes={"fibercache_mb": list(values)})


def _objectives(results):
    return [(r.label, r.seconds, r.energy_pj, r.dram_bytes)
            for r in results]


# ---------------------------------------------------------------------- #
# batched evaluation parity
# ---------------------------------------------------------------------- #
def test_batched_sweep_bitwise_identical_to_per_point(rng):
    """The tentpole invariant: grouped probe+replay evaluation returns
    the SAME bits as evaluating every point through the full backend."""
    inputs, shapes = _workload(rng)
    pts = _space().grid()
    batched = SweepEngine(inputs, shapes, backend="analytic").sweep(pts)
    scalar = SweepEngine(inputs, shapes, backend="analytic",
                         batch=False).sweep(pts)
    assert all(r.ok for r in batched + scalar)
    assert _objectives(batched) == _objectives(scalar)


def test_batched_sweep_amortizes_probe(rng):
    inputs, shapes = _workload(rng)
    pts = _space().grid()
    eng = SweepEngine(inputs, shapes, backend="analytic")
    results = eng.sweep(pts)
    assert all(r.ok for r in results)
    # one probe through the backend, every other point replayed
    assert eng.plan_cache_hits == len(pts) - 1


def test_batched_stat_misses_matches_scalar_bitwise():
    from repro.core.density import batched_stat_misses, stat_misses
    rng = np.random.default_rng(3)
    for _ in range(50):
        unique = float(rng.integers(0, 1000))
        n = unique + float(rng.integers(0, 1000))
        nbytes = float(rng.integers(1, 1 << 22))
        caps = np.array([float(rng.integers(1, 1 << 22))
                         for _ in range(8)])
        vec = batched_stat_misses(n, unique, nbytes, caps)
        for j, cap in enumerate(caps):
            assert vec[j] == stat_misses(n, unique, nbytes, float(cap))


def test_batched_group_key_separates_mappings(rng):
    """Points with different mapping params must not share a group's
    recorded stream (different plans -> different events)."""
    inputs, shapes = _workload(rng, n=24)
    pts = [DesignPoint.make("extensor",
                            params={"K0": k0, "K1": 256, "M1": 256,
                                    "M0": 64, "N1": 256, "N0": 64})
           for k0 in (32, 64)]
    batched = SweepEngine(inputs, shapes, backend="analytic").sweep(pts)
    scalar = SweepEngine(inputs, shapes, backend="analytic",
                         batch=False).sweep(pts)
    assert _objectives(batched) == _objectives(scalar)


# ---------------------------------------------------------------------- #
# result cache
# ---------------------------------------------------------------------- #
def test_result_cache_serves_repeat_sweeps(rng):
    inputs, shapes = _workload(rng)
    pts = _space().grid()
    cache = ResultCache()
    eng = SweepEngine(inputs, shapes, backend="analytic",
                      result_cache=cache)
    first = eng.sweep(pts)
    evaluated = eng.points_evaluated
    second = eng.sweep(pts)
    assert eng.points_evaluated == evaluated       # no backend work
    assert all(r.cached and r.status == "cached" for r in second)
    assert _objectives(first) == _objectives(second)
    assert eng.last_coverage["cached"] == len(pts)
    assert cache.stats()["hits"] == len(pts)
    assert f"{len(pts)} cached" in SweepEngine.summarize(second)


def test_result_cache_persistence_round_trip(rng, tmp_path):
    inputs, shapes = _workload(rng)
    pts = _space().grid()
    cache = ResultCache(directory=tmp_path / "rc")
    eng = SweepEngine(inputs, shapes, backend="analytic",
                      result_cache=cache)
    first = eng.sweep(pts)
    # sweep() flushed on exit; a second flush has nothing new
    assert not cache.flush()
    # a fresh process-equivalent: new cache object, same directory
    cache2 = ResultCache(directory=tmp_path / "rc")
    assert len(cache2) == len(pts)
    eng2 = SweepEngine(inputs, shapes, backend="analytic",
                       result_cache=cache2)
    again = eng2.sweep(pts)
    assert all(r.cached for r in again)
    assert _objectives(first) == _objectives(again)
    assert eng2.points_evaluated == 0


def test_result_cache_lru_eviction():
    c = ResultCache(capacity=2)
    c.put("a", 1, 1, 1)
    c.put("b", 2, 2, 2)
    assert c.get("a")["seconds"] == 1      # refresh a
    c.put("c", 3, 3, 3)                    # evicts b
    assert c.get("b") is None
    assert c.get("a") is not None and c.get("c") is not None


def test_result_cache_keys_are_content_addressed(rng):
    inputs, shapes = _workload(rng, n=16)
    wl = workload_hash(inputs, shapes)
    assert wl == workload_hash(dict(inputs), dict(shapes))
    inputs2 = {k: v.copy() for k, v in inputs.items()}
    inputs2["A"][0, 0] += 1.0
    assert wl != workload_hash(inputs2, shapes)
    p1 = DesignPoint.make("gamma", {"fibercache_mb": 1.0})
    p2 = DesignPoint.make("gamma", {"fibercache_mb": 1.0})
    p3 = DesignPoint.make("gamma", {"fibercache_mb": 2.0})
    k1 = result_key(wl, "sig", p1, "analytic", "calibrated")
    assert k1 == result_key(wl, "sig", p2, "analytic", "calibrated")
    assert k1 != result_key(wl, "sig", p3, "analytic", "calibrated")
    assert k1 != result_key(wl, "sig", p1, "python", "calibrated")


def test_result_cache_never_caches_failures(rng):
    inputs, shapes = _workload(rng, n=16)
    cache = ResultCache()
    eng = SweepEngine(inputs, shapes, result_cache=cache)
    res = eng.evaluate(DesignPoint.make("no-such-design"))
    assert not res.ok
    assert len(cache) == 0


# ---------------------------------------------------------------------- #
# process-pool sharded sweeps
# ---------------------------------------------------------------------- #
def test_process_sweep_bitwise_identical_to_serial(rng):
    inputs, shapes = _workload(rng)
    pts = _space().grid()
    serial = SweepEngine(inputs, shapes, backend="analytic").sweep(pts)
    sharded = SweepEngine(inputs, shapes, backend="analytic",
                          executor="process", max_workers=2).sweep(pts)
    assert all(r.ok for r in sharded), [r.error for r in sharded]
    assert _objectives(serial) == _objectives(sharded)


def test_process_sweep_worker_crash_checkpoint_resume(rng, tmp_path):
    """PR-8 contract across the worker boundary: a worker killed by an
    injected crash loses only its in-flight chunk; the parent persists
    completed points and a resumed sweep is bit-identical."""
    from repro.testing.faults import (FaultInjector, FaultSpec,
                                      SimulatedCrash, clear_injector,
                                      install_injector)
    inputs, shapes = _workload(rng)
    pts = _space().grid()
    truth = SweepEngine(inputs, shapes, backend="analytic").sweep(pts)
    truth_front = _objectives(pareto_front(truth))

    ckpt = tmp_path / "sweep"
    install_injector(FaultInjector(
        [FaultSpec(kind="crash", point=pts[3].label, at=1)]))
    try:
        eng1 = SweepEngine(inputs, shapes, backend="analytic",
                           executor="process", max_workers=2)
        with pytest.raises(SimulatedCrash):
            eng1.sweep(pts, checkpoint_dir=str(ckpt),
                       checkpoint_every=1)
    finally:
        clear_injector()
    assert (ckpt / "LATEST").exists()

    eng2 = SweepEngine(inputs, shapes, backend="analytic",
                       executor="process", max_workers=2)
    results = eng2.sweep(pts, checkpoint_dir=str(ckpt), resume=True)
    assert len(results) == len(pts)
    restored = [r for r in results if r.restored]
    assert restored and len(restored) < len(pts)
    cov = eng2.last_coverage
    assert cov["total"] == len(pts)
    assert cov["skipped"] == len(restored)
    assert cov["ok"] == len(pts)
    assert cov["evaluated"] == len(pts) - len(restored)
    assert _objectives(pareto_front(results)) == truth_front


def test_host_shard_partitions_exactly():
    from repro.launch.mesh import host_shard
    items = list(range(10))
    shards = [host_shard(items, process_index=i, process_count=3)
              for i in range(3)]
    assert [len(s) for s in shards] == [4, 3, 3]
    assert sum(shards, []) == items                # contiguous cover
    assert host_shard(items, process_index=0, process_count=1) == items
    with pytest.raises(ValueError):
        host_shard(items, process_index=3, process_count=3)


# ---------------------------------------------------------------------- #
# space.random properties
# ---------------------------------------------------------------------- #
def test_space_random_stable_across_processes():
    code = (
        "from repro.dse import DesignSpace\n"
        "s = DesignSpace('gamma', axes={\n"
        "    'fibercache_mb': [0.1 * i for i in range(1, 11)],\n"
        "    'merge_radix': [2, 4, 8, 16, 32, 64]})\n"
        "print([p.label for p in s.random(5, seed=7)])\n")
    outs = {
        subprocess.run([sys.executable, "-c", code], check=True,
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src",
                            "PYTHONHASHSEED": str(seed)}).stdout
        for seed in (0, 1)}
    assert len(outs) == 1                          # hash-seed invariant
    space = DesignSpace("gamma", axes={
        "fibercache_mb": [0.1 * i for i in range(1, 11)],
        "merge_radix": [2, 4, 8, 16, 32, 64]})
    assert str([p.label for p in space.random(5, seed=7)]) == \
        outs.pop().strip()


def test_space_random_collision_free_subset_of_grid():
    space = DesignSpace("gamma", axes={
        "fibercache_mb": [0.1 * i for i in range(1, 9)],
        "merge_radix": [2, 4, 8, 16]})
    grid_labels = {p.label for p in space.grid()}
    assert len(grid_labels) == space.size
    for n in (1, 5, 17, space.size):
        pts = space.random(n, seed=3)
        labels = [p.label for p in pts]
        assert len(labels) == len(set(labels)) == n
        assert set(labels) <= grid_labels
    # n beyond the space clamps instead of hanging
    assert len(space.random(10 * space.size, seed=0)) == space.size
    assert space.random(0) == []


# ---------------------------------------------------------------------- #
# pareto edge cases
# ---------------------------------------------------------------------- #
def _res(label, s, e=0.0, d=0.0, ok=True):
    if ok:
        return PointResult(point=DesignPoint.make(label), seconds=s,
                           energy_pj=e, dram_bytes=d)
    return PointResult(point=DesignPoint.make(label), error="boom")


def test_pareto_excludes_failed_results():
    rs = [_res("a", 1.0), _res("b", 0.0, ok=False), _res("c", 2.0)]
    front = pareto_front(rs, objectives=("seconds",))
    assert [r.label for r in front] == ["a"]


def test_pareto_all_failed_is_empty():
    rs = [_res("a", 0.0, ok=False), _res("b", 0.0, ok=False)]
    assert pareto_front(rs) == []


def test_pareto_ties_keep_first_duplicate_labels_tolerated():
    rs = [_res("a", 1.0, 2.0, 3.0), _res("a", 1.0, 2.0, 3.0),
          _res("b", 1.0, 2.0, 3.0)]
    front = pareto_front(rs)
    assert len(front) == 1 and front[0] is rs[0]


# ---------------------------------------------------------------------- #
# sweep service
# ---------------------------------------------------------------------- #
def test_service_round_trip_and_coalescing(rng):
    inputs, shapes = _workload(rng)
    pts = _space().grid()
    cache = ResultCache()
    eng = SweepEngine(inputs, shapes, backend="analytic",
                      result_cache=cache)
    with SweepService(eng, max_batch=32, batch_window_s=0.01) as svc:
        futs = [svc.submit(p) for p in pts]
        dups = [svc.submit(pts[0]) for _ in range(3)]
        res = [f.result(timeout=60) for f in futs]
        dup_res = [f.result(timeout=60) for f in dups]
        # repeats served from the result cache
        res2 = [svc.what_if(p, timeout=60) for p in pts]
        stats = svc.stats()
    assert all(r.ok for r in res + dup_res + res2)
    assert _objectives(res) == _objectives(res2)
    assert all(r.seconds == res[0].seconds for r in dup_res)
    assert stats["requests"] == 2 * len(pts) + 3
    assert stats["batches"] >= 1
    assert all(r.cached for r in res2)


def test_service_concurrent_clients_agree(rng):
    inputs, shapes = _workload(rng)
    pts = _space().grid()
    eng = SweepEngine(inputs, shapes, backend="analytic",
                      result_cache=ResultCache())
    seen = {}
    lock = threading.Lock()

    def client(cid, svc):
        import random as _random
        r = _random.Random(cid)
        for _ in range(8):
            res = svc.what_if(r.choice(pts), timeout=60)
            assert res.ok, res.error
            with lock:
                seen.setdefault(res.label, set()).add(
                    (res.seconds, res.energy_pj, res.dram_bytes))

    with SweepService(eng, max_batch=16) as svc:
        threads = [threading.Thread(target=client, args=(i, svc))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    # every client observed identical objectives per configuration
    assert seen and all(len(v) == 1 for v in seen.values())


def test_service_rejects_when_stopped(rng):
    inputs, shapes = _workload(rng, n=16)
    eng = SweepEngine(inputs, shapes, backend="analytic")
    svc = SweepService(eng)
    with pytest.raises(ServiceClosed):
        svc.submit(DesignPoint.make("gamma"))
    svc.start()
    svc.stop()
    with pytest.raises(ServiceClosed):
        svc.submit(DesignPoint.make("gamma"))


def test_service_point_failure_is_structured_not_fatal(rng):
    inputs, shapes = _workload(rng, n=16)
    eng = SweepEngine(inputs, shapes, backend="analytic")
    with SweepService(eng) as svc:
        bad = svc.what_if(DesignPoint.make("no-such-design"), timeout=60)
        good = svc.what_if(DesignPoint.make("gamma"), timeout=60)
    assert not bad.ok and "no-such-design" in bad.error
    assert good.ok


# ---------------------------------------------------------------------- #
# search
# ---------------------------------------------------------------------- #
def test_evolutionary_search_finds_grid_optimum(rng):
    inputs, shapes = _workload(rng)
    space = _space()
    eng = SweepEngine(inputs, shapes, backend="analytic",
                      result_cache=ResultCache())
    grid = eng.sweep(space.grid())
    best_traffic = min(r.dram_bytes for r in grid if r.ok)
    search = EvolutionarySearch(space, eng, population=4, generations=5,
                                elite=1, seed=0, objective="dram_bytes")
    out = search.run()
    assert out.best is not None and out.best_value == best_traffic
    assert out.evaluations == 4 * 5
    # monotone non-increasing incumbent trajectory
    inc = [min(out.trajectory[:i + 1]) for i in range(len(out.trajectory))]
    assert inc == sorted(inc, reverse=True)
    # cache exploited across generations: far fewer backend evals than
    # queries
    assert eng.points_evaluated < out.evaluations + len(grid)


def test_halving_search_promotes_across_fidelities(rng):
    inputs, shapes = _workload(rng)
    space = _space()
    lo = SweepEngine(inputs, shapes, backend="analytic", mode="uniform")
    hi = SweepEngine(inputs, shapes, backend="analytic")
    out = HalvingSearch(space, [lo, hi], n=6, eta=3, seed=0,
                        objective="dram_bytes").run()
    assert out.best is not None and out.best.ok
    assert math.isfinite(out.best_value)
    assert len(out.trajectory) == 2
    # rung sizes: 6 on the cheap engine, 2 promoted to the exact one
    assert out.evaluations == 6 + 2


def test_search_steers_around_failures(rng):
    inputs, shapes = _workload(rng, n=16)
    space = DesignSpace("no-such-design",
                        axes={"fibercache_mb": [0.1, 1.0]})
    eng = SweepEngine(inputs, shapes, backend="analytic")
    out = EvolutionarySearch(space, eng, population=2, generations=2,
                             elite=1, seed=0).run()
    assert out.best is None and out.best_value == math.inf
