"""Integration tests: the four validated accelerator models + the
Table-2 cascade zoo, executed on real sparse matrices and checked
against the dense oracle (paper Sec. 7 methodology at test scale)."""
import numpy as np
import pytest

from repro.accelerators import extensor, gamma, outerspace, sigma
from repro.accelerators.zoo import ZOO
from repro.core.einsum import parse_einsum, dense_reference
from repro.core.generator import CascadeSimulator, check_against_dense
from repro.core.cascade import fusion_blocks


ACCELS = [
    (outerspace, None),
    (extensor, "DEFAULT_PARAMS"),
    (gamma, None),
    (sigma, None),
]


@pytest.mark.parametrize("mod,params_attr", ACCELS,
                         ids=["outerspace", "extensor", "gamma", "sigma"])
def test_accelerator_matches_dense(mod, params_attr, rng, spmat):
    M = K = N = 48
    a, b = spmat(rng, M, K, 0.15), spmat(rng, K, N, 0.15)
    spec = mod.spec()
    params = getattr(mod, params_attr) if params_attr else None
    assert check_against_dense(spec, {"A": a, "B": b},
                               {"m": M, "k": K, "n": N}, params=params)


@pytest.mark.parametrize("mod,params_attr", ACCELS,
                         ids=["outerspace", "extensor", "gamma", "sigma"])
def test_accelerator_report_sane(mod, params_attr, rng, spmat):
    M = K = N = 32
    a, b = spmat(rng, M, K, 0.2), spmat(rng, K, N, 0.2)
    spec = mod.spec()
    params = getattr(mod, params_attr) if params_attr else None
    sim = CascadeSimulator(spec, params=params)
    res = sim.run({"A": a, "B": b}, {"m": M, "k": K, "n": N})
    r = res.report
    assert r.seconds > 0
    assert r.dram_bytes > 0
    assert r.energy_pj > 0
    # traffic must at least cover reading both operands once
    nnz = int(np.count_nonzero(a)) + int(np.count_nonzero(b))
    assert r.dram_bytes >= nnz * 4


def test_fusion_blocks_gamma_fused_outerspace_not(rng, spmat):
    """Sec. 4.3: Gamma's two Einsums fuse; OuterSPACE's phases do not
    (different topologies / spacetime prefixes)."""
    gsim = CascadeSimulator(gamma.spec())
    gblocks = fusion_blocks(gamma.spec(), gsim.plans)
    assert any(len(b) >= 2 for b in gblocks), gblocks

    osim = CascadeSimulator(outerspace.spec())
    oblocks = fusion_blocks(outerspace.spec(), osim.plans)
    assert all(len(b) == 1 for b in oblocks), oblocks


def test_outerspace_emits_merge_work(rng, spmat):
    """OuterSPACE's sort of the linked lists = online rank swizzle of
    the intermediate T -> Merger action counts must be nonzero."""
    a, b = spmat(rng, 32, 32, 0.2), spmat(rng, 32, 32, 0.2)
    sim = CascadeSimulator(outerspace.spec())
    res = sim.run({"A": a, "B": b}, {"m": 32, "k": 32, "n": 32})
    acts = res.report.action_counts
    assert acts.get("merge_elem", 0) > 0


# ---------------------------------------------------------------------- #
# Table 2 zoo: every cascade evaluates correctly against the oracle
# ---------------------------------------------------------------------- #
def _zoo_inputs(name, rng):
    if name in ("eyeriss-conv", "toeplitz-conv"):
        shapes = {"b": 2, "c": 3, "h": 6, "w": 6, "m": 4, "r": 3, "s": 3,
                  "p": 4, "q": 4}
        inputs = {
            "I": rng.random((2, 3, 6, 6)) * (rng.random((2, 3, 6, 6)) < .5),
            "F": rng.random((3, 4, 3, 3)),
        }
        return inputs, shapes
    if name in ("tensaurus-mttkrp", "factorized-mttkrp"):
        shapes = {"i": 5, "j": 4, "k": 3, "r": 6}
        inputs = {
            "T": rng.random((5, 4, 3)) * (rng.random((5, 4, 3)) < 0.4),
            "A": rng.random((3, 6)),
            "B": rng.random((4, 6)),
        }
        return inputs, shapes
    if name == "fft-step":
        shapes = {"u": 1, "k0": 4, "n1": 2, "v": 2}
        inputs = {
            "P": rng.random((1, 4, 2, 2)),
            "X": rng.random((2, 2)),
        }
        return inputs, shapes
    if name in ("rowwise-spmspm", "sparse-add"):
        shapes = {"m": 20, "k": 20, "n": 20}
        inputs = {
            "A": rng.random((20, 20)) * (rng.random((20, 20)) < 0.25),
            "B": rng.random((20, 20)) * (rng.random((20, 20)) < 0.25),
        }
        return inputs, shapes
    if name in ("elementwise-3way", "sparse-add-3way"):
        shapes = {"m": 20, "n": 20}
        inputs = {
            "A": rng.random((20, 20)) * (rng.random((20, 20)) < 0.3),
            "B": rng.random((20, 20)) * (rng.random((20, 20)) < 0.4),
            "C": rng.random((20, 20)) * (rng.random((20, 20)) < 0.3),
        }
        return inputs, shapes
    if name == "broadcast-outer":
        shapes = {"m": 20, "n": 6}
        inputs = {
            "A": rng.random(20) * (rng.random(20) < 0.5),
            "B": rng.random(20) * (rng.random(20) < 0.5),
        }
        return inputs, shapes
    raise KeyError(name)


@pytest.mark.parametrize("name", sorted(ZOO))
def test_zoo_cascade_matches_dense(name, rng):
    spec = ZOO[name]()
    inputs, shapes = _zoo_inputs(name, rng)
    sim = CascadeSimulator(spec, model=False)
    res = sim.run(dict(inputs), shapes)

    dense = {k: np.asarray(v) for k, v in inputs.items()}
    for e in spec.einsum.expressions:
        dense[e.output.tensor] = dense_reference(
            e, dense, {k.upper(): v for k, v in shapes.items()})
    for e in spec.einsum.expressions:
        out = e.output.tensor
        got = res.tensors[out].to_dense()
        want = dense[out]
        # stored rank order may differ from declaration
        decl = spec.einsum.declaration[out]
        order = spec.mapping.rank_order.get(out, decl)
        perm = [decl.index(r) for r in order]
        want = np.transpose(want, perm)
        got_pad = np.zeros(want.shape)
        slc = tuple(slice(0, s) for s in got.shape)
        got_pad[slc] = got
        assert np.allclose(got_pad, want), f"{name}:{out}"


def test_toeplitz_equals_direct_conv(rng):
    """Sec. 3.1: the Toeplitz cascade computes the same O as direct
    convolution -- the defining example of cascade equivalence."""
    direct = ZOO["eyeriss-conv"]()
    toep = ZOO["toeplitz-conv"]()
    inputs, shapes = _zoo_inputs("eyeriss-conv", rng)
    o1 = CascadeSimulator(direct, model=False).run(
        dict(inputs), shapes).tensors["O"].to_dense()
    o2 = CascadeSimulator(toep, model=False).run(
        dict(inputs), shapes).tensors["O"].to_dense()
    assert np.allclose(o1, o2)
