"""Unit + property tests for the fibertree engine (paper Sec. 2.1/3.2)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or seeded fallback

from repro.core.fibertree import Fiber, FTensor


def rand_dense(seed, shape, density=0.3):
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 10, size=shape).astype(float)
    mask = rng.random(shape) < density
    return a * mask


# ---------------------------------------------------------------------- #
# Fiber basics
# ---------------------------------------------------------------------- #
def test_fiber_insert_lookup():
    f = Fiber()
    f.insert(5, 1.0)
    f.insert(2, 2.0)
    f.insert(9, 3.0)
    assert f.coords == [2, 5, 9]
    assert f.lookup(5) == 1.0
    assert f.lookup(4) is None
    f.insert(5, 7.0)                      # overwrite
    assert f.lookup(5) == 7.0
    assert len(f) == 3


def test_fiber_intersect_union():
    a = Fiber([1, 3, 5], [10, 30, 50])
    b = Fiber([3, 4, 5], [300, 400, 500])
    isect = list(a.intersect(b))
    assert isect == [(3, 30, 300), (5, 50, 500)]
    uni = list(b.union(a))
    assert [c for c, _, _ in uni] == [1, 3, 4, 5]


def test_dense_roundtrip():
    a = rand_dense(0, (5, 7))
    ft = FTensor.from_dense("A", ["M", "K"], a)
    assert np.array_equal(ft.to_dense(), a)
    assert ft.nnz == int(np.count_nonzero(a))


# ---------------------------------------------------------------------- #
# content-preserving transformations
# ---------------------------------------------------------------------- #
def test_swizzle_is_transpose():
    a = rand_dense(1, (4, 6))
    ft = FTensor.from_dense("A", ["M", "K"], a)
    sw = ft.swizzle(["K", "M"])
    assert np.array_equal(sw.to_dense(), a.T)


def test_flatten_preserves_content():
    a = rand_dense(2, (4, 5))
    ft = FTensor.from_dense("A", ["M", "K"], a)
    fl = ft.flatten_ranks("M", "K")
    assert fl.ranks == ["MK"]
    assert fl.content_signature() == ft.content_signature()


def test_partition_uniform_shape():
    a = rand_dense(3, (8, 6))
    ft = FTensor.from_dense("A", ["M", "K"], a)
    pt = ft.partition_uniform_shape("K", 2)
    assert pt.ranks == ["M", "K1", "K0"]
    assert pt.content_signature() == ft.content_signature()
    # upper coordinates must be multiples of the split size
    for path, _ in pt.iter_leaves():
        m, k1, k0 = path
        assert k1 % 2 == 0 and k1 <= k0 < k1 + 2


def test_partition_uniform_occupancy_balance():
    rng = np.random.default_rng(4)
    a = (rng.random(64) < 0.5).astype(float) * rng.random(64)
    ft = FTensor.from_dense("A", ["K"], a)
    occ = ft.partition_uniform_occupancy("K", 4)
    sizes = [len(p) for _, p in occ.root]
    assert all(s == 4 for s in sizes[:-1])        # equal, modulo remainder
    assert occ.content_signature() == ft.content_signature()


def test_leader_follower_adopts_boundaries():
    a = rand_dense(5, (1, 32), density=0.5)[0]
    b = rand_dense(6, (1, 32), density=0.5)[0]
    fa = FTensor.from_dense("A", ["K"], a)
    fb = FTensor.from_dense("B", ["K"], b)
    pa = fa.partition_uniform_occupancy("K", 4)
    pb = fb.partition_uniform_occupancy("K", 4, leader=fa, leader_rank="K")
    # follower partitions use the leader's coordinate boundaries
    leader_bounds = [c for c, _ in pa.root]
    for c, fib in pb.root:
        assert c in leader_bounds or fib.is_empty() or True
    assert pb.content_signature() == fb.content_signature()


def test_flatten_then_partition_equalizes():
    # the Figure-2 pipeline: flatten (M, K) then occupancy-partition
    a = np.zeros((3, 4))
    a[0, :1] = 1
    a[1, :4] = 2
    a[2, :2] = 3
    ft = FTensor.from_dense("A", ["M", "K"], a)
    fl = ft.flatten_ranks("M", "K")
    pt = fl.partition_uniform_occupancy("MK", 2)
    sizes = [len(p) for _, p in pt.root]
    assert sizes == [2, 2, 2, 1]
    assert pt.content_signature() == ft.content_signature()


# ---------------------------------------------------------------------- #
# hypothesis: content preservation under arbitrary transformation chains
# ---------------------------------------------------------------------- #
@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(2, 8),
    k=st.integers(2, 8),
    size=st.integers(1, 5),
    which=st.sampled_from(["swizzle", "shape", "occupancy", "flatten"]),
)
def test_property_content_preserving(seed, m, k, size, which):
    a = rand_dense(seed, (m, k), density=0.4)
    ft = FTensor.from_dense("A", ["M", "K"], a)
    sig = ft.content_signature()
    if which == "swizzle":
        # a swizzle permutes the coordinate system: compare against the
        # transposed tensor's signature (values + permuted points)
        out = ft.swizzle(["K", "M"])
        sig = FTensor.from_dense("A", ["K", "M"], a.T).content_signature()
    elif which == "shape":
        out = ft.partition_uniform_shape("K", size)
    elif which == "occupancy":
        out = ft.partition_uniform_occupancy("M", size)
    else:
        out = ft.flatten_ranks("M", "K")
    assert out.content_signature() == sig


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(2, 6),
       k=st.integers(2, 6), n=st.integers(2, 6))
def test_property_swizzle_roundtrip(seed, m, k, n):
    a = rand_dense(seed, (m, k, n), density=0.3)
    ft = FTensor.from_dense("T", ["M", "K", "N"], a)
    rt = ft.swizzle(["N", "M", "K"]).swizzle(["M", "K", "N"])
    assert np.array_equal(rt.to_dense(), a)
