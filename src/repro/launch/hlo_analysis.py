"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` gives HLO FLOPs and HBM bytes but NOT collective
traffic; we parse the optimized HLO module text (``compiled.as_text()``)
and sum the result sizes of every collective op, with per-op wire-byte
multipliers (ring algorithms):

    all-reduce        2x result bytes   (reduce-scatter + all-gather)
    all-gather        1x result bytes   (each device receives ~result)
    reduce-scatter    gx result bytes   (input = g x output flows through)
    all-to-all        1x result bytes
    collective-permute 1x result bytes

Shapes in a partitioned module are per-device, so result bytes already
measure per-device traffic (within the (g-1)/g ring factor).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# one HLO instruction: "%name = <shape-or-tuple> op-name(...)"
_INSTR_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"
    r"((?:all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?)\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> float:
    """Bytes of one shape or tuple-of-shapes literal."""
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    ops: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    total_wire_bytes: float = 0.0

    def add(self, op: str, wire_bytes: float) -> None:
        self.ops[op] = self.ops.get(op, 0) + 1
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + wire_bytes
        self.total_wire_bytes += wire_bytes


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shape_text, op = m.group(1), m.group(2)
        base = op.replace("-start", "")
        result_bytes = _shape_bytes(shape_text)
        if op.endswith("-start") and base in ("all-reduce", "all-gather",
                                              "collective-permute"):
            # async start returns (operand, result) tuples: halve
            result_bytes /= 2.0

        gsize = _group_size(line)
        if base == "all-reduce":
            wire = 2.0 * result_bytes
        elif base == "reduce-scatter":
            wire = float(gsize or 1) * result_bytes
        else:
            wire = result_bytes
        stats.add(base, wire)
    return stats


def _group_size(line: str) -> Optional[int]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return None


# ---------------------------------------------------------------------- #
# cost-analysis extraction (robust across jax versions)
# ---------------------------------------------------------------------- #
def extract_costs(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    if bytes_accessed == 0.0:
        bytes_accessed = sum(float(v) for k, v in ca.items()
                             if k.startswith("bytes accessed"))
    return {"flops": flops, "bytes": bytes_accessed}


def extract_memory(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out
