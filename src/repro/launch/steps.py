"""train / prefill / serve step builders + ShapeDtypeStruct input specs.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input (no device allocation) -- the dry-run
lowers against these for all 40 (arch x shape) cells.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec
from repro.models import api
from repro.optim import optimizers as opt

Params = Any
SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------- #
# input specs (ShapeDtypeStructs; nothing is allocated)
# ---------------------------------------------------------------------- #
def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": SDS((b, s), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        out["patches"] = SDS((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["frames"] = SDS((b, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return out


def param_specs(cfg: ModelConfig) -> Params:
    return jax.eval_shape(
        lambda: api.init(cfg, jax.random.PRNGKey(0)))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return jax.eval_shape(lambda: api.init_cache(cfg, batch, max_len))


def opt_state_specs(cfg: ModelConfig, optimizer: opt.Optimizer) -> Params:
    p = param_specs(cfg)
    return jax.eval_shape(optimizer.init, p)


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                optimizer: Optional[opt.Optimizer] = None
                ) -> Dict[str, Any]:
    """All inputs of the step this shape lowers (train/prefill/decode)."""
    if shape.kind == "train":
        optimizer = optimizer or opt.for_config(cfg)
        return {
            "params": param_specs(cfg),
            "opt_state": opt_state_specs(cfg, optimizer),
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "prefill":
        return {
            "params": param_specs(cfg),
            "batch": batch_specs(cfg, shape),
        }
    # decode: one new token against a seq_len KV cache
    b = shape.global_batch
    return {
        "params": param_specs(cfg),
        "cache": cache_specs(cfg, b, shape.seq_len),
        "token": SDS((b,), jnp.int32),
        "pos": SDS((b,), jnp.int32),
    }


# ---------------------------------------------------------------------- #
# step functions
# ---------------------------------------------------------------------- #
def make_train_step(cfg: ModelConfig,
                    optimizer: Optional[opt.Optimizer] = None,
                    clip_norm: float = 1.0,
                    accum_steps: int = 1) -> Callable:
    """One optimizer step.

    ``accum_steps > 1`` splits the global batch into microbatches and
    accumulates gradients under a ``lax.scan`` (sequential, so only one
    microbatch's activations are live) -- the standard memory lever when
    the per-step activation footprint exceeds HBM.  Gradients are
    averaged, so the update is numerically the full-batch update (up to
    fp reassociation); verified by tests.
    """
    optimizer = optimizer or opt.for_config(cfg)

    def grads_of(params: Params, batch: Dict[str, jnp.ndarray]):
        return jax.value_and_grad(
            lambda p: api.loss_fn(cfg, p, batch))(params)

    def train_step(params: Params, opt_state: Params,
                   batch: Dict[str, jnp.ndarray]):
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps,
                                     x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                loss_acc, g_acc = carry
                l, g = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (loss_acc + l, g_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / accum_steps
            grads = jax.tree_util.tree_map(
                lambda g, p: (g / accum_steps).astype(p.dtype),
                grads, params)
        grads, gnorm = opt.clip_by_global_norm(grads, clip_norm)
        new_params, new_state = optimizer.update(params, grads, opt_state,
                                                 loss)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """Forward logits over the full prompt (inference prefill)."""

    def prefill_step(params: Params, batch: Dict[str, jnp.ndarray]):
        if cfg.family == "encdec":
            from repro.models import encdec
            return encdec.forward(cfg, params, batch["tokens"],
                                  batch["frames"])
        if cfg.family == "vlm":
            from repro.models import transformer
            return transformer.forward(cfg, params, batch["tokens"],
                                       extra_embeds=batch["patches"])
        if cfg.family in ("moe", "hybrid"):
            logits, _aux = api._mod(cfg).forward(cfg, params,
                                                 batch["tokens"])
            return logits
        return api._mod(cfg).forward(cfg, params, batch["tokens"])

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params: Params, cache: Params, token: jnp.ndarray,
                   pos: jnp.ndarray):
        return api.serve_step(cfg, params, cache, token, pos)

    return serve_step


def step_for(cfg: ModelConfig, shape: ShapeSpec,
             optimizer: Optional[opt.Optimizer] = None) -> Callable:
    if shape.kind == "train":
        return make_train_step(cfg, optimizer)
    if shape.kind == "prefill":
        return make_prefill_step(cfg)
    return make_serve_step(cfg)
