"""Concrete shardings for params / batches / caches on the production
mesh.

Parameter sharding policy (the compiled form of the TeAAL mapping's
spatial ranks, DESIGN.md):
  * TP: the last dimension divisible by the ``model`` axis size is
    sharded over ``model`` (matmul contracting/output dims);
  * FSDP/ZeRO: the largest *remaining* dimension divisible by the
    ``data`` axis size is sharded over ``data`` -- optimizer states
    inherit the param spec, so states are fully sharded too;
  * pods: parameters are replicated across the ``pod`` axis (pure DP
    between pods; gradient all-reduce over ``pod`` is the inter-pod
    collective the roofline's third term sees).

Divisibility-aware: dimensions that do not divide stay replicated
(e.g. granite's single KV head never shards over the 16-way model
axis).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import mesh_axis_sizes
from repro.sharding.logical import AxisRules

Params = Any


# ---------------------------------------------------------------------- #
# activation rules (TeAAL spacetime -> mesh axes)
# ---------------------------------------------------------------------- #
def train_rules() -> AxisRules:
    return AxisRules({
        "batch": ("pod", "data"),
        "seq": (),
        "embed": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_cap": ("data",),
        "expert_group": ("data",),
        "sp": ("model",),
        "kv_seq": ("model",),
        "state": (),
    })


def decode_rules() -> AxisRules:
    """Decode: the KV cache's sequence rank is the huge dimension --
    shard it over (data, model); batch over pod."""
    return AxisRules({
        "batch": ("pod", "data"),
        "seq": (),
        "embed": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_cap": ("data",),
        "expert_group": ("data",),
        "sp": ("model",),
        "kv_seq": ("data", "model"),
        "state": ("model",),
    })


def rules_for(kind: str) -> AxisRules:
    return decode_rules() if kind == "decode" else train_rules()


# ---------------------------------------------------------------------- #
# parameter shardings
# ---------------------------------------------------------------------- #
def param_pspec(shape: Tuple[int, ...], tp: int, dp: int,
                skip_leading: bool = True) -> P:
    """TP on the last divisible dim, FSDP on the largest remaining."""
    spec: list = [None] * len(shape)
    start = 1 if (skip_leading and len(shape) >= 3) else 0  # scan layer dim
    if tp > 1:
        for i in reversed(range(start, len(shape))):
            if shape[i] % tp == 0 and shape[i] >= tp:
                spec[i] = "model"
                break
    if dp > 1:
        cands = [i for i in range(len(shape))
                 if spec[i] is None and shape[i] % dp == 0
                 and shape[i] >= dp]
        if cands:
            i = max(cands, key=lambda j: shape[j])
            spec[i] = "data"
    return P(*spec)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        k = getattr(p, "key", None)
        if isinstance(k, str):
            names.append(k)
    return tuple(names)


def param_pspecs(params: Params, mesh: Mesh,
                 fsdp: bool = True) -> Params:
    """Path-aware parameter specs.

    The embedding table is the one tensor the generic heuristic gets
    wrong: it must be sharded on the VOCAB dim (so the tied lm-head
    contraction yields vocab-sharded logits without a reshard), not on
    d_model.  Everything else uses :func:`param_pspec`.

    ``fsdp=False`` (decode/serving): params are TP-sharded only and
    replicated across data -- there is no optimizer state to amortize,
    and FSDP would all-gather every parameter once per generated token
    (perf iteration 9, EXPERIMENTS.md SPerf).
    """
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get("model", 1)
    dp = sizes.get("data", 1) if fsdp else 1

    def spec(path, x):
        names = _path_names(path)
        leaf = names[-1] if names else ""
        shape = x.shape
        if leaf == "tok":                       # [vocab, d]
            return P("model" if tp > 1 and shape[0] % tp == 0 else None,
                     "data" if dp > 1 and shape[1] % dp == 0 else None)
        if leaf == "head":                      # [d, vocab]
            return P("data" if dp > 1 and shape[0] % dp == 0 else None,
                     "model" if tp > 1 and shape[1] % tp == 0 else None)
        if leaf in ("w_out", "wo"):
            # down-projections contract over the TP-sharded hidden
            # (ff / heads) dim: TP belongs on dim -2 (Megatron row
            # parallel -> local partial matmul + one all-reduce), NOT on
            # the output dim (which would force a full all-gather of
            # the ff-sharded activations first).  Perf iteration 1, see
            # EXPERIMENTS.md SPerf.
            spec: list = [None] * len(shape)
            if tp > 1 and shape[-2] % tp == 0:
                spec[-2] = "model"
            cands = [i for i in range(len(shape))
                     if spec[i] is None and shape[i] % dp == 0
                     and shape[i] >= dp]
            if dp > 1 and cands:
                spec[max(cands, key=lambda j: shape[j])] = "data"
            return P(*spec)
        return param_pspec(shape, tp, dp)

    return jax.tree_util.tree_map_with_path(spec, params)


def param_shardings(params: Params, mesh: Mesh) -> Params:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_pspecs(params, mesh))


# ---------------------------------------------------------------------- #
# batch / cache / token shardings
# ---------------------------------------------------------------------- #
def _dims_spec(shape: Tuple[int, ...], logical: Tuple[Optional[str], ...],
               mesh: Mesh, rules: AxisRules) -> P:
    sizes = mesh_axis_sizes(mesh)
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        axes = [a for a in rules.axes_for(name)
                if a in sizes and a not in used]
        keep, prod = [], 1
        for a in axes:
            if dim % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
        used.update(keep)
        parts.append(None if not keep
                     else keep[0] if len(keep) == 1 else tuple(keep))
    return P(*parts)


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
                 ) -> Dict[str, P]:
    rules = rules_for(shape.kind)
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": _dims_spec((b, s), ("batch", "seq"), mesh, rules),
        "labels": _dims_spec((b, s), ("batch", "seq"), mesh, rules),
    }
    if cfg.family == "vlm":
        out["patches"] = _dims_spec((b, cfg.n_patches, cfg.d_model),
                                    ("batch", "seq", "embed"), mesh, rules)
    if cfg.family == "encdec":
        out["frames"] = _dims_spec((b, cfg.enc_frames, cfg.d_model),
                                   ("batch", "seq", "embed"), mesh, rules)
    return out


def cache_pspecs(cfg: ModelConfig, batch: int, max_len: int, mesh: Mesh
                 ) -> Dict[str, P]:
    """PartitionSpec per decode-cache leaf, by family."""
    rules = decode_rules()
    from repro.models import api
    cache = jax.eval_shape(
        lambda: api.init_cache(cfg, batch, max_len))

    def leaf_spec(path: str, x) -> P:
        nd = len(x.shape)
        if path in ("k", "v"):                   # [L, b, s, kv, h]
            return _dims_spec(x.shape,
                              (None, "batch", "kv_seq", "kv_heads", None),
                              mesh, rules)
        if path in ("xk", "xv"):                 # cross-attn KV
            return _dims_spec(x.shape,
                              (None, "batch", "kv_seq", "kv_heads", None),
                              mesh, rules)
        if path == "ssm":                        # [L(,m), b, h, p, n]
            logical = (None,) * (nd - 4) + ("batch", "heads", None, None)
            return _dims_spec(x.shape, logical, mesh, rules)
        if path == "conv":                       # [L(,m), b, k-1, convdim]
            logical = (None,) * (nd - 3) + ("batch", None, "ff")
            return _dims_spec(x.shape, logical, mesh, rules)
        return P(*([None] * nd))

    return {k: leaf_spec(k, v) for k, v in cache.items()}


def token_pspec(batch: int, mesh: Mesh) -> P:
    return _dims_spec((batch,), ("batch",), mesh, decode_rules())
