"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json and derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs / (chips x 197e12)
    memory term     = HLO_bytes / (chips x 819e9)
    collective term = collective_wire_bytes / (chips x 50e9)

using the scan-corrected (probe-extrapolated) totals.  The JSON stores
PER-DEVICE partitioned-module numbers, so terms divide by chips=1 here
(each device's work against each device's peak) -- equivalent to the
global/chips form.  Also reports MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

import repro.configs as C
from repro.configs.base import (SHAPES, ModelConfig, active_param_count,
                                param_count)

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_global: float
    flops_ratio: float           # MODEL_FLOPS / HLO_FLOPs (global)
    status: str = "ok"
    note: str = ""

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_seconds(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute term / bottleneck term: 1.0 = compute-bound at peak."""
        t = self.step_seconds
        return self.compute_s / t if t > 0 else 0.0


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode D = global_batch
    tokens; train/prefill D = batch x seq tokens.  Train includes
    fwd+bwd (the 6 covers it); prefill/decode are fwd-only (2*N*D)."""
    shape = SHAPES[shape_name]
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # one token per request


def load_cell(arch: str, shape: str, mesh: str) -> Optional[Dict]:
    p = DRYRUN_DIR / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def cell_roofline(arch: str, shape: str, mesh: str
                  ) -> Optional[CellRoofline]:
    rec = load_cell(arch, shape, mesh)
    if rec is None:
        return None
    cfg = C.get(arch)
    if rec["status"] == "skipped":
        return CellRoofline(arch, shape, mesh, 0, 0, 0, 0, 0, 0, 0,
                            status="skipped",
                            note=rec.get("reason", ""))
    if rec["status"] != "ok":
        return CellRoofline(arch, shape, mesh, 0, 0, 0, 0, 0, 0, 0,
                            status="error", note=rec.get("error", ""))
    chips = rec["chips"]
    flops = rec.get("flops_corrected", rec["flops"])          # per device
    hbm = rec.get("hbm_bytes_corrected", rec["hbm_bytes"])
    coll = rec.get("collective_wire_bytes_corrected",
                   rec["collective_wire_bytes"])
    mf = model_flops(cfg, shape)
    hlo_global = flops * chips
    return CellRoofline(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=coll / LINK_BW,
        model_flops=mf,
        hlo_flops_global=hlo_global,
        flops_ratio=mf / hlo_global if hlo_global else 0.0,
    )


def full_table(mesh: str = "pod_16x16") -> List[CellRoofline]:
    out = []
    for arch in C.ARCH_IDS:
        for shape in SHAPES:
            cell = cell_roofline(arch, shape, mesh)
            if cell is not None:
                out.append(cell)
    return out


def format_table(cells: List[CellRoofline]) -> str:
    hdr = (f"{'arch':<22} {'shape':<12} {'compute_s':>10} {'memory_s':>10} "
           f"{'collect_s':>10} {'bound':>10} {'MODEL/HLO':>10} "
           f"{'roofline%':>10}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        if c.status == "skipped":
            lines.append(f"{c.arch:<22} {c.shape:<12} "
                         f"{'skip: ' + c.note[:58]}")
            continue
        if c.status == "error":
            lines.append(f"{c.arch:<22} {c.shape:<12} ERROR {c.note[:50]}")
            continue
        lines.append(
            f"{c.arch:<22} {c.shape:<12} {c.compute_s:>10.3e} "
            f"{c.memory_s:>10.3e} {c.collective_s:>10.3e} "
            f"{c.dominant:>10} {c.flops_ratio:>10.3f} "
            f"{100 * c.roofline_fraction:>9.1f}%")
    return "\n".join(lines)


def main() -> None:
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        cells = full_table(mesh)
        if not cells:
            continue
        print(f"\n=== roofline ({mesh}) ===")
        print(format_table(cells))


if __name__ == "__main__":
    main()
