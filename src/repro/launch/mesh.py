"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state -- the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, TypeVar

import jax
from jax.sharding import Mesh

_T = TypeVar("_T")


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(dp: int, tp: int, pods: int = 1) -> Mesh:
    """Arbitrary (pod) x data x model mesh (smoke tests use 1x1)."""
    if pods > 1:
        return jax.make_mesh((pods, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    """Axis name -> size; works for Mesh and AbstractMesh."""
    return dict(mesh.shape)


def n_chips(mesh) -> int:
    out = 1
    for s in mesh_axis_sizes(mesh).values():
        out *= s
    return out


def host_shard(items: Sequence[_T], *,
               process_index: Optional[int] = None,
               process_count: Optional[int] = None) -> List[_T]:
    """This host's contiguous shard of ``items`` in a multi-host run.

    Defaults to ``jax.process_index()`` / ``jax.process_count()``;
    pass both explicitly to shard without touching jax device state
    (e.g. in tests, or CPU-only sweep fleets coordinated outside jax).
    Shards are contiguous and cover ``items`` exactly: earlier hosts
    get the extra item when the split is uneven, and a single-process
    run returns the whole list.
    """
    if process_count is None:
        process_count = jax.process_count()
    if process_index is None:
        process_index = jax.process_index()
    if not 0 <= process_index < process_count:
        raise ValueError(
            f"process_index {process_index} outside [0, {process_count})")
    n = len(items)
    base, extra = divmod(n, process_count)
    start = process_index * base + min(process_index, extra)
    stop = start + base + (1 if process_index < extra else 0)
    return list(items[start:stop])
