"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state -- the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(dp: int, tp: int, pods: int = 1) -> Mesh:
    """Arbitrary (pod) x data x model mesh (smoke tests use 1x1)."""
    if pods > 1:
        return jax.make_mesh((pods, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    """Axis name -> size; works for Mesh and AbstractMesh."""
    return dict(mesh.shape)


def n_chips(mesh) -> int:
    out = 1
    for s in mesh_axis_sizes(mesh).values():
        out *= s
    return out
