"""Training launcher.

    python -m repro.launch.train --arch olmo-1b --smoke --steps 20

``--smoke`` uses the reduced same-family config on the local device
mesh; full configs are intended for real pods (or the dry-run).
"""
from __future__ import annotations

import argparse

import jax

import repro.configs as C
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    tcfg = TrainerConfig(total_steps=args.steps,
                         checkpoint_every=args.ckpt_every,
                         checkpoint_dir=args.ckpt_dir,
                         global_batch=args.batch, seq_len=args.seq,
                         accum_steps=args.accum)
    mesh = jax.make_mesh((args.dp, args.tp), ("data", "model"))
    trainer = Trainer(cfg, tcfg, mesh=mesh)
    state = trainer.run_with_recovery()
    print(f"finished at step {state.step}")
    for rec in trainer.metrics_log[-5:]:
        print(rec)


if __name__ == "__main__":
    main()
