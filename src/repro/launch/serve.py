"""Batched serving loop: continuous-batching decode driver.

    python -m repro.launch.serve --arch olmo-1b --smoke --requests 8

Implements slot-based continuous batching: a fixed decode batch of
``--batch`` slots; finished requests release their slot, queued
requests claim it (prefill-on-slot via teacher-forced cache warmup).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import api


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class Server:
    """Slot-based continuous batching on a fixed decode batch."""

    def __init__(self, cfg, batch: int = 4, max_len: int = 256):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.params = api.init(cfg, jax.random.PRNGKey(0))
        self.cache = api.init_cache(cfg, batch, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch
        self.pos = np.zeros(batch, np.int32)
        self.queue: List[Request] = []
        self._step = jax.jit(
            lambda p, c, t, q: api.serve_step(cfg, p, c, t, q))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------ #
    def _fill_slots(self) -> None:
        for i in range(self.batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                # prefill the slot by streaming prompt tokens (cache
                # warmup through the decode path keeps one compiled fn)
                self.pos[i] = 0
                for tok in req.prompt[:-1]:
                    self._advance_slot(i, tok)
                req._next = req.prompt[-1]

    def _advance_slot(self, i: int, tok: int) -> int:
        toks = np.zeros(self.batch, np.int32)
        toks[i] = tok
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.pos))
        self.pos[i] += 1
        return int(jnp.argmax(logits[i]))

    def step(self) -> None:
        """One fleet decode step for every active slot."""
        self._fill_slots()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros(self.batch, np.int32)
        for i in active:
            toks[i] = getattr(self.slot_req[i], "_next", 0)
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            req = self.slot_req[i]
            self.pos[i] += 1
            req.out.append(int(nxt[i]))
            req._next = int(nxt[i])
            if (len(req.out) >= req.max_new
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                self.slot_req[i] = None

    def drain(self) -> None:
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    server = Server(cfg, batch=args.batch)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12)
                              ).tolist()
        server.submit(Request(rid, prompt, args.max_new))
    server.drain()
    dt = time.time() - t0
    total = args.requests * args.max_new
    print(f"served {args.requests} requests, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
