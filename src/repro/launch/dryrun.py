import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks
# the device count on first init), so no `from __future__` here.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the step function for the shape's kind (train / prefill /
     decode) and its ShapeDtypeStruct input specs (no allocation),
  3. jit-lowers with explicit in/out shardings and compiles,
  4. records memory_analysis / cost_analysis / parsed collective bytes
     into experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.configs as C
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, cells
from repro.launch import hlo_analysis as H
from repro.launch import sharding as S
from repro.launch import steps
from repro.launch.mesh import make_production_mesh, n_chips
from repro.sharding import logical

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _tree_shardings(tree: Any, mesh: Mesh, spec_tree: Any = None):
    if spec_tree is None:
        return jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), tree)
    return jax.tree_util.tree_map(
        lambda _, s: NamedSharding(mesh, s), tree, spec_tree)


def shardings_for(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                  specs: Dict[str, Any]):
    """in_shardings matching ``steps.input_specs`` ordering."""
    # decode serves from TP-sharded, data-replicated weights (no
    # optimizer to co-locate; FSDP would re-gather params every token)
    ps = S.param_pspecs(specs["params"], mesh,
                        fsdp=(shape.kind != "decode"))
    p_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), ps)
    if shape.kind == "train":
        o_shard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            S.param_pspecs(specs["opt_state"], mesh))
        b_p = S.batch_pspecs(cfg, shape, mesh)
        b_shard = {k: NamedSharding(mesh, b_p[k]) for k in specs["batch"]}
        return (p_shard, o_shard, b_shard)
    if shape.kind == "prefill":
        b_p = S.batch_pspecs(cfg, shape, mesh)
        b_shard = {k: NamedSharding(mesh, b_p[k]) for k in specs["batch"]}
        return (p_shard, b_shard)
    c_p = S.cache_pspecs(cfg, shape.global_batch, shape.seq_len, mesh)
    c_shard = {k: NamedSharding(mesh, c_p[k]) for k in specs["cache"]}
    t_shard = NamedSharding(mesh, S.token_pspec(shape.global_batch, mesh))
    return (p_shard, c_shard, t_shard, t_shard)


def probe_config(cfg: ModelConfig, k: int) -> ModelConfig:
    """Same arch with k layer-units, UNROLLED (scan off).

    XLA's cost analysis counts while-loop bodies once (verified:
    scan(8 matmuls) reports 1 matmul), so per-layer costs are
    calibrated from unrolled 1- and 2-unit compiles and extrapolated
    linearly -- exact, because total cost is affine in the unit count.
    A 'unit' is a layer (dense/moe/ssm), a superblock (hybrid), or an
    encoder+decoder layer pair (encdec, where enc_layers==n_layers).
    """
    import dataclasses
    kw: Dict[str, Any] = {"scan_layers": False}
    if cfg.family == "hybrid":
        kw["n_layers"] = k * cfg.hybrid_block
    else:
        kw["n_layers"] = k
    if cfg.family == "encdec":
        kw["enc_layers"] = k
    return dataclasses.replace(cfg, **kw)


def n_units(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid_block
    return cfg.n_layers


def _lower_compile(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    specs = steps.input_specs(cfg, shape)
    step = steps.step_for(cfg, shape)
    in_shardings = shardings_for(cfg, shape, mesh, specs)
    args = {
        "train": ("params", "opt_state", "batch"),
        "prefill": ("params", "batch"),
        "decode": ("params", "cache", "token", "pos"),
    }[shape.kind]
    arg_specs = [specs[a] for a in args]
    with mesh:
        jitted = jax.jit(step, in_shardings=in_shardings)
        lowered = jitted.lower(*arg_specs)
        compiled = lowered.compile()
    return lowered, compiled


def _cell_costs(compiled) -> Dict[str, float]:
    costs = H.extract_costs(compiled)
    coll = H.parse_collectives(compiled.as_text())
    return {"flops": costs["flops"], "hbm_bytes": costs["bytes"],
            "collective_wire_bytes": coll.total_wire_bytes,
            "_coll": coll}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, verbose: bool = True,
             probes: bool = True) -> Dict[str, Any]:
    cfg = C.get(arch)
    shape = SHAPES[shape_name]
    if (shape.kind == "prefill" and cfg.family in ("dense", "vlm")
            and cfg.d_model >= 3500):
        # Megatron-style sequence parallelism: -25% collective wire on
        # prefill for wide models (perf iteration 12); train is left off
        # (remat x SP measured +39% HBM) and narrow models are left off
        # (olmo-1b measured +47% collective: the per-layer AG/RS pair
        # costs more than the saved all-reduce below ~2.5k width).
        import dataclasses
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"

    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped",
               "reason": "pure full-attention arch: 500k dense-KV decode "
                         "is quadratic with no sparsity mechanism "
                         "(DESIGN.md Arch-applicability)"}
        if save:
            _save(rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)

    logical.set_mesh(mesh)
    logical.set_rules(S.rules_for(shape.kind))
    t0 = time.time()
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "chips": n_chips(mesh),
                           "kind": shape.kind}
    try:
        lowered, compiled = _lower_compile(cfg, shape, mesh)
        t_compile = time.time() - t0

        memory = H.extract_memory(compiled)
        full = _cell_costs(compiled)
        coll = full.pop("_coll")
        rec.update({
            "status": "ok",
            "compile_s": round(t_compile, 2),
            "collective_ops": coll.ops,
            "collective_bytes_by_op": coll.bytes_by_op,
            "memory_analysis": memory,
            **full,
        })

        # scan-aware calibration: unrolled 1- and 2-unit probes; total
        # cost is affine in unit count, so corrected = p1 + (U-1)(p2-p1)
        if probes:
            t1 = time.time()
            p1 = _cell_costs(_lower_compile(probe_config(cfg, 1), shape,
                                            mesh)[1])
            p2 = _cell_costs(_lower_compile(probe_config(cfg, 2), shape,
                                            mesh)[1])
            U = n_units(cfg)
            for key in ("flops", "hbm_bytes", "collective_wire_bytes"):
                rec[key + "_corrected"] = (
                    p1[key] + (U - 1) * (p2[key] - p1[key]))
            rec["probe_s"] = round(time.time() - t1, 2)
        if verbose:
            fc = rec.get("flops_corrected", rec["flops"])
            hc = rec.get("hbm_bytes_corrected", rec["hbm_bytes"])
            cc = rec.get("collective_wire_bytes_corrected",
                         rec["collective_wire_bytes"])
            print(f"[ok] {arch} x {shape_name} x {mesh_name}: "
                  f"flops={fc:.3e} hbm={hc:.3e}B coll={cc:.3e}B "
                  f"(compile {t_compile:.1f}s probes "
                  f"{rec.get('probe_s', 0)}s)")
            print("  memory_analysis:", memory)
            print("  collectives:", coll.ops)
    except Exception as ex:
        rec.update({"status": "error", "error": f"{type(ex).__name__}: "
                    f"{ex}"[:2000]})
        if verbose:
            print(f"[ERR] {arch} x {shape_name} x {mesh_name}: {ex}")
            traceback.print_exc()
    finally:
        logical.set_mesh(None)
        logical.set_rules(None)

    if save:
        _save(rec)
    return rec


def _save(rec: Dict[str, Any]) -> None:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    (RESULTS_DIR / name).write_text(json.dumps(rec, indent=2))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--archs", type=str, default=None,
                    help="comma-separated arch subset (with --all)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.all:
        archs = (args.archs.split(",") if args.archs else C.ARCH_IDS)
        pairs = [(a, s) for a in archs
                 for s in list(SHAPES)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for arch, shape in pairs:
        for mp in meshes:
            rec = run_cell(arch, shape, mp)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skipped"
            n_err += rec["status"] == "error"
    print(f"\ndry-run summary: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
