"""Logical axis names -> mesh axes, with divisibility-aware fallback.

Model code annotates activations with *logical* axis names
(``constrain(x, ("batch", "seq", "ff"))``); the active rule set maps
each name to one or more mesh axes.  A mapping is applied only when the
dimension is divisible by the mesh-axis product, so e.g. granite's
single KV head silently stays replicated instead of failing to shard
over the 16-way model axis.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclass
class AxisRules:
    rules: Dict[str, Tuple[str, ...]] = field(default_factory=dict)

    def axes_for(self, name: Optional[str]) -> Tuple[str, ...]:
        if name is None:
            return ()
        return self.rules.get(name, ())


def default_rules() -> AxisRules:
    """The production mapping: batch over (pod, data); width over model.

    This is the compiled form of the TeAAL ``spacetime`` spec in
    ``repro.sharding.compiler.mapping_spec_for_step`` -- spatial ranks
    bind to mesh axes, temporal ranks stay local.
    """
    return AxisRules({
        "batch": ("pod", "data"),
        "seq": (),
        "embed": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_cap": ("data",),
        "expert_group": ("data",),
        "sp": ("model",),
        "kv_seq": ("data",),          # long-context decode: shard the cache
        "state": (),
    })


def set_rules(rules: Optional[AxisRules]) -> None:
    _STATE.rules = rules


def get_rules() -> AxisRules:
    return getattr(_STATE, "rules", None) or default_rules()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _STATE.mesh = mesh


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def spec_for(shape: Sequence[int],
             logical: Sequence[Optional[str]],
             mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for ``shape`` under the active rules; axes that do
    not divide are dropped (replicated)."""
    mesh = mesh or current_mesh()
    rules = get_rules()
    if mesh is None:
        return P(*([None] * len(logical)))
    sizes = dict(mesh.shape)
    used = set()
    parts = []
    for dim, name in zip(shape, logical):
        axes = [a for a in rules.axes_for(name)
                if a in sizes and a not in used]
        keep = []
        prod = 1
        for a in axes:
            if dim % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
        used.update(keep)
        if not keep:
            parts.append(None)
        elif len(keep) == 1:
            parts.append(keep[0])
        else:
            parts.append(tuple(keep))
    return P(*parts)


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint under the active mesh; no-op without one."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"logical axes {logical} vs shape {x.shape}")
    spec = spec_for(x.shape, logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
