"""TeAAL mapping -> jax.sharding.PartitionSpec compiler.

This is the bridge that makes the paper's mapping language a
first-class feature of the distributed runtime: a TeAAL ``spacetime``
spec schedules loop ranks in *space*; on a TPU pod the spatial axes are
the mesh axes (pod, data, model).  ``compile_mapping`` turns a mapped
Einsum into per-tensor PartitionSpecs:

  * a rank whose partitioned *upper* level is scheduled in space is
    sharded on the mesh axis bound to that spatial rank;
  * ranks scheduled only in time stay local (sequential on-device).

``mapping_spec_for_step`` writes down the production mapping of one
transformer FFN/attention step as a TeAAL cascade, so the same language
describes both the sparse-accelerator models and the LM fleet sharding.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

from jax.sharding import PartitionSpec as P

from repro.core.mapping import MappingResolver
from repro.core.spec import AcceleratorSpec, load_spec

AxisBinding = Dict[str, Union[str, Tuple[str, ...]]]


def compile_mapping(spec: AcceleratorSpec, out_name: str,
                    axis_binding: AxisBinding,
                    params: Optional[Dict[str, int]] = None
                    ) -> Dict[str, P]:
    """PartitionSpec per tensor of one mapped Einsum.

    ``axis_binding`` maps spatial rank names (e.g. 'B1', 'F1') to mesh
    axis names.  Every spatial rank must be bound; temporal ranks are
    ignored (local).
    """
    resolver = MappingResolver(spec, params)
    plan = resolver.plan(out_name)
    space = set(plan.space_ranks)
    unbound = space - set(axis_binding)
    if unbound:
        raise ValueError(f"spatial ranks {sorted(unbound)} have no mesh "
                         f"axis binding")

    decl = spec.einsum.declaration
    out: Dict[str, P] = {}
    for t, tp in plan.tensors.items():
        declared = spec.mapping.rank_order.get(t) or decl[t]
        parts = []
        for r in declared:
            axis = None
            for sr in plan.space_ranks:
                # spatial rank 'B1' shards declared rank 'B'
                base = sr.rstrip("0123456789")
                if base == r:
                    axis = axis_binding[sr]
                    break
            parts.append(axis)
        out[t] = P(*parts)
    return out


def mapping_spec_for_step(dp: int = 16, tp: int = 16,
                          pods: int = 1) -> AcceleratorSpec:
    """The production LM-step mapping as a TeAAL cascade.

    Two mapped Einsums stand in for the step's two matmul classes:
      H[b, f] = X[b, d] * Wi[d, f]     (up-projection: activations x W1)
      Y[b, d] = H[b, f] * Wo[f, d]     (down-projection)

    B is partitioned across (pod x data) and scheduled in space; F
    across model.  D (the contraction of the first Einsum / output of
    the second) stays temporal -- its reduction is the all-reduce XLA
    inserts, exactly the collective the roofline's third term measures.
    """
    b_ways = dp * pods
    return load_spec({
        "name": "lm-step-mapping",
        "einsum": {
            "declaration": {
                "X": ["B", "D"], "Wi": ["D", "F"], "H": ["B", "F"],
                "Wo": ["F", "D"], "Y": ["B", "D"],
            },
            "expressions": [
                "H[b, f] = X[b, d] * Wi[d, f]",
                "Y[b, d] = H[b, f] * Wo[f, d]",
            ],
        },
        "mapping": {
            "rank-order": {"X": ["B", "D"], "Wi": ["D", "F"],
                           "H": ["B", "F"], "Wo": ["F", "D"],
                           "Y": ["B", "D"]},
            "partitioning": {
                "H": {"B": [f"uniform_shape(B0S)"],
                      "F": [f"uniform_shape(F0S)"]},
                "Y": {"B": [f"uniform_shape(B0S)"],
                      "F": [f"uniform_shape(F0S)"]},
            },
            "loop-order": {
                "H": ["B1", "F1", "B0", "F0", "D"],
                "Y": ["B1", "F1", "B0", "D", "F0"],
            },
            "spacetime": {
                "H": {"space": ["B1", "F1"], "time": ["B0", "F0", "D"]},
                "Y": {"space": ["B1", "F1"], "time": ["B0", "D", "F0"]},
            },
        },
    })


def step_partition_specs(global_batch: int, d_model: int, d_ff: int,
                         dp: int = 16, tp: int = 16, pods: int = 1
                         ) -> Dict[str, P]:
    """Compile the production step mapping for concrete sizes."""
    spec = mapping_spec_for_step(dp, tp, pods)
    binding: AxisBinding = {
        "B1": ("pod", "data") if pods > 1 else "data",
        "F1": "model",
    }
    params = {"B0S": max(1, global_batch // (dp * pods)),
              "F0S": max(1, d_ff // tp)}
    return compile_mapping(spec, "H", binding, params)
