"""Sharding: TeAAL-mapping-driven PartitionSpec compilation + logical axes."""
from .logical import (AxisRules, constrain, current_mesh, default_rules,
                      set_mesh, set_rules, spec_for)
from .compiler import compile_mapping, mapping_spec_for_step

__all__ = ["AxisRules", "constrain", "current_mesh", "default_rules",
           "set_mesh", "set_rules", "spec_for", "compile_mapping",
           "mapping_spec_for_step"]
