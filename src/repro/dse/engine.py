"""The sweep engine: evaluate design points through any backend.

One engine instance owns one workload (the input tensors + var shapes)
and amortizes everything that is shared across sweep points:

  * **plan lowering** -- memoized on ``cascade.mapping_signature``, so
    points that only change architecture attributes (cache capacity,
    merger radix, bandwidth) reuse the lowered ``EinsumPlan``s;
  * **density calibration** (analytic backend) -- the one-pass tensor
    scans are cached per (workload, mapping-signature, tensor, exec
    order) and shared across points *and* threads, so an
    arch-attribute sweep transforms + scans the workload exactly once
    and every later point is closed-form evaluation only.

Evaluation defaults to the analytic backend; pass ``backend='vector'``
or ``'python'`` for execution-based fidelity at sweep cost.
"""
from __future__ import annotations

import itertools
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cascade import mapping_signature
from repro.core.generator import CascadeSimulator
from repro.core.mapping import EinsumPlan
from repro.core.metrics import Report

from .space import DesignPoint

_token_counter = itertools.count()


@dataclass
class PointResult:
    """Modeled objectives of one evaluated design point."""
    point: DesignPoint
    seconds: float = float("nan")
    energy_pj: float = float("nan")
    dram_bytes: float = float("nan")
    wall_seconds: float = 0.0
    fallback_reasons: Dict[str, str] = field(default_factory=dict)
    report: Optional[Report] = None
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def label(self) -> str:
        return self.point.label

    def row(self) -> str:
        if not self.ok:
            return f"{self.label}: FAILED ({self.error})"
        return (f"{self.label}: time={self.seconds:.3e}s "
                f"traffic={self.dram_bytes / 1e3:.1f}KB "
                f"energy={self.energy_pj / 1e6:.2f}uJ")


class SweepEngine:
    """Evaluates ``DesignPoint``s on one fixed workload."""

    def __init__(self, inputs: Dict[str, Any],
                 var_shapes: Dict[str, int],
                 backend: str = "analytic",
                 mode: str = "calibrated",
                 keep_reports: bool = False,
                 max_workers: Optional[int] = None):
        self.inputs = dict(inputs)
        self.var_shapes = dict(var_shapes)
        self.backend = backend
        self.mode = mode
        self.keep_reports = keep_reports
        self.max_workers = max_workers
        # shared caches (see module docstring)
        self._plan_cache: Dict[str, Dict[str, EinsumPlan]] = {}
        self._calib_cache: Dict[Tuple, Any] = {}
        self._workload_token = f"wl{next(_token_counter)}"
        # simple stats for tests / benchmarks
        self.plan_cache_hits = 0
        self.points_evaluated = 0

    # ------------------------------------------------------------------ #
    def _backend_for(self, token: str):
        if self.backend != "analytic":
            return self.backend
        from repro.core.analytic import AnalyticBackend
        # one instance per evaluation (per-cascade predicted-stats are
        # stateful) sharing the engine-wide calibration cache
        return AnalyticBackend(mode=self.mode,
                               calib_cache=self._calib_cache,
                               cache_token=token)

    def evaluate(self, point: DesignPoint) -> PointResult:
        t0 = time.perf_counter()
        try:
            spec = point.build_spec()
            params = point.default_params()
            sig = mapping_signature(spec, params)
            plans = self._plan_cache.get(sig)
            if plans is not None:
                self.plan_cache_hits += 1
            token = f"{self._workload_token}|{hash(sig):x}"
            sim = CascadeSimulator(spec, params=params,
                                   backend=self._backend_for(token),
                                   plans=plans)
            if plans is None:
                self._plan_cache[sig] = sim.plans
            res = sim.run(dict(self.inputs), self.var_shapes)
            rep = res.report
            self.points_evaluated += 1
            return PointResult(
                point=point,
                seconds=rep.seconds,
                energy_pj=rep.energy_pj,
                dram_bytes=rep.dram_bytes,
                wall_seconds=time.perf_counter() - t0,
                fallback_reasons=dict(res.fallback_reasons),
                report=rep if self.keep_reports else None)
        except Exception as exc:                      # noqa: BLE001
            return PointResult(point=point,
                               wall_seconds=time.perf_counter() - t0,
                               error=f"{type(exc).__name__}: {exc}")

    # ------------------------------------------------------------------ #
    def sweep(self, points: Sequence[DesignPoint],
              warm: bool = True) -> List[PointResult]:
        """Evaluate every point, preserving input order.

        With ``max_workers > 1`` evaluation is threaded; the first
        point is evaluated up front (``warm``) so the shared plan /
        calibration caches are populated before the fan-out."""
        points = list(points)
        if not points:
            return []
        workers = self.max_workers or 1
        if workers <= 1 or len(points) == 1:
            return [self.evaluate(p) for p in points]
        head = [self.evaluate(points[0])] if warm else []
        rest = points[1:] if warm else points
        with ThreadPoolExecutor(max_workers=workers) as pool:
            tail = list(pool.map(self.evaluate, rest))
        return head + tail
