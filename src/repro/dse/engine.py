"""The sweep engine: evaluate design points through any backend.

One engine instance owns one workload (the input tensors + var shapes)
and amortizes everything that is shared across sweep points:

  * **plan lowering** -- memoized on ``cascade.mapping_signature``, so
    points that only change architecture attributes (cache capacity,
    merger radix, bandwidth) reuse the lowered ``EinsumPlan``s;
  * **density calibration** (analytic backend) -- the one-pass tensor
    scans are cached per (workload, mapping-signature, tensor, exec
    order) and shared across points *and* threads, so an
    arch-attribute sweep transforms + scans the workload exactly once
    and every later point is closed-form evaluation only;
  * **input conversion** (analytic backend) -- the dense->fibertree
    transform of the workload operands is cached per stored rank
    order and shared read-only across every point;
  * **batched group evaluation** (analytic backend, ``batch=True``) --
    points are partitioned on ``(mapping_signature, isect_configs)``;
    within a group the backend's instrumentation event stream is a
    pure function of the workload and the lowered plans (architecture
    attributes enter only at stream *consumption* time), so the first
    point of a group (the probe) records its stream once
    (``trace.RecordingInstr``) and every other member replays it into
    its own ``PerformanceModel`` -- bit-identical per-point results at
    a fraction of the per-point cost.  The capacity-dependent
    statistical-residency closed form is precomputed across the whole
    point axis in one numpy pass (``density.batched_stat_misses``) and
    served to each replay through ``components.stat_miss_feed``;
  * **result cache** (optional ``result_cache``) -- previously
    evaluated (workload x point x backend x mode) queries are served
    from ``dse.cache.ResultCache`` without touching the backend.

Evaluation defaults to the analytic backend; pass ``backend='vector'``
or ``'python'`` for execution-based fidelity at sweep cost.

Sweeps run serially (batched), threaded (``executor='thread'``,
execution backends) or sharded over a process pool
(``executor='process'``): point chunks are shipped to worker processes
that each run their own batched engine, sidestepping the GIL.  The
fault-tolerance contract survives the worker boundary: per-point
timeouts / retries apply inside the worker, fault injectors are
re-installed in every worker, a ``SimulatedCrash`` in a worker still
tears the sweep down after a final checkpoint save, and crash->resume
stays bit-identical.
"""
from __future__ import annotations

import itertools
import math
import time
import traceback as _tb
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout
from concurrent.futures import wait as _fut_wait
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

import numpy as np

from repro.core.cascade import mapping_signature
from repro.core.components import PerformanceModel, stat_miss_feed
from repro.core.density import batched_stat_misses
from repro.core.generator import CascadeSimulator, isect_configs
from repro.core.mapping import EinsumPlan
from repro.core.metrics import Report
from repro.core.metrics import evaluate as _evaluate_report
from repro.core.trace import RecordingInstr

from .space import DesignPoint

_token_counter = itertools.count()

#: objective fields checkpointed per point (alphabetical: jax flattens
#: dict pytrees in sorted-key order, so save and restore agree)
_CKPT_FIELDS = ("dram_bytes", "energy_pj", "seconds", "wall_seconds")


def _active_injector():
    try:
        from repro.testing.faults import active_injector
    except ImportError:
        return None
    return active_injector()


def _trim_traceback(exc: BaseException, limit: int = 600) -> str:
    """The exception line plus the innermost two frames -- enough to
    locate a sweep failure without shipping whole tracebacks around."""
    lines = _tb.format_exception(type(exc), exc, exc.__traceback__)
    return "".join(lines[:1] + lines[-3:])[-limit:]


@dataclass
class PointResult:
    """Modeled objectives of one evaluated design point."""
    point: DesignPoint
    seconds: float = float("nan")
    energy_pj: float = float("nan")
    dram_bytes: float = float("nan")
    wall_seconds: float = 0.0
    fallback_reasons: Dict[str, str] = field(default_factory=dict)
    report: Optional[Report] = None
    #: "ExcType: message" on failure (None when the point evaluated)
    error: Optional[str] = None
    #: the exception class name alone (machine-matchable)
    error_type: Optional[str] = None
    #: trimmed traceback (exception line + innermost frames)
    traceback: Optional[str] = None
    #: the point exceeded the engine's per-point wall-clock budget
    timed_out: bool = False
    #: evaluation attempts consumed (> 1 after retries)
    attempts: int = 1
    #: objectives restored from a sweep checkpoint, not re-evaluated
    restored: bool = False
    #: objectives served from the result cache, not re-evaluated
    cached: bool = False

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def status(self) -> str:
        if self.ok:
            if self.restored:
                return "restored"
            return "cached" if self.cached else "ok"
        return "timeout" if self.timed_out else "failed"

    @property
    def label(self) -> str:
        return self.point.label

    def row(self) -> str:
        if not self.ok:
            tag = "TIMEOUT" if self.timed_out else "FAILED"
            tries = f" attempts={self.attempts}" if self.attempts > 1 \
                else ""
            return f"{self.label}: {tag} ({self.error}){tries}"
        return (f"{self.label}: time={self.seconds:.3e}s "
                f"traffic={self.dram_bytes / 1e3:.1f}KB "
                f"energy={self.energy_pj / 1e6:.2f}uJ")


# ---------------------------------------------------------------------- #
# batched-evaluation plumbing
# ---------------------------------------------------------------------- #
@dataclass
class _Prep:
    """Per-point lowering shared by the batched paths: everything a
    point needs before any backend work."""
    point: DesignPoint
    spec: Any
    params: Optional[Dict[str, int]]
    sig: str
    group_key: Tuple


class _CaptureFeed:
    """Probe-side feed: records the ``touch_stat`` consumption sequence
    (level, nbytes, n, unique) and always stands down (returns None),
    so the probe computes its misses through the scalar closed form --
    probe results are untouched by capturing."""

    def __init__(self):
        self.calls: List[Tuple[Any, float, int, int]] = []

    def take(self, level, nbytes, n, unique):
        self.calls.append((level, float(nbytes), int(n), int(unique)))
        return None


class _ReplayFeed:
    """Replay-side feed: serves one point's lane of the pre-vectorized
    miss values, validating every call against the recorded occurrence
    (args + this point's capacity for the same level key).  Any
    mismatch permanently stands the feed down -- the scalar closed form
    takes over, so feeding can reroute work but never change results
    (``batched_stat_misses`` is bit-identical to ``stat_misses``
    elementwise)."""

    def __init__(self, occurrences, values, caps):
        self.occurrences = occurrences    # [(lvl_key, nbytes, n, unique)]
        self.values = values              # this point's lane, same length
        self.caps = caps                  # lvl_key -> this point's capacity
        self.i = 0
        self.dead = False

    def reset(self) -> None:
        self.i = 0
        self.dead = False

    def take(self, level, nbytes, n, unique):
        if self.dead or self.i >= len(self.occurrences):
            self.dead = True
            return None
        key, e_nbytes, e_n, e_unique = self.occurrences[self.i]
        cap = self.caps.get(key)
        if (cap is None or cap != level.capacity_bytes
                or e_nbytes != float(nbytes) or e_n != int(n)
                or e_unique != int(unique)):
            self.dead = True
            return None
        v = self.values[self.i]
        self.i += 1
        return v


# ---------------------------------------------------------------------- #
# process-pool worker plumbing (module level: must be picklable)
# ---------------------------------------------------------------------- #
_WORKER_ENGINE: Optional["SweepEngine"] = None


def _pool_init(inputs, var_shapes, engine_kw, fault_payload) -> None:
    """Per-process initializer: one engine singleton per worker, and
    the parent's fault injector re-installed so the fault contract
    survives the process boundary under fork AND spawn."""
    global _WORKER_ENGINE
    if fault_payload is not None:
        from repro.testing.faults import FaultInjector, install_injector
        specs, seed = fault_payload
        install_injector(FaultInjector(list(specs), seed=seed))
    _WORKER_ENGINE = SweepEngine(inputs, var_shapes, **engine_kw)


def _pool_run(points: List[DesignPoint]) -> List[Dict[str, Any]]:
    """Evaluate one chunk in the worker's engine (batched, serial,
    full per-point fault policy).  A ``SimulatedCrash`` propagates to
    the parent -- the chunk's partial results are dropped, preserving
    the either-completed-or-pending contract."""
    assert _WORKER_ENGINE is not None
    results = _WORKER_ENGINE.sweep(points)
    return [_pack_result(r) for r in results]


def _pack_result(r: PointResult) -> Dict[str, Any]:
    return {
        "label": r.label, "seconds": r.seconds, "energy_pj": r.energy_pj,
        "dram_bytes": r.dram_bytes, "wall_seconds": r.wall_seconds,
        "fallback_reasons": dict(r.fallback_reasons), "error": r.error,
        "error_type": r.error_type, "traceback": r.traceback,
        "timed_out": r.timed_out, "attempts": r.attempts,
    }


def _unpack_result(row: Dict[str, Any], point: DesignPoint) -> PointResult:
    return PointResult(
        point=point, seconds=row["seconds"], energy_pj=row["energy_pj"],
        dram_bytes=row["dram_bytes"], wall_seconds=row["wall_seconds"],
        fallback_reasons=dict(row["fallback_reasons"]),
        error=row["error"], error_type=row["error_type"],
        traceback=row["traceback"], timed_out=row["timed_out"],
        attempts=row["attempts"])


class SweepEngine:
    """Evaluates ``DesignPoint``s on one fixed workload."""

    def __init__(self, inputs: Dict[str, Any],
                 var_shapes: Dict[str, int],
                 backend: str = "analytic",
                 mode: str = "calibrated",
                 keep_reports: bool = False,
                 max_workers: Optional[int] = None,
                 point_timeout_s: Optional[float] = None,
                 point_retries: int = 0,
                 retry_backoff_s: float = 0.0,
                 batch: bool = True,
                 executor: str = "thread",
                 result_cache: Optional[Any] = None,
                 multi_host: bool = False):
        self.inputs = dict(inputs)
        self.var_shapes = dict(var_shapes)
        self.backend = backend
        self.mode = mode
        self.keep_reports = keep_reports
        self.max_workers = max_workers
        #: per-point wall-clock budget; a point past it is recorded as
        #: timed out and the sweep proceeds (None = unbounded)
        self.point_timeout_s = point_timeout_s
        #: bounded re-evaluations of a failed / timed-out point
        self.point_retries = point_retries
        self.retry_backoff_s = retry_backoff_s
        #: group points by (mapping signature, intersection config) and
        #: evaluate each group probe-then-replay (analytic backend only)
        self.batch = batch
        if executor not in ("thread", "process"):
            raise ValueError(f"executor must be 'thread' or 'process', "
                             f"got {executor!r}")
        #: 'thread' (shared caches, GIL-bound) or 'process' (sharded
        #: chunks over a process pool, true parallelism)
        self.executor = executor
        #: optional dse.cache.ResultCache serving repeat queries
        self.result_cache = result_cache
        #: shard sweeps across jax hosts (each host evaluates its
        #: contiguous slice of the points; see launch.mesh.host_shard)
        self.multi_host = multi_host
        # shared caches (see module docstring)
        self._plan_cache: Dict[str, Dict[str, EinsumPlan]] = {}
        self._calib_cache: Dict[Tuple, Any] = {}
        self._conv_cache: Dict[Tuple, Any] = {}
        self._sig_cache: Dict[DesignPoint, str] = {}
        self._workload_token = f"wl{next(_token_counter)}"
        self._workload_id: Optional[str] = None
        # simple stats for tests / benchmarks
        self.plan_cache_hits = 0
        self.points_evaluated = 0
        #: coverage tallies of the most recent sweep() call
        self.last_coverage: Dict[str, int] = {}

    # ------------------------------------------------------------------ #
    @property
    def workload_id(self) -> str:
        """Content hash of the workload (cache-key component)."""
        if self._workload_id is None:
            from .cache import workload_hash
            self._workload_id = workload_hash(self.inputs, self.var_shapes)
        return self._workload_id

    def _backend_for(self, token: str):
        if self.backend != "analytic":
            return self.backend
        from repro.core.analytic import AnalyticBackend
        # one instance per evaluation (per-cascade predicted-stats are
        # stateful) sharing the engine-wide calibration cache
        return AnalyticBackend(mode=self.mode,
                               calib_cache=self._calib_cache,
                               cache_token=token)

    def _sim_inputs(self, sim: CascadeSimulator) -> Dict[str, Any]:
        """The workload operands, pre-converted to fibertrees and
        cached per stored rank order (analytic backend: the transform
        dominates single-point cost).  Shared read-only across points;
        execution backends keep per-run conversion."""
        if self.backend != "analytic":
            return dict(self.inputs)
        out: Dict[str, Any] = {}
        for name, val in self.inputs.items():
            try:
                ranks = tuple(
                    sim.spec.mapping.rank_order.get(name)
                    or sim.spec.einsum.declaration[name])
            except Exception:               # noqa: BLE001 - let sim cope
                return dict(self.inputs)
            key = (name, ranks)
            ft = self._conv_cache.get(key)
            if ft is None:
                ft = sim._to_ftensor(name, val)
                self._conv_cache[key] = ft
            out[name] = ft
        return out

    def prime(self, point: DesignPoint, calibrate: bool = True) -> None:
        """Pre-pay ``point``'s one-time setup costs (idempotent): the
        dense->fibertree operand conversion and -- for the analytic
        backend, when ``calibrate`` -- the plan lowering and the
        workload-calibration scan, via one throwaway evaluation.
        Benchmarks and long-lived services call this at setup so the
        first timed evaluation runs at steady-state cost.  The
        throwaway run does not touch result caches, point counters, or
        sweep coverage."""
        spec = point.build_spec()
        params = point.default_params()
        sim = CascadeSimulator(spec, params=params, model=False,
                               backend=None)
        self._sim_inputs(sim)
        if not calibrate or self.backend != "analytic":
            return
        sig = mapping_signature(spec, params)
        self._sig_cache[point] = sig
        plans = self._plan_cache.get(sig)
        token = f"{self._workload_token}|{hash(sig):x}"
        sim = CascadeSimulator(spec, params=params,
                               backend=self._backend_for(token),
                               plans=plans)
        if plans is None:
            self._plan_cache[sig] = sim.plans
        sim.run(self._sim_inputs(sim), self.var_shapes)

    # ------------------------------------------------------------------ #
    # result cache
    # ------------------------------------------------------------------ #
    def _cache_key(self, point: DesignPoint) -> Optional[str]:
        if self.result_cache is None:
            return None
        sig = self._sig_cache.get(point)
        if sig is None:
            try:
                sig = mapping_signature(point.build_spec(),
                                        point.default_params())
            except Exception:               # noqa: BLE001
                return None                  # broken point: evaluate it
            self._sig_cache[point] = sig
        from .cache import result_key
        backend = self.backend if isinstance(self.backend, str) else \
            getattr(self.backend, "name", type(self.backend).__name__)
        return result_key(self.workload_id, sig, point, backend, self.mode)

    def _cache_get(self, point: DesignPoint) -> Optional[PointResult]:
        key = self._cache_key(point)
        if key is None:
            return None
        t0 = time.perf_counter()
        hit = self.result_cache.get(key)
        if hit is None:
            return None
        return PointResult(point=point, cached=True,
                           wall_seconds=time.perf_counter() - t0, **hit)

    def _cache_put(self, point: DesignPoint, res: PointResult) -> None:
        if self.result_cache is None or not res.ok \
                or res.cached or res.restored:
            return
        key = self._cache_key(point)
        if key is not None:
            self.result_cache.put(key, res.seconds, res.energy_pj,
                                  res.dram_bytes)

    # ------------------------------------------------------------------ #
    def evaluate(self, point: DesignPoint) -> PointResult:
        """Evaluate one point: result-cache lookup first, then the
        engine's full fault policy (see :meth:`_guarded`)."""
        hit = self._cache_get(point)
        if hit is not None:
            return hit
        res = self._guarded(point, lambda: self._evaluate_once(point))
        self._cache_put(point, res)
        return res

    def _guarded(self, point: DesignPoint,
                 once: Callable[[], PointResult]) -> PointResult:
        """One point under the engine's fault policy: per-point
        wall-clock timeout, then up to ``point_retries`` bounded
        re-attempts with backoff.  Never raises for a point failure --
        the error lands structured on the result (class name, message,
        trimmed traceback).  ``SimulatedCrash`` (a BaseException) is
        deliberately not absorbed: it models the whole process dying.

        Telemetry: one ``point:<label>`` span per evaluation (status /
        attempts / error in the span args) and unconditional
        ``dse.point/<status>`` + ``dse.point_attempts`` counters, so
        sweep health is visible with or without a trace attached."""
        from repro.obs.metrics import metrics
        from repro.obs.spans import active_tracer

        tr = active_tracer()
        sp = tr.span("point:" + point.label, "dse") if tr is not None \
            else None
        if sp is not None:
            sp.__enter__()
        attempts = 0
        res: Optional[PointResult] = None
        try:
            while True:
                attempts += 1
                res = self._evaluate_attempt(point, once)
                res.attempts = attempts
                if res.ok or attempts > self.point_retries:
                    break
                if self.retry_backoff_s > 0.0:
                    time.sleep(min(
                        self.retry_backoff_s * (2 ** (attempts - 1)),
                        5.0))
        finally:
            # res is None only when a SimulatedCrash (BaseException)
            # escaped the attempt -- tally it as a failure
            status = res.status if res is not None else "failed"
            reg = metrics()
            reg.counter("dse.point/" + status).inc()
            reg.counter("dse.point_attempts").inc(attempts)
            if sp is not None:
                sp.set("status", status)
                sp.set("attempts", attempts)
                if res is not None and res.error:
                    sp.set("error", res.error)
                sp.__exit__(None, None, None)
        return res

    def _evaluate_attempt(self, point: DesignPoint,
                          once: Callable[[], PointResult]) -> PointResult:
        if self.point_timeout_s is None:
            return once()
        # a disposable single-use worker so one pathological point
        # cannot stall the sweep; on timeout the worker thread is
        # abandoned (daemonic futures cannot be killed) and the point
        # is recorded as timed out
        ex = ThreadPoolExecutor(max_workers=1)
        fut: Future = ex.submit(once)
        try:
            return fut.result(timeout=self.point_timeout_s)
        except _FutTimeout:
            fut.cancel()
            return PointResult(
                point=point, wall_seconds=self.point_timeout_s,
                error=f"TimeoutError: point exceeded "
                      f"{self.point_timeout_s}s wall-clock budget",
                error_type="TimeoutError", timed_out=True)
        finally:
            ex.shutdown(wait=False, cancel_futures=True)

    def _evaluate_once(self, point: DesignPoint) -> PointResult:
        t0 = time.perf_counter()
        try:
            inj = _active_injector()
            if inj is not None:
                inj.before_point(point.label)
            spec = point.build_spec()
            params = point.default_params()
            sig = mapping_signature(spec, params)
            self._sig_cache[point] = sig
            plans = self._plan_cache.get(sig)
            from repro.obs.metrics import metrics
            if plans is not None:
                self.plan_cache_hits += 1
                metrics().counter("dse.plan_cache/hit").inc()
            else:
                metrics().counter("dse.plan_cache/miss").inc()
            token = f"{self._workload_token}|{hash(sig):x}"
            sim = CascadeSimulator(spec, params=params,
                                   backend=self._backend_for(token),
                                   plans=plans)
            if plans is None:
                self._plan_cache[sig] = sim.plans
            res = sim.run(self._sim_inputs(sim), self.var_shapes)
            rep = res.report
            self.points_evaluated += 1
            return PointResult(
                point=point,
                seconds=rep.seconds,
                energy_pj=rep.energy_pj,
                dram_bytes=rep.dram_bytes,
                wall_seconds=time.perf_counter() - t0,
                fallback_reasons=dict(res.fallback_reasons),
                report=rep if self.keep_reports else None)
        except Exception as exc:                      # noqa: BLE001
            return PointResult(point=point,
                               wall_seconds=time.perf_counter() - t0,
                               error=f"{type(exc).__name__}: {exc}",
                               error_type=type(exc).__name__,
                               traceback=_trim_traceback(exc))

    # ------------------------------------------------------------------ #
    # batched group evaluation (probe + replay)
    # ------------------------------------------------------------------ #
    def _prep(self, point: DesignPoint) -> _Prep:
        spec = point.build_spec()
        params = point.default_params()
        sig = mapping_signature(spec, params)
        self._sig_cache[point] = sig
        return _Prep(point=point, spec=spec, params=params, sig=sig,
                     group_key=(sig, isect_configs(spec)))

    def _probe_once(self, prep: _Prep, ctx: Dict[str, Any]) -> PointResult:
        """Evaluate the first point of a group through the full
        backend, recording the instrumentation stream and the
        ``touch_stat`` consumption sequence for the group's replays."""
        t0 = time.perf_counter()
        try:
            inj = _active_injector()
            if inj is not None:
                inj.before_point(prep.point.label)
            plans = self._plan_cache.get(prep.sig)
            from repro.obs.metrics import metrics
            if plans is not None:
                self.plan_cache_hits += 1
                metrics().counter("dse.plan_cache/hit").inc()
            else:
                metrics().counter("dse.plan_cache/miss").inc()
            token = f"{self._workload_token}|{hash(prep.sig):x}"
            rec = RecordingInstr()
            sim = CascadeSimulator(prep.spec, params=prep.params,
                                   backend=self._backend_for(token),
                                   extra_instr=rec, plans=plans)
            if plans is None:
                self._plan_cache[prep.sig] = sim.plans
            capture = _CaptureFeed()
            with stat_miss_feed(capture):
                res = sim.run(self._sim_inputs(sim), self.var_shapes)
            rep = res.report
            self.points_evaluated += 1
            ctx["rec"] = rec
            ctx["plans"] = sim.plans
            ctx["fallbacks"] = dict(res.fallback_reasons)
            ctx["exec_tensors"] = {
                name: dict(m.tensors)
                for name, m in sim.model.models.items() if m.tensors}
            ctx["capture"] = capture
            ctx["level_keys"] = {id(lvl): key for key, lvl
                                 in sim.model.shared_levels.items()}
            return PointResult(
                point=prep.point,
                seconds=rep.seconds,
                energy_pj=rep.energy_pj,
                dram_bytes=rep.dram_bytes,
                wall_seconds=time.perf_counter() - t0,
                fallback_reasons=dict(res.fallback_reasons),
                report=rep if self.keep_reports else None)
        except Exception as exc:                      # noqa: BLE001
            return PointResult(point=prep.point,
                               wall_seconds=time.perf_counter() - t0,
                               error=f"{type(exc).__name__}: {exc}",
                               error_type=type(exc).__name__,
                               traceback=_trim_traceback(exc))

    def _replay_once(self, prep: _Prep, plans: Dict[str, EinsumPlan],
                     rec: RecordingInstr,
                     exec_tensors: Dict[str, Dict[str, Any]],
                     fallbacks: Dict[str, str],
                     feed: Optional[_ReplayFeed],
                     premodel: Optional[List[Any]] = None) -> PointResult:
        """Re-consume the group's recorded stream through this point's
        own ``PerformanceModel``: same events, this point's component
        attributes -- bit-identical to a full evaluation by
        construction.  ``premodel`` is a one-shot container holding a
        model prebuilt by :meth:`_replay_feeds`; the first attempt pops
        it, retries and abandoned timeout threads always build fresh so
        no attempt can observe another's partial state."""
        t0 = time.perf_counter()
        try:
            inj = _active_injector()
            if inj is not None:
                inj.before_point(prep.point.label)
            from repro.obs.metrics import metrics
            self.plan_cache_hits += 1
            metrics().counter("dse.plan_cache/hit").inc()
            model = premodel.pop() if premodel else \
                PerformanceModel(prep.spec, plans)
            for name, tensors in exec_tensors.items():
                model.register_exec_tensors(name, tensors)
            if feed is not None:
                feed.reset()
                with stat_miss_feed(feed):
                    rec.replay(model)
            else:
                rec.replay(model)
            rep = _evaluate_report(prep.spec, plans, model)
            rep.fallback_reasons = dict(fallbacks)
            self.points_evaluated += 1
            return PointResult(
                point=prep.point,
                seconds=rep.seconds,
                energy_pj=rep.energy_pj,
                dram_bytes=rep.dram_bytes,
                wall_seconds=time.perf_counter() - t0,
                fallback_reasons=dict(fallbacks),
                report=rep if self.keep_reports else None)
        except Exception as exc:                      # noqa: BLE001
            return PointResult(point=prep.point,
                               wall_seconds=time.perf_counter() - t0,
                               error=f"{type(exc).__name__}: {exc}",
                               error_type=type(exc).__name__,
                               traceback=_trim_traceback(exc))

    def _replay_feeds(self, ctx: Dict[str, Any], rest: Sequence[_Prep],
                      plans: Dict[str, EinsumPlan]
                      ) -> Tuple[List[Optional[_ReplayFeed]],
                                 List[Optional[Any]]]:
        """Vectorize the capacity-dependent miss closed form across the
        group's point axis: one ``batched_stat_misses`` call per
        recorded ``touch_stat`` occurrence covers every point.

        Returns ``(feeds, models)``: the per-point replay feeds (all
        None when the scalar path must be used) and the per-point
        ``PerformanceModel`` built to read the capacities off -- handed
        to :meth:`_replay_once` so the first replay attempt reuses it
        instead of building a second identical model."""
        none: List[Optional[_ReplayFeed]] = [None] * len(rest)
        models: List[Optional[Any]] = []
        for prep in rest:
            try:
                models.append(PerformanceModel(prep.spec, plans))
            except Exception:               # noqa: BLE001 - scalar path
                models.append(None)
        capture: _CaptureFeed = ctx.get("capture")
        level_keys: Dict[int, Tuple] = ctx.get("level_keys", {})
        if capture is None or not capture.calls or None in models:
            return none, models
        occurrences = []
        for level, nbytes, n, unique in capture.calls:
            key = level_keys.get(id(level))
            if key is None:
                return none, models
            occurrences.append((key, nbytes, n, unique))
        caps_list = [{k: lvl.capacity_bytes
                      for k, lvl in m.shared_levels.items()}
                     for m in models]
        values = np.empty((len(occurrences), len(rest)), dtype=np.float64)
        for i, (key, nbytes, n, unique) in enumerate(occurrences):
            caps = np.array([c.get(key, np.nan) for c in caps_list],
                            dtype=np.float64)
            values[i] = batched_stat_misses(n, unique, nbytes, caps)
        feeds = [_ReplayFeed(occurrences, values[:, j].tolist(),
                             caps_list[j])
                 for j in range(len(rest))]
        return feeds, models

    def _sweep_batched(self, todo: Sequence[DesignPoint],
                       done: Dict[str, PointResult],
                       maybe_save: Callable[[], None]) -> None:
        """Group -> probe -> replay evaluation of every pending point
        (analytic backend).  Each point still passes through the full
        per-point fault policy; a probe failure or an overflowed
        recorder degrades the group to per-point evaluation."""
        groups: "Dict[Tuple, List[_Prep]]" = {}
        order: List[Tuple] = []
        for p in todo:
            try:
                prep = self._prep(p)
            except Exception:               # noqa: BLE001
                # a point whose spec will not even build: route through
                # the per-point path for the structured error + counters
                done[p.label] = self.evaluate(p)
                maybe_save()
                continue
            if prep.group_key not in groups:
                groups[prep.group_key] = []
                order.append(prep.group_key)
            groups[prep.group_key].append(prep)

        for key in order:
            members = groups[key]
            ctx: Dict[str, Any] = {}
            probe = members[0]
            res0 = self._guarded(probe.point,
                                 lambda: self._probe_once(probe, ctx))
            done[probe.point.label] = res0
            self._cache_put(probe.point, res0)
            maybe_save()
            rest = members[1:]
            if not rest:
                continue
            rec: Optional[RecordingInstr] = ctx.get("rec")
            if not res0.ok or rec is None or rec.overflowed:
                for prep in rest:
                    done[prep.point.label] = self.evaluate(prep.point)
                    maybe_save()
                continue
            plans = ctx["plans"]
            exec_tensors = ctx.get("exec_tensors", {})
            fallbacks = ctx.get("fallbacks", {})
            feeds, models = self._replay_feeds(ctx, rest, plans)
            for prep, feed, model in zip(rest, feeds, models):
                pre = [model] if model is not None else []
                res = self._guarded(
                    prep.point,
                    lambda p=prep, f=feed, pm=pre: self._replay_once(
                        p, plans, rec, exec_tensors, fallbacks, f, pm))
                done[prep.point.label] = res
                self._cache_put(prep.point, res)
                maybe_save()

    # ------------------------------------------------------------------ #
    # process-pool sharded sweep
    # ------------------------------------------------------------------ #
    def _sweep_process(self, todo: Sequence[DesignPoint],
                       done: Dict[str, PointResult],
                       maybe_save: Callable[[], None],
                       workers: int, checkpoint_every: int) -> None:
        """Shard ``todo`` into contiguous chunks over a process pool;
        each worker runs its own batched engine.  Chunk size is bounded
        by ``checkpoint_every`` so the parent checkpoints at a
        comparable cadence to the serial path."""
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor

        chunk = max(1, math.ceil(len(todo) / workers))
        if checkpoint_every > 0:
            chunk = min(chunk, max(checkpoint_every, 1))
        chunks = [list(todo[i:i + chunk])
                  for i in range(0, len(todo), chunk)]
        by_label = {p.label: p for p in todo}

        engine_kw = dict(backend=self.backend, mode=self.mode,
                         max_workers=1, point_timeout_s=self.point_timeout_s,
                         point_retries=self.point_retries,
                         retry_backoff_s=self.retry_backoff_s,
                         batch=self.batch)
        inj = _active_injector()
        fault_payload = None
        if inj is not None:
            from dataclasses import replace
            fault_payload = ([replace(sp, calls=0, fired=0)
                              for sp in inj.specs], inj.seed)

        method = "fork" if "fork" in mp.get_all_start_methods() else "spawn"
        ctx = mp.get_context(method)
        with ProcessPoolExecutor(
                max_workers=min(workers, len(chunks)), mp_context=ctx,
                initializer=_pool_init,
                initargs=(self.inputs, self.var_shapes, engine_kw,
                          fault_payload)) as pool:
            futs = {pool.submit(_pool_run, c): c for c in chunks}
            pending = set(futs)
            while pending:
                finished, pending = _fut_wait(
                    pending, return_when=FIRST_COMPLETED)
                for f in finished:
                    # a SimulatedCrash (or a genuinely dead worker:
                    # BrokenProcessPool) re-raises here; sweep()'s
                    # BaseException handler runs the final save
                    rows = f.result()
                    for row in rows:
                        p = by_label[row["label"]]
                        res = _unpack_result(row, p)
                        done[res.label] = res
                        self._cache_put(p, res)
                maybe_save()

    # ------------------------------------------------------------------ #
    def sweep(self, points: Sequence[DesignPoint],
              warm: bool = True,
              checkpoint_dir: Optional[str] = None,
              checkpoint_every: int = 16,
              resume: bool = False) -> List[PointResult]:
        """Evaluate every point, preserving input order.

        Evaluation strategy, in precedence order: checkpoint-restored
        and result-cached points are served without the backend; with
        ``executor='process'`` and ``max_workers > 1`` the rest are
        sharded over a process pool; the analytic backend with
        ``batch=True`` (the default) evaluates grouped points
        probe-then-replay; execution backends fall back to the
        threaded pool (``max_workers > 1``, ``warm`` evaluates the
        first point up front to populate the shared caches) or the
        serial loop.

        With ``checkpoint_dir`` the sweep saves its completed results
        (objectives + structured errors) atomically every
        ``checkpoint_every`` completions and once at the end -- on an
        interruption (including a :class:`SimulatedCrash`) a final
        best-effort save still runs, so ``resume=True`` on a later
        call restores every checkpointed point by label instead of
        re-evaluating it.  A point never finishes silently in neither
        state: it is either in the results or still pending.

        With ``multi_host=True`` each jax host evaluates only its
        contiguous shard of the points (``launch.mesh.host_shard``)
        and returns results for that shard; give each host its own
        ``checkpoint_dir``.

        Coverage tallies of the call land on ``self.last_coverage``
        (total / evaluated / ok / failed / timed_out / skipped /
        cached, where skipped counts checkpoint-restored points)."""
        points = list(points)
        self.last_coverage = {}
        if not points:
            return []
        if self.multi_host:
            from repro.launch.mesh import host_shard
            points = host_shard(points)
            if not points:
                return []

        done: Dict[str, PointResult] = {}
        store = None
        saved_count = 0
        if checkpoint_dir is not None:
            from repro.dse.sweep_ckpt import SweepCheckpointStore
            store = SweepCheckpointStore(checkpoint_dir)
            if resume:
                for r in store.load(points):
                    done[r.label] = r
                saved_count = len(done)

        todo = [p for p in points if p.label not in done]
        if self.result_cache is not None:
            still: List[DesignPoint] = []
            for p in todo:
                hit = self._cache_get(p)
                if hit is not None:
                    done[p.label] = hit
                else:
                    still.append(p)
            todo = still

        def maybe_save(final: bool = False) -> None:
            nonlocal saved_count
            if store is None:
                return
            if final or (len(done) - saved_count) >= checkpoint_every:
                store.save(list(done.values()), n_total=len(points))
                saved_count = len(done)

        try:
            workers = self.max_workers or 1
            if self.executor == "process" and workers > 1 \
                    and len(todo) > 1 and isinstance(self.backend, str) \
                    and not self.keep_reports:
                self._sweep_process(todo, done, maybe_save, workers,
                                    checkpoint_every
                                    if store is not None else 0)
            elif self.batch and self.backend == "analytic" and todo:
                self._sweep_batched(todo, done, maybe_save)
            elif workers <= 1 or len(todo) <= 1:
                for p in todo:
                    done[p.label] = self.evaluate(p)
                    maybe_save()
            else:
                head = todo[:1] if warm else []
                for p in head:
                    done[p.label] = self.evaluate(p)
                    maybe_save()
                rest = todo[1:] if warm else todo
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    futs = {pool.submit(self.evaluate, p): p
                            for p in rest}
                    pending = set(futs)
                    while pending:
                        finished, pending = _fut_wait(
                            pending, return_when=FIRST_COMPLETED)
                        for f in finished:
                            done[futs[f].label] = f.result()
                        maybe_save()
        except BaseException:
            # a crash mid-sweep (SimulatedCrash, KeyboardInterrupt)
            # still publishes what completed, so --resume works
            maybe_save(final=True)
            if self.result_cache is not None:
                try:
                    self.result_cache.flush()
                except Exception:           # noqa: BLE001 - best effort
                    pass
            raise
        maybe_save(final=True)
        if self.result_cache is not None:
            self.result_cache.flush()

        results = [done[p.label] for p in points]
        self.last_coverage = self.coverage(results)
        return results

    # ------------------------------------------------------------------ #
    @staticmethod
    def coverage(results: Sequence[PointResult]) -> Dict[str, int]:
        """Tally results by outcome (``skipped`` = restored from a
        checkpoint, ``cached`` = served from the result cache -- both
        excluded from ``evaluated``)."""
        cov = {"total": len(results), "evaluated": 0, "ok": 0,
               "failed": 0, "timed_out": 0, "skipped": 0, "cached": 0}
        for r in results:
            if r.restored:
                cov["skipped"] += 1
            elif r.cached:
                cov["cached"] += 1
            else:
                cov["evaluated"] += 1
            if r.ok:
                cov["ok"] += 1
            elif r.timed_out:
                cov["timed_out"] += 1
            else:
                cov["failed"] += 1
        return cov

    @staticmethod
    def summarize(results: Sequence[PointResult]) -> str:
        """One-line sweep coverage summary for logs / CLI output."""
        cov = SweepEngine.coverage(results)
        extra = f", {cov['cached']} cached" if cov["cached"] else ""
        return (f"{cov['ok']}/{cov['total']} ok "
                f"({cov['evaluated']} evaluated, "
                f"{cov['skipped']} restored{extra}, "
                f"{cov['failed']} failed, "
                f"{cov['timed_out']} timed out)")
