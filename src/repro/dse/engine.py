"""The sweep engine: evaluate design points through any backend.

One engine instance owns one workload (the input tensors + var shapes)
and amortizes everything that is shared across sweep points:

  * **plan lowering** -- memoized on ``cascade.mapping_signature``, so
    points that only change architecture attributes (cache capacity,
    merger radix, bandwidth) reuse the lowered ``EinsumPlan``s;
  * **density calibration** (analytic backend) -- the one-pass tensor
    scans are cached per (workload, mapping-signature, tensor, exec
    order) and shared across points *and* threads, so an
    arch-attribute sweep transforms + scans the workload exactly once
    and every later point is closed-form evaluation only.

Evaluation defaults to the analytic backend; pass ``backend='vector'``
or ``'python'`` for execution-based fidelity at sweep cost.
"""
from __future__ import annotations

import itertools
import time
import traceback as _tb
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout
from concurrent.futures import wait as _fut_wait
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cascade import mapping_signature
from repro.core.generator import CascadeSimulator
from repro.core.mapping import EinsumPlan
from repro.core.metrics import Report

from .space import DesignPoint

_token_counter = itertools.count()

#: objective fields checkpointed per point (alphabetical: jax flattens
#: dict pytrees in sorted-key order, so save and restore agree)
_CKPT_FIELDS = ("dram_bytes", "energy_pj", "seconds", "wall_seconds")


def _active_injector():
    try:
        from repro.testing.faults import active_injector
    except ImportError:
        return None
    return active_injector()


def _trim_traceback(exc: BaseException, limit: int = 600) -> str:
    """The exception line plus the innermost two frames -- enough to
    locate a sweep failure without shipping whole tracebacks around."""
    lines = _tb.format_exception(type(exc), exc, exc.__traceback__)
    return "".join(lines[:1] + lines[-3:])[-limit:]


@dataclass
class PointResult:
    """Modeled objectives of one evaluated design point."""
    point: DesignPoint
    seconds: float = float("nan")
    energy_pj: float = float("nan")
    dram_bytes: float = float("nan")
    wall_seconds: float = 0.0
    fallback_reasons: Dict[str, str] = field(default_factory=dict)
    report: Optional[Report] = None
    #: "ExcType: message" on failure (None when the point evaluated)
    error: Optional[str] = None
    #: the exception class name alone (machine-matchable)
    error_type: Optional[str] = None
    #: trimmed traceback (exception line + innermost frames)
    traceback: Optional[str] = None
    #: the point exceeded the engine's per-point wall-clock budget
    timed_out: bool = False
    #: evaluation attempts consumed (> 1 after retries)
    attempts: int = 1
    #: objectives restored from a sweep checkpoint, not re-evaluated
    restored: bool = False

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def status(self) -> str:
        if self.ok:
            return "restored" if self.restored else "ok"
        return "timeout" if self.timed_out else "failed"

    @property
    def label(self) -> str:
        return self.point.label

    def row(self) -> str:
        if not self.ok:
            tag = "TIMEOUT" if self.timed_out else "FAILED"
            tries = f" attempts={self.attempts}" if self.attempts > 1 \
                else ""
            return f"{self.label}: {tag} ({self.error}){tries}"
        return (f"{self.label}: time={self.seconds:.3e}s "
                f"traffic={self.dram_bytes / 1e3:.1f}KB "
                f"energy={self.energy_pj / 1e6:.2f}uJ")


class SweepEngine:
    """Evaluates ``DesignPoint``s on one fixed workload."""

    def __init__(self, inputs: Dict[str, Any],
                 var_shapes: Dict[str, int],
                 backend: str = "analytic",
                 mode: str = "calibrated",
                 keep_reports: bool = False,
                 max_workers: Optional[int] = None,
                 point_timeout_s: Optional[float] = None,
                 point_retries: int = 0,
                 retry_backoff_s: float = 0.0):
        self.inputs = dict(inputs)
        self.var_shapes = dict(var_shapes)
        self.backend = backend
        self.mode = mode
        self.keep_reports = keep_reports
        self.max_workers = max_workers
        #: per-point wall-clock budget; a point past it is recorded as
        #: timed out and the sweep proceeds (None = unbounded)
        self.point_timeout_s = point_timeout_s
        #: bounded re-evaluations of a failed / timed-out point
        self.point_retries = point_retries
        self.retry_backoff_s = retry_backoff_s
        # shared caches (see module docstring)
        self._plan_cache: Dict[str, Dict[str, EinsumPlan]] = {}
        self._calib_cache: Dict[Tuple, Any] = {}
        self._workload_token = f"wl{next(_token_counter)}"
        # simple stats for tests / benchmarks
        self.plan_cache_hits = 0
        self.points_evaluated = 0
        #: coverage tallies of the most recent sweep() call
        self.last_coverage: Dict[str, int] = {}

    # ------------------------------------------------------------------ #
    def _backend_for(self, token: str):
        if self.backend != "analytic":
            return self.backend
        from repro.core.analytic import AnalyticBackend
        # one instance per evaluation (per-cascade predicted-stats are
        # stateful) sharing the engine-wide calibration cache
        return AnalyticBackend(mode=self.mode,
                               calib_cache=self._calib_cache,
                               cache_token=token)

    def evaluate(self, point: DesignPoint) -> PointResult:
        """Evaluate one point with the engine's fault policy: per-point
        wall-clock timeout, then up to ``point_retries`` bounded
        re-attempts with backoff.  Never raises for a point failure --
        the error lands structured on the result (class name, message,
        trimmed traceback).  ``SimulatedCrash`` (a BaseException) is
        deliberately not absorbed: it models the whole process dying.

        Telemetry: one ``point:<label>`` span per evaluation (status /
        attempts / error in the span args) and unconditional
        ``dse.point/<status>`` + ``dse.point_attempts`` counters, so
        sweep health is visible with or without a trace attached."""
        from repro.obs.metrics import metrics
        from repro.obs.spans import active_tracer

        tr = active_tracer()
        sp = tr.span("point:" + point.label, "dse") if tr is not None \
            else None
        if sp is not None:
            sp.__enter__()
        attempts = 0
        res: Optional[PointResult] = None
        try:
            while True:
                attempts += 1
                res = self._evaluate_attempt(point)
                res.attempts = attempts
                if res.ok or attempts > self.point_retries:
                    break
                if self.retry_backoff_s > 0.0:
                    time.sleep(min(
                        self.retry_backoff_s * (2 ** (attempts - 1)),
                        5.0))
        finally:
            # res is None only when a SimulatedCrash (BaseException)
            # escaped _evaluate_attempt -- tally it as a failure
            status = res.status if res is not None else "failed"
            reg = metrics()
            reg.counter("dse.point/" + status).inc()
            reg.counter("dse.point_attempts").inc(attempts)
            if sp is not None:
                sp.set("status", status)
                sp.set("attempts", attempts)
                if res is not None and res.error:
                    sp.set("error", res.error)
                sp.__exit__(None, None, None)
        return res

    def _evaluate_attempt(self, point: DesignPoint) -> PointResult:
        if self.point_timeout_s is None:
            return self._evaluate_once(point)
        # a disposable single-use worker so one pathological point
        # cannot stall the sweep; on timeout the worker thread is
        # abandoned (daemonic futures cannot be killed) and the point
        # is recorded as timed out
        ex = ThreadPoolExecutor(max_workers=1)
        fut: Future = ex.submit(self._evaluate_once, point)
        try:
            return fut.result(timeout=self.point_timeout_s)
        except _FutTimeout:
            fut.cancel()
            return PointResult(
                point=point, wall_seconds=self.point_timeout_s,
                error=f"TimeoutError: point exceeded "
                      f"{self.point_timeout_s}s wall-clock budget",
                error_type="TimeoutError", timed_out=True)
        finally:
            ex.shutdown(wait=False, cancel_futures=True)

    def _evaluate_once(self, point: DesignPoint) -> PointResult:
        t0 = time.perf_counter()
        try:
            inj = _active_injector()
            if inj is not None:
                inj.before_point(point.label)
            spec = point.build_spec()
            params = point.default_params()
            sig = mapping_signature(spec, params)
            plans = self._plan_cache.get(sig)
            from repro.obs.metrics import metrics
            if plans is not None:
                self.plan_cache_hits += 1
                metrics().counter("dse.plan_cache/hit").inc()
            else:
                metrics().counter("dse.plan_cache/miss").inc()
            token = f"{self._workload_token}|{hash(sig):x}"
            sim = CascadeSimulator(spec, params=params,
                                   backend=self._backend_for(token),
                                   plans=plans)
            if plans is None:
                self._plan_cache[sig] = sim.plans
            res = sim.run(dict(self.inputs), self.var_shapes)
            rep = res.report
            self.points_evaluated += 1
            return PointResult(
                point=point,
                seconds=rep.seconds,
                energy_pj=rep.energy_pj,
                dram_bytes=rep.dram_bytes,
                wall_seconds=time.perf_counter() - t0,
                fallback_reasons=dict(res.fallback_reasons),
                report=rep if self.keep_reports else None)
        except Exception as exc:                      # noqa: BLE001
            return PointResult(point=point,
                               wall_seconds=time.perf_counter() - t0,
                               error=f"{type(exc).__name__}: {exc}",
                               error_type=type(exc).__name__,
                               traceback=_trim_traceback(exc))

    # ------------------------------------------------------------------ #
    def sweep(self, points: Sequence[DesignPoint],
              warm: bool = True,
              checkpoint_dir: Optional[str] = None,
              checkpoint_every: int = 16,
              resume: bool = False) -> List[PointResult]:
        """Evaluate every point, preserving input order.

        With ``max_workers > 1`` evaluation is threaded; the first
        point is evaluated up front (``warm``) so the shared plan /
        calibration caches are populated before the fan-out.

        With ``checkpoint_dir`` the sweep saves its completed results
        (objectives + structured errors) atomically every
        ``checkpoint_every`` completions and once at the end -- on an
        interruption (including a :class:`SimulatedCrash`) a final
        best-effort save still runs, so ``resume=True`` on a later
        call restores every checkpointed point by label instead of
        re-evaluating it.  A point never finishes silently in neither
        state: it is either in the results or still pending.

        Coverage tallies of the call land on ``self.last_coverage``
        (total / evaluated / ok / failed / timed_out / skipped, where
        skipped counts checkpoint-restored points)."""
        points = list(points)
        self.last_coverage = {}
        if not points:
            return []

        done: Dict[str, PointResult] = {}
        store = None
        saved_count = 0
        if checkpoint_dir is not None:
            from repro.dse.sweep_ckpt import SweepCheckpointStore
            store = SweepCheckpointStore(checkpoint_dir)
            if resume:
                for r in store.load(points):
                    done[r.label] = r
                saved_count = len(done)

        todo = [p for p in points if p.label not in done]

        def maybe_save(final: bool = False) -> None:
            nonlocal saved_count
            if store is None:
                return
            if final or (len(done) - saved_count) >= checkpoint_every:
                store.save(list(done.values()), n_total=len(points))
                saved_count = len(done)

        try:
            workers = self.max_workers or 1
            if workers <= 1 or len(todo) <= 1:
                for p in todo:
                    done[p.label] = self.evaluate(p)
                    maybe_save()
            else:
                head = todo[:1] if warm else []
                for p in head:
                    done[p.label] = self.evaluate(p)
                    maybe_save()
                rest = todo[1:] if warm else todo
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    futs = {pool.submit(self.evaluate, p): p
                            for p in rest}
                    pending = set(futs)
                    while pending:
                        finished, pending = _fut_wait(
                            pending, return_when=FIRST_COMPLETED)
                        for f in finished:
                            done[futs[f].label] = f.result()
                        maybe_save()
        except BaseException:
            # a crash mid-sweep (SimulatedCrash, KeyboardInterrupt)
            # still publishes what completed, so --resume works
            maybe_save(final=True)
            raise
        maybe_save(final=True)

        results = [done[p.label] for p in points]
        self.last_coverage = self.coverage(results)
        return results

    # ------------------------------------------------------------------ #
    @staticmethod
    def coverage(results: Sequence[PointResult]) -> Dict[str, int]:
        """Tally results by outcome (``skipped`` = restored from a
        checkpoint rather than re-evaluated)."""
        cov = {"total": len(results), "evaluated": 0, "ok": 0,
               "failed": 0, "timed_out": 0, "skipped": 0}
        for r in results:
            if r.restored:
                cov["skipped"] += 1
            else:
                cov["evaluated"] += 1
            if r.ok:
                cov["ok"] += 1
            elif r.timed_out:
                cov["timed_out"] += 1
            else:
                cov["failed"] += 1
        return cov

    @staticmethod
    def summarize(results: Sequence[PointResult]) -> str:
        """One-line sweep coverage summary for logs / CLI output."""
        cov = SweepEngine.coverage(results)
        return (f"{cov['ok']}/{cov['total']} ok "
                f"({cov['evaluated']} evaluated, "
                f"{cov['skipped']} restored, "
                f"{cov['failed']} failed, "
                f"{cov['timed_out']} timed out)")
