"""Sweep checkpointing: periodic, atomic, resumable partial results.

Bridges the sweep engine to ``repro.checkpoint.store``: completed
``PointResult``s become one checkpoint step whose pytree leaves are the
numeric objective arrays (label-sorted for determinism) and whose
manifest ``meta`` carries everything non-numeric -- point labels,
structured error strings, timeout flags, attempt counts.  Saves ride
the store's atomic tmp+rename publish, so a sweep killed mid-write
(the fault harness's ``SimulatedCrash``, a real OOM, ctrl-C) never
leaves a half-visible checkpoint, and ``--resume`` restores exactly
the points that completed: the resumed sweep's Pareto front is
bit-identical to an uninterrupted run over the same points.
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Sequence

import numpy as np

from .engine import _CKPT_FIELDS, PointResult
from .space import DesignPoint


class SweepCheckpointStore:
    """Directory-backed store of one sweep's completed results."""

    def __init__(self, directory: "str | Path", keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep

    # ------------------------------------------------------------------ #
    def save(self, results: Sequence[PointResult], n_total: int) -> None:
        from repro.checkpoint.store import CheckpointManager
        results = sorted(results, key=lambda r: r.label)
        tree = {f: np.array([getattr(r, f) for r in results],
                            dtype=np.float64)
                for f in _CKPT_FIELDS}
        meta = {
            "kind": "dse-sweep",
            "n_total": int(n_total),
            "labels": [r.label for r in results],
            "errors": [r.error or "" for r in results],
            "error_types": [r.error_type or "" for r in results],
            "timed_out": [bool(r.timed_out) for r in results],
            "attempts": [int(r.attempts) for r in results],
        }
        mgr = CheckpointManager(self.directory, keep=self.keep)
        # step = completed count: monotone as the sweep progresses, and
        # re-saving the same count just overwrites that step atomically
        mgr.save(len(results), tree, extra_meta=meta)

    # ------------------------------------------------------------------ #
    def load(self, points: Sequence[DesignPoint]) -> List[PointResult]:
        """Restore checkpointed results for the given points (matched
        by label; checkpointed labels not in ``points`` are ignored).
        Returns [] when no checkpoint exists."""
        if not (self.directory / "LATEST").exists():
            return []
        from repro.checkpoint.store import load_checkpoint, load_manifest
        manifest = load_manifest(self.directory)
        meta = manifest.get("meta", {})
        if meta.get("kind") != "dse-sweep":
            raise ValueError(
                f"checkpoint at {self.directory} is not a sweep "
                f"checkpoint (kind={meta.get('kind')!r})")
        labels = meta["labels"]
        like = {f: np.zeros(len(labels)) for f in _CKPT_FIELDS}
        tree, _ = load_checkpoint(self.directory, like=like)
        by_label = {p.label: p for p in points}
        out: List[PointResult] = []
        for i, lbl in enumerate(labels):
            p = by_label.get(lbl)
            if p is None:
                continue
            out.append(PointResult(
                point=p,
                seconds=float(tree["seconds"][i]),
                energy_pj=float(tree["energy_pj"][i]),
                dram_bytes=float(tree["dram_bytes"][i]),
                wall_seconds=float(tree["wall_seconds"][i]),
                error=meta["errors"][i] or None,
                error_type=meta["error_types"][i] or None,
                timed_out=bool(meta["timed_out"][i]),
                attempts=int(meta["attempts"][i]),
                restored=True))
        return out
