"""Result cache: serve repeated what-if queries without the backend.

A design-space query is a pure function of (workload, design point,
backend, fidelity mode): the analytic model has no hidden state, so two
evaluations of the same point on the same workload return bit-identical
objectives.  The cache exploits that purity at two scopes:

  * **in-memory LRU** -- bounded ``capacity`` of most recently used
    results, shared by every consumer of one :class:`ResultCache`
    (the sweep engine, the sweep service, search optimizers);
  * **persistent store** (optional ``directory``) -- ok-results are
    flushed through :mod:`repro.checkpoint.store`'s atomic publish,
    so a later process resumes with the whole cache warm.

The key is content-addressed, NOT object-addressed: a sha256 digest of
(workload hash, mapping signature, design id, spec kwargs, params,
backend, fidelity mode).  Consequences:

  * two ``DesignPoint`` objects describing the same configuration hit
    the same entry, whatever process built them (no dependence on
    ``PYTHONHASHSEED`` or object identity);
  * the *workload hash* covers the input tensor contents and the var
    shapes -- change the operands and the cache is cold, so stale
    results cannot leak across workloads (invalidation by keying);
  * failed / timed-out results are never cached: transient faults must
    not be replayed as facts.

Hits and misses are tallied on ``dse.result_cache/{hit,miss}``
counters (:mod:`repro.obs.metrics`).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from pathlib import Path
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

#: objective fields a cache entry carries (alphabetical, matching the
#: sweep-checkpoint convention so persisted trees stay deterministic)
_CACHE_FIELDS = ("dram_bytes", "energy_pj", "seconds")


def workload_hash(inputs: Dict[str, Any],
                  var_shapes: Dict[str, int]) -> str:
    """Content hash of a workload: input tensor values + var shapes.

    Dense arrays hash their raw bytes; fibertree tensors densify first
    (exact -- the dense image determines the tree).  Anything else
    falls back to ``repr``, which is conservative: an unstable repr
    only costs cache misses, never wrong hits.
    """
    h = hashlib.sha256()
    for name in sorted(inputs):
        val = inputs[name]
        h.update(name.encode())
        dense = None
        if isinstance(val, np.ndarray):
            dense = val
        elif hasattr(val, "to_dense"):
            try:
                dense = val.to_dense()
            except Exception:           # noqa: BLE001 - repr fallback
                dense = None
        if dense is not None:
            arr = np.ascontiguousarray(dense)
            h.update(str(arr.shape).encode())
            h.update(str(arr.dtype).encode())
            h.update(arr.tobytes())
        else:
            h.update(repr(val).encode())
    h.update(repr(sorted(var_shapes.items())).encode())
    return h.hexdigest()[:16]


def result_key(workload: str, signature: str, point,
               backend: str, mode: str) -> str:
    """Content-addressed cache key for one (workload, point) query."""
    design = point.design if isinstance(point.design, str) else \
        getattr(point.design, "__qualname__", repr(point.design))
    blob = "\x1f".join((
        workload,
        signature,
        design,
        repr(tuple(point.spec_kw)),
        repr(tuple(point.params)),
        backend,
        mode,
    ))
    return hashlib.sha256(blob.encode()).hexdigest()


class ResultCache:
    """Bounded LRU of evaluated objectives, optionally persistent.

    Entries map a :func:`result_key` digest to the objective tuple
    ``(seconds, energy_pj, dram_bytes)``.  ``get`` / ``put`` are
    thread-safe under CPython's GIL for the OrderedDict operations
    used; the sweep service serializes access anyway.
    """

    def __init__(self, capacity: int = 4096,
                 directory: "str | Path | None" = None,
                 keep: int = 3):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.directory = Path(directory) if directory is not None else None
        self.keep = keep
        self._data: "OrderedDict[str, Tuple[float, float, float]]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self._dirty = 0
        if self.directory is not None:
            self._load()

    def __len__(self) -> int:
        return len(self._data)

    # ------------------------------------------------------------------ #
    def get(self, key: str) -> Optional[Dict[str, float]]:
        """The cached objectives for ``key`` or None; counts the
        outcome on ``dse.result_cache/{hit,miss}``."""
        from repro.obs.metrics import metrics
        entry = self._data.get(key)
        if entry is None:
            self.misses += 1
            metrics().counter("dse.result_cache/miss").inc()
            return None
        self._data.move_to_end(key)
        self.hits += 1
        metrics().counter("dse.result_cache/hit").inc()
        seconds, energy_pj, dram_bytes = entry
        return {"seconds": seconds, "energy_pj": energy_pj,
                "dram_bytes": dram_bytes}

    def put(self, key: str, seconds: float, energy_pj: float,
            dram_bytes: float) -> None:
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = (float(seconds), float(energy_pj),
                           float(dram_bytes))
        self._dirty += 1
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._data)}

    # ------------------------------------------------------------------ #
    # persistence (atomic, via repro.checkpoint.store)
    # ------------------------------------------------------------------ #
    def flush(self) -> bool:
        """Publish the current entries atomically to ``directory``.
        No-op (returns False) without a directory or new entries."""
        if self.directory is None or self._dirty == 0:
            return False
        from repro.checkpoint.store import CheckpointManager
        keys = list(self._data)                     # LRU -> MRU order
        tree = {f: np.array([self._data[k][i] for k in keys],
                            dtype=np.float64)
                for i, f in enumerate(_CACHE_FIELDS)}
        meta = {"kind": "dse-result-cache", "keys": keys}
        mgr = CheckpointManager(self.directory, keep=self.keep)
        # step = entry count; equal counts overwrite atomically
        mgr.save(len(keys), tree, extra_meta=meta)
        self._dirty = 0
        return True

    def _load(self) -> None:
        if not (self.directory / "LATEST").exists():
            return
        from repro.checkpoint.store import load_checkpoint, load_manifest
        manifest = load_manifest(self.directory)
        meta = manifest.get("meta", {})
        if meta.get("kind") != "dse-result-cache":
            raise ValueError(
                f"checkpoint at {self.directory} is not a result cache "
                f"(kind={meta.get('kind')!r})")
        keys: Sequence[str] = meta["keys"]
        like = {f: np.zeros(len(keys)) for f in _CACHE_FIELDS}
        tree, _ = load_checkpoint(self.directory, like=like)
        for i, k in enumerate(keys):                # preserves LRU order
            self._data[k] = tuple(
                float(tree[f][i]) for f in _CACHE_FIELDS)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
        self._dirty = 0
