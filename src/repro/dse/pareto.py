"""Pareto-frontier extraction over modeled objectives.

All objectives are minimized.  Objectives are attribute names on the
result objects (``seconds``, ``energy_pj``, ``dram_bytes`` by default,
matching ``PointResult`` / ``Report``) or callables.
"""
from __future__ import annotations

from typing import Any, Callable, List, Sequence, Tuple, Union

Objective = Union[str, Callable[[Any], float]]

DEFAULT_OBJECTIVES: Tuple[str, ...] = ("seconds", "energy_pj", "dram_bytes")


def _values(item: Any, objectives: Sequence[Objective]) -> Tuple[float, ...]:
    out = []
    for ob in objectives:
        v = ob(item) if callable(ob) else getattr(item, ob)
        out.append(float(v))
    return tuple(out)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True when ``a`` is no worse than ``b`` on every objective and
    strictly better on at least one (minimization)."""
    assert len(a) == len(b)
    no_worse = all(x <= y for x, y in zip(a, b))
    better = any(x < y for x, y in zip(a, b))
    return no_worse and better


def pareto_front(results: Sequence[Any],
                 objectives: Sequence[Objective] = DEFAULT_OBJECTIVES
                 ) -> List[Any]:
    """Non-dominated subset of ``results``, in input order.  Duplicate
    objective vectors keep their first representative.

    Results that report a falsy ``ok`` attribute (failed / timed-out
    ``PointResult``s, whose objectives are NaN placeholders) are
    filtered out before frontier construction -- a failed point can
    never appear on the front.  Objects without an ``ok`` attribute
    (plain ``Report``s, ad-hoc records) are kept."""
    alive = [r for r in results if getattr(r, "ok", True)]
    vals = [_values(r, objectives) for r in alive]
    front: List[Any] = []
    seen = set()
    for i, (r, v) in enumerate(zip(alive, vals)):
        if v in seen:
            continue
        if any(dominates(w, v) for j, w in enumerate(vals) if j != i):
            continue
        seen.add(v)
        front.append(r)
    return front
