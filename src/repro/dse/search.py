"""Gradient-free search over a design space.

Exhaustive grids stop scaling once a few axes multiply out; these
optimizers walk the space instead, consuming the ordinary
``PointResult`` stream from a :class:`~repro.dse.engine.SweepEngine`.
Both are deliberately cache-shaped: every generation/rung is evaluated
through ``engine.sweep``, so

  * points sharing a mapping signature share one probe (batched
    analytic evaluation), and
  * re-visited configurations -- elites carried between generations,
    survivors promoted between rungs -- hit the engine's
    :class:`~repro.dse.cache.ResultCache` instead of the backend.

Failed / timed-out points get an infinite objective: faults steer the
search away rather than crashing it.

:class:`EvolutionarySearch` -- fixed-budget genetic search: tournament
selection, uniform crossover, per-gene mutation, elite carry-over.

:class:`HalvingSearch` -- successive halving across fidelity rungs:
a wide random cohort is scored on a cheap engine and the top ``1/eta``
fraction is promoted to the next (more exact / more expensive) engine.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

from .engine import PointResult, SweepEngine
from .space import DesignPoint, DesignSpace

Objective = Union[str, Callable[[PointResult], float]]


def _objective_value(res: PointResult, objective: Objective) -> float:
    if not res.ok:
        return math.inf
    v = objective(res) if callable(objective) else getattr(res, objective)
    v = float(v)
    return v if math.isfinite(v) else math.inf


@dataclass
class SearchResult:
    """Outcome of one search run."""
    best: Optional[PointResult]          #: best ok result (None if none)
    best_value: float                    #: its objective (inf if none)
    evaluations: int                     #: engine queries issued
    history: List[Tuple[str, float]] = field(default_factory=list)
    #: per-round best objective, for convergence plots / tests
    trajectory: List[float] = field(default_factory=list)


class _Genome:
    """A design-space configuration as per-axis value indices --
    crossover and mutation operate on indices, so every offspring is a
    legal grid member by construction."""

    def __init__(self, space: DesignSpace):
        self.space = space
        self.kw_keys = list(space.axes)
        self.p_keys = list(space.param_axes)
        self.sizes = [len(space.axes[k]) for k in self.kw_keys] + \
                     [len(space.param_axes[k]) for k in self.p_keys]

    def random(self, rng: random.Random) -> Tuple[int, ...]:
        return tuple(rng.randrange(max(s, 1)) for s in self.sizes)

    def mutate(self, g: Tuple[int, ...], rate: float,
               rng: random.Random) -> Tuple[int, ...]:
        out = list(g)
        for i, s in enumerate(self.sizes):
            if s > 1 and rng.random() < rate:
                out[i] = rng.randrange(s)
        return tuple(out)

    def crossover(self, a: Tuple[int, ...], b: Tuple[int, ...],
                  rng: random.Random) -> Tuple[int, ...]:
        return tuple(x if rng.random() < 0.5 else y for x, y in zip(a, b))

    def point(self, g: Tuple[int, ...]) -> DesignPoint:
        nk = len(self.kw_keys)
        kw = {k: self.space.axes[k][g[i]]
              for i, k in enumerate(self.kw_keys)}
        params = {k: self.space.param_axes[k][g[nk + i]]
                  for i, k in enumerate(self.p_keys)}
        return self.space.point(kw, params)


class EvolutionarySearch:
    """Fixed-budget genetic search for the objective-minimizing point.

    Each generation is evaluated through one ``engine.sweep`` call;
    elites re-appear verbatim in the next generation and are served
    from the result cache, so the marginal cost of a generation is
    only its genuinely new configurations.
    """

    def __init__(self, space: DesignSpace, engine: SweepEngine, *,
                 population: int = 16, generations: int = 8,
                 elite: int = 2, mutation: float = 0.25,
                 tournament: int = 3, seed: int = 0,
                 objective: Objective = "seconds"):
        if population < 2:
            raise ValueError("population must be >= 2")
        if not 0 < elite < population:
            raise ValueError("elite must be in (0, population)")
        self.space = space
        self.engine = engine
        self.population = population
        self.generations = generations
        self.elite = elite
        self.mutation = mutation
        self.tournament = tournament
        self.seed = seed
        self.objective = objective

    def run(self) -> SearchResult:
        rng = random.Random(self.seed)
        genome = _Genome(self.space)
        pop = []
        seen = set()
        while len(pop) < self.population:
            g = genome.random(rng)
            if g not in seen or len(seen) >= self.space.size:
                seen.add(g)
                pop.append(g)

        out = SearchResult(best=None, best_value=math.inf, evaluations=0)
        for _ in range(self.generations):
            points = [genome.point(g) for g in pop]
            results = self.engine.sweep(points)
            out.evaluations += len(points)
            by_label = {r.label: r for r in results}
            scored = []
            for g, p in zip(pop, points):
                res = by_label[p.label]
                val = _objective_value(res, self.objective)
                scored.append((val, g, res))
                out.history.append((p.label, val))
                if val < out.best_value:
                    out.best_value, out.best = val, res
            scored.sort(key=lambda t: t[0])
            out.trajectory.append(scored[0][0])

            elites = [g for _, g, _ in scored[:self.elite]]
            nxt = list(elites)

            def pick() -> Tuple[int, ...]:
                k = min(self.tournament, len(scored))
                return min(rng.sample(scored, k), key=lambda t: t[0])[1]

            while len(nxt) < self.population:
                child = genome.crossover(pick(), pick(), rng)
                nxt.append(genome.mutate(child, self.mutation, rng))
            pop = nxt
        return out


class HalvingSearch:
    """Successive halving over fidelity rungs.

    ``engines`` is ordered cheap -> exact (e.g. an analytic engine in
    ``uniform`` mode, then ``calibrated``, then an execution backend).
    Rung 0 scores ``n`` random candidates on the cheapest engine; each
    following rung keeps the best ``1/eta`` fraction and re-scores them
    on the next engine.  With a single engine this degrades gracefully
    to plain random search with ``len(engines)`` == 1 rung.
    """

    def __init__(self, space: DesignSpace,
                 engines: Sequence[SweepEngine], *,
                 n: int = 27, eta: int = 3, seed: int = 0,
                 objective: Objective = "seconds"):
        if not engines:
            raise ValueError("need at least one engine")
        if eta < 2:
            raise ValueError("eta must be >= 2")
        self.space = space
        self.engines = list(engines)
        self.n = n
        self.eta = eta
        self.seed = seed
        self.objective = objective

    def run(self) -> SearchResult:
        candidates = self.space.random(self.n, seed=self.seed)
        out = SearchResult(best=None, best_value=math.inf, evaluations=0)
        for rung, engine in enumerate(self.engines):
            if not candidates:
                break
            results = engine.sweep(candidates)
            out.evaluations += len(candidates)
            by_label = {r.label: r for r in results}
            scored = []
            for p in candidates:
                res = by_label[p.label]
                val = _objective_value(res, self.objective)
                scored.append((val, p, res))
                out.history.append((p.label, val))
            scored.sort(key=lambda t: t[0])
            out.trajectory.append(scored[0][0])
            last = rung == len(self.engines) - 1
            if last:
                val, _, res = scored[0]
                if val < out.best_value:
                    out.best_value, out.best = val, res
                break
            keep = max(1, len(scored) // self.eta)
            candidates = [p for _, p, _ in scored[:keep]]
        return out
