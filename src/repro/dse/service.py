"""Persistent sweep service: concurrent what-if queries, micro-batched.

A long-lived design-space exploration session -- an interactive
notebook, an optimizer population, several engineers poking the same
workload -- issues many small queries instead of one big sweep.  Served
naively, each query pays the full per-point cost and the batched
evaluation path (one probe amortized over a group) never engages.

:class:`SweepService` fixes that with a classic serving loop:

  * **request queue** -- ``submit(point)`` enqueues and returns a
    ``concurrent.futures.Future`` immediately; a bounded queue
    (``max_queue``) provides backpressure, rejecting work instead of
    buffering without limit;
  * **micro-batching** -- the single worker thread takes the first
    pending request, then drains whatever else arrives within
    ``batch_window_s`` (up to ``max_batch``): concurrent queries are
    coalesced into ONE ``SweepEngine.sweep`` call, so points sharing a
    mapping signature share one probe and the result cache is checked
    once per distinct point;
  * **request coalescing** -- duplicate in-flight points (same label)
    are evaluated once and fanned out to every waiting future;
  * **fault isolation** -- the engine already converts per-point
    failures into structured ``PointResult``s; anything that still
    escapes a batch fails only that batch's futures and the loop keeps
    serving.

Telemetry: ``dse.service/{requests,batches,coalesced,rejected}``
counters, a ``dse.service/batch_size`` histogram, and one
``service:batch`` span per drained batch.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .engine import PointResult, SweepEngine
from .space import DesignPoint


class ServiceClosed(RuntimeError):
    """Raised by ``submit`` after ``stop()`` (or before ``start()``)."""


class ServiceOverloaded(RuntimeError):
    """Raised by ``submit`` when the request queue is full."""


class SweepService:
    """Single-worker micro-batching front-end over a
    :class:`SweepEngine`.

    One worker thread keeps the engine's internal caches (plans,
    calibration, converted operands, result cache) on a single timeline
    -- no cross-thread engine locking -- while still letting any number
    of client threads (or an asyncio loop, via :meth:`asubmit`) issue
    queries concurrently.
    """

    def __init__(self, engine: SweepEngine, *, max_batch: int = 64,
                 batch_window_s: float = 0.002, max_queue: int = 1024):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.engine = engine
        self.max_batch = max_batch
        self.batch_window_s = batch_window_s
        self._queue: "queue.Queue[Optional[Tuple[DesignPoint, Future]]]" = \
            queue.Queue(maxsize=max_queue)
        self._thread: Optional[threading.Thread] = None
        self._open = False
        self.requests = 0
        self.batches = 0
        self.coalesced = 0
        self.rejected = 0

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self) -> "SweepService":
        if self._thread is not None:
            return self
        self._open = True
        self._thread = threading.Thread(target=self._serve, daemon=True,
                                        name="sweep-service")
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Shut the service down.  With ``drain`` (default) queued
        requests are still served; without, they fail with
        :class:`ServiceClosed`."""
        if self._thread is None:
            return
        self._open = False
        if not drain:
            while True:
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break
                if item is not None:
                    item[1].set_exception(ServiceClosed("service stopped"))
        self._queue.put(None)                       # wake the worker
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "SweepService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------ #
    # client API
    # ------------------------------------------------------------------ #
    def submit(self, point: DesignPoint) -> "Future[PointResult]":
        """Enqueue one query; resolves to the point's
        :class:`PointResult`.  Raises :class:`ServiceClosed` when the
        service is not running and :class:`ServiceOverloaded` when the
        queue is full (backpressure -- callers should slow down, not
        buffer)."""
        from repro.obs.metrics import metrics
        if not self._open:
            raise ServiceClosed("service is not running")
        fut: "Future[PointResult]" = Future()
        try:
            self._queue.put_nowait((point, fut))
        except queue.Full:
            self.rejected += 1
            metrics().counter("dse.service/rejected").inc()
            raise ServiceOverloaded(
                f"request queue full ({self._queue.maxsize})") from None
        self.requests += 1
        metrics().counter("dse.service/requests").inc()
        return fut

    def what_if(self, point: DesignPoint,
                timeout: Optional[float] = None) -> PointResult:
        """Blocking convenience wrapper: submit and wait."""
        return self.submit(point).result(timeout=timeout)

    def asubmit(self, point: DesignPoint):
        """``await``-able form of :meth:`submit` for asyncio callers."""
        import asyncio
        return asyncio.wrap_future(self.submit(point))

    def stats(self) -> Dict[str, int]:
        return {"requests": self.requests, "batches": self.batches,
                "coalesced": self.coalesced, "rejected": self.rejected,
                "queued": self._queue.qsize()}

    # ------------------------------------------------------------------ #
    # worker loop
    # ------------------------------------------------------------------ #
    def _serve(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                if self._open:                       # spurious wake
                    continue
                return
            batch = [item]
            deadline = time.monotonic() + self.batch_window_s
            while len(batch) < self.max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is None:
                    # propagate the shutdown wake after this batch
                    self._queue.put(None)
                    break
                batch.append(nxt)
            self._run_batch(batch)

    def _run_batch(self,
                   batch: List[Tuple[DesignPoint, Future]]) -> None:
        from repro.obs.metrics import metrics
        from repro.obs.spans import active_tracer

        reg = metrics()
        self.batches += 1
        reg.counter("dse.service/batches").inc()
        reg.histogram("dse.service/batch_size",
                      buckets=(1, 2, 4, 8, 16, 32, 64, 128)) \
            .observe(len(batch))

        # coalesce duplicate in-flight points: first occurrence wins
        # the evaluation, every future gets the shared result
        unique: "Dict[str, DesignPoint]" = {}
        waiting: "Dict[str, List[Future]]" = {}
        for point, fut in batch:
            if point.label in unique:
                self.coalesced += 1
                reg.counter("dse.service/coalesced").inc()
            else:
                unique[point.label] = point
            waiting.setdefault(point.label, []).append(fut)

        tr = active_tracer()
        sp = tr.span("service:batch", "dse") if tr is not None else None
        if sp is not None:
            sp.__enter__()
            sp.set("requests", len(batch))
            sp.set("points", len(unique))
        try:
            results = self.engine.sweep(list(unique.values()))
            by_label = {r.label: r for r in results}
            for label, futs in waiting.items():
                res = by_label.get(label)
                for fut in futs:
                    if res is not None:
                        fut.set_result(res)
                    else:
                        fut.set_exception(RuntimeError(
                            f"sweep returned no result for {label!r}"))
        except BaseException as exc:               # noqa: BLE001
            # fail this batch's futures, keep serving the next one
            for futs in waiting.values():
                for fut in futs:
                    if not fut.done():
                        fut.set_exception(exc)
            if sp is not None:
                sp.set("error", f"{type(exc).__name__}: {exc}")
        finally:
            if sp is not None:
                sp.__exit__(None, None, None)
