"""Design-space exploration over declarative TeAAL specs.

The paper's Section-8 workflow -- sweep point changes to a spec and
compare modeled designs -- made engine-shaped:

  * ``space``  -- declarative sweep-space construction (grid / random /
    parameter overrides) producing hashable ``DesignPoint``s;
  * ``engine`` -- evaluation of points through any execution backend
    (default: the analytic engine, with memoized plan lowering and a
    shared per-workload density-calibration cache);
  * ``pareto`` -- dominance filtering over the modeled objectives
    (time / energy / DRAM traffic).

``examples/design_space_study.py`` and ``benchmarks/dse_sweep.py`` sit
on top of this package.
"""
from .engine import PointResult, SweepEngine
from .pareto import dominates, pareto_front
from .space import DesignPoint, DesignSpace

__all__ = ["DesignPoint", "DesignSpace", "PointResult", "SweepEngine",
           "dominates", "pareto_front"]
