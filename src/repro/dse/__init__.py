"""Design-space exploration over declarative TeAAL specs.

The paper's Section-8 workflow -- sweep point changes to a spec and
compare modeled designs -- made engine-shaped:

  * ``space``   -- declarative sweep-space construction (grid / random /
    parameter overrides) producing hashable ``DesignPoint``s;
  * ``engine``  -- evaluation of points through any execution backend
    (default: the analytic engine, with memoized plan lowering, a
    shared per-workload density-calibration cache, and batched
    probe+replay evaluation of points sharing a mapping signature);
  * ``cache``   -- content-addressed result cache (in-memory LRU plus
    an optional persistent store) serving repeat queries without the
    backend;
  * ``service`` -- persistent micro-batching front-end coalescing
    concurrent what-if queries into shared sweeps;
  * ``search``  -- gradient-free optimizers (evolutionary, successive
    halving) walking spaces too large to grid;
  * ``pareto``  -- dominance filtering over the modeled objectives
    (time / energy / DRAM traffic).

``examples/design_space_study.py``, ``examples/serve_batched.py`` and
``benchmarks/dse_sweep.py`` sit on top of this package.
"""
from .cache import ResultCache, result_key, workload_hash
from .engine import PointResult, SweepEngine
from .pareto import dominates, pareto_front
from .search import EvolutionarySearch, HalvingSearch, SearchResult
from .service import (ServiceClosed, ServiceOverloaded, SweepService)
from .space import DesignPoint, DesignSpace

__all__ = ["DesignPoint", "DesignSpace", "EvolutionarySearch",
           "HalvingSearch", "PointResult", "ResultCache", "SearchResult",
           "ServiceClosed", "ServiceOverloaded", "SweepEngine",
           "SweepService", "dominates", "pareto_front", "result_key",
           "workload_hash"]
