"""Sweep-space construction: which design points to evaluate.

A ``DesignPoint`` names a design (an ``repro.accelerators`` registry
entry or a ``spec()`` factory) plus the spec-factory keyword overrides
and symbolic mapping params that define one concrete configuration.
``DesignSpace`` expands axes of such overrides into points -- full
grid, random subsample, or explicit per-point override dicts.
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple, Union)


def _freeze(d: Optional[Mapping[str, Any]]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted((d or {}).items()))


@dataclass(frozen=True)
class DesignPoint:
    """One concrete configuration of one design."""
    design: Any                                   # registry name or factory
    spec_kw: Tuple[Tuple[str, Any], ...] = ()     # spec-factory overrides
    params: Tuple[Tuple[str, int], ...] = ()      # symbolic mapping params
    label: str = ""

    @staticmethod
    def make(design: Any, spec_kw: Optional[Mapping[str, Any]] = None,
             params: Optional[Mapping[str, int]] = None,
             label: str = "") -> "DesignPoint":
        return DesignPoint(design, _freeze(spec_kw), _freeze(params),
                           label or DesignPoint._auto_label(design, spec_kw))

    @staticmethod
    def _auto_label(design: Any, spec_kw: Optional[Mapping[str, Any]]
                    ) -> str:
        name = design if isinstance(design, str) else \
            getattr(design, "__module__", repr(design)).rsplit(".", 1)[-1]
        kw = ",".join(f"{k}={v}" for k, v in sorted((spec_kw or {}).items()))
        return f"{name}({kw})" if kw else name

    @property
    def spec_kwargs(self) -> Dict[str, Any]:
        return dict(self.spec_kw)

    @property
    def param_dict(self) -> Optional[Dict[str, int]]:
        return dict(self.params) if self.params else None

    def build_spec(self):
        """Instantiate the AcceleratorSpec for this point."""
        if callable(self.design):
            return self.design(**self.spec_kwargs)
        from repro.accelerators import REGISTRY
        return REGISTRY[self.design](**self.spec_kwargs)

    def default_params(self) -> Optional[Dict[str, int]]:
        if self.params:
            return dict(self.params)
        if isinstance(self.design, str):
            from repro.accelerators import DEFAULT_PARAMS
            return DEFAULT_PARAMS.get(self.design)
        return None


@dataclass
class DesignSpace:
    """Axes of spec-factory overrides (and mapping params) for one
    design; expand with ``grid()`` or ``random(n)``."""
    design: Any
    axes: Dict[str, Sequence[Any]] = field(default_factory=dict)
    param_axes: Dict[str, Sequence[int]] = field(default_factory=dict)
    base_kw: Dict[str, Any] = field(default_factory=dict)
    base_params: Dict[str, int] = field(default_factory=dict)

    @property
    def size(self) -> int:
        n = 1
        for vals in self.axes.values():
            n *= max(len(vals), 1)
        for vals in self.param_axes.values():
            n *= max(len(vals), 1)
        return n

    def __len__(self) -> int:
        return self.size

    # ------------------------------------------------------------------ #
    def point(self, kw: Mapping[str, Any],
              params: Mapping[str, int]) -> DesignPoint:
        merged_kw = dict(self.base_kw)
        merged_kw.update(kw)
        merged_params = dict(self.base_params)
        merged_params.update(params)
        return DesignPoint.make(self.design, merged_kw,
                                merged_params or None)

    def grid(self) -> List[DesignPoint]:
        """Full Cartesian product of all axes, in axis-definition
        order."""
        kw_keys = list(self.axes)
        p_keys = list(self.param_axes)
        out: List[DesignPoint] = []
        kw_vals = [self.axes[k] for k in kw_keys]
        p_vals = [self.param_axes[k] for k in p_keys]
        for combo in itertools.product(*kw_vals, *p_vals):
            kw = dict(zip(kw_keys, combo[:len(kw_keys)]))
            params = dict(zip(p_keys, combo[len(kw_keys):]))
            out.append(self.point(kw, params))
        return out

    def random(self, n: int, seed: int = 0) -> List[DesignPoint]:
        """Random subsample of the grid: ``n`` distinct points,
        deterministic in ``seed``.

        The draw depends only on (axes, seed): ``random.Random`` is
        stable across processes and platforms (unlike ``hash``-seeded
        orderings), so sharded sweep workers and cache keys agree on
        the same points.  ``n`` is clamped to the space size;
        duplicates are rejected, so the result is always
        collision-free (every label unique) and a subset of
        ``grid()``."""
        n = min(n, self.size)
        if n <= 0:
            return []
        rng = random.Random(seed)
        if self.size <= max(n * 4, 64):
            pts = self.grid()
            rng.shuffle(pts)
            return pts[:n]
        out: List[DesignPoint] = []
        seen = set()
        # n <= size/4 here, so each i.i.d. draw collides with
        # probability < 1/4 and the bounded loop cannot realistically
        # exhaust; the cap turns a logic error into a loud failure
        # instead of a hang
        budget = 64 * n + 256
        while len(out) < n and budget > 0:
            budget -= 1
            kw = {k: rng.choice(list(v)) for k, v in self.axes.items()}
            params = {k: rng.choice(list(v))
                      for k, v in self.param_axes.items()}
            pt = self.point(kw, params)
            if pt in seen:
                continue
            seen.add(pt)
            out.append(pt)
        if len(out) < n:
            raise RuntimeError(
                f"DesignSpace.random drew {len(out)}/{n} distinct "
                f"points from a size-{self.size} space before "
                f"exhausting its draw budget")
        return out

    def overrides(self, per_point: Iterable[Mapping[str, Any]]
                  ) -> List[DesignPoint]:
        """Explicit per-point spec-kw override dicts (param overrides
        under the reserved key ``'params'``)."""
        out: List[DesignPoint] = []
        for ov in per_point:
            ov = dict(ov)
            params = ov.pop("params", {})
            out.append(self.point(ov, params))
        return out
