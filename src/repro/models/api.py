"""Family-dispatching model API.

Every architecture family exposes the same four entry points so the
launcher / dry-run / trainer are family-agnostic:

    init(cfg, key)                      -> params
    loss_fn(cfg, params, batch)         -> scalar loss
    init_cache(cfg, batch, max_len)     -> decode cache pytree
    serve_step(cfg, params, cache, token, pos) -> (logits, cache)

Batch layout per family:
    dense/moe/ssm/hybrid: {tokens [b,s] int32, labels [b,s] int32}
    vlm:                  + patches [b, n_patches, d_model]
    encdec:               + frames  [b, enc_frames, d_model]
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, moe, ssm, transformer

Params = Dict[str, Any]


def _mod(cfg: ModelConfig):
    return {
        "dense": transformer,
        "vlm": transformer,
        "moe": moe,
        "ssm": ssm,
        "hybrid": hybrid,
        "encdec": encdec,
    }[cfg.family]


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    return _mod(cfg).init(cfg, key)


def loss_fn(cfg: ModelConfig, params: Params,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    m = _mod(cfg)
    if cfg.family == "vlm":
        return transformer.loss_fn(cfg, params, batch)
    return m.loss_fn(cfg, params, batch)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    return _mod(cfg).init_cache(cfg, batch, max_len, dtype)


def serve_step(cfg: ModelConfig, params: Params, cache: Params,
               token: jnp.ndarray, pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, Params]:
    return _mod(cfg).serve_step(cfg, params, cache, token, pos)


def make_batch(cfg: ModelConfig, key: jax.Array, batch: int,
               seq: int) -> Dict[str, jnp.ndarray]:
    """Random batch with the family's layout (smoke tests / examples)."""
    k1, k2, k3 = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab),
        "labels": jax.random.randint(k2, (batch, seq), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            k3, (batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        # labels cover only the token positions
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            k3, (batch, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return out


def param_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params))
