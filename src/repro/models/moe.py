"""Mixture-of-Experts transformer (grok-1, qwen2-moe).

MoE dispatch is the framework's instantiation of TeAAL's
*uniform-occupancy leader-follower partitioning* (DESIGN.md): the
router output is the leader tensor; tokens (the followers) are split
into equal-occupancy partitions per expert (capacity), and the
expert-parallel all-to-all is the online rank swizzle
[token, expert] -> [expert, token].

Supports shared (always-on) experts (qwen2-moe: 4 shared + 60 routed
top-4) and pure top-k routing (grok-1: 8 experts top-2).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.sharding.logical import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------- #
# expert FFN params (stacked over experts -> shard on the expert axis)
# ---------------------------------------------------------------------- #
def init_experts(cfg: ModelConfig, key: jax.Array, n: int,
                 d_expert: int) -> Params:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "w_in": (jax.random.normal(k1, (n, d, d_expert)) * s).astype(dt),
        "w_out": (jax.random.normal(k2, (n, d_expert, d))
                  / math.sqrt(d_expert)).astype(dt),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k3, (n, d, d_expert)) * s
                       ).astype(dt)
    return p


def padded_expert_count(n_experts: int, tp: int = 16) -> int:
    """Perf iteration 3 (REFUTED, kept for the record -- see
    EXPERIMENTS.md SPerf): padding experts to a mesh multiple so the
    dispatch buffers shard on the expert axis measured 4-6x WORSE than
    capacity-axis-only sharding -- the token->buffer scatter across a
    model-sharded expert dim forces replicated scatter operands.  The
    shipped configuration shards the capacity axis only (iteration 2),
    so this returns ``n_experts`` unchanged."""
    return n_experts


def init_moe_layer(cfg: ModelConfig, key: jax.Array) -> Params:
    m = cfg.moe
    d_expert = m.d_expert or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    e_pad = padded_expert_count(m.n_experts)
    p: Params = {
        "router": (jax.random.normal(k1, (cfg.d_model, m.n_experts))
                   * 0.02).astype(jnp.float32),
        "experts": init_experts(cfg, k2, e_pad, d_expert),
    }
    if m.n_shared:
        p["shared"] = init_experts(cfg, k3, m.n_shared, d_expert)
    return p


# ---------------------------------------------------------------------- #
# dispatch: occupancy-equalized expert capacity (leader-follower)
# ---------------------------------------------------------------------- #
def route(logits: jnp.ndarray, top_k: int, capacity: int
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Router logits [t, e] -> (expert_id [t*k], slot [t*k], keep [t*k],
    gate [t*k]).

    ``slot`` is each (token, k)-assignment's arrival position within its
    expert -- the *occupancy coordinate* of TeAAL's leader-follower
    partitioning (the router output is the leader; capacity is the
    partition boundary; assignments past it are dropped).  O(t*e)
    memory -- no [t, e, c] one-hot tables (those are O(t^2) at scale).
    """
    t, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # [t, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True),
                                     1e-9)
    eid = gate_idx.reshape(t * top_k)
    onehot = jax.nn.one_hot(eid, e, dtype=jnp.float32)      # [t*k, e]
    pos = jnp.cumsum(onehot, axis=0) - onehot               # arrival order
    slot = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # [t*k]
    keep = slot < capacity
    return eid, slot, keep, gate_vals.reshape(t * top_k)


def expert_ffn(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """x: [e, c, d] or [e, g, c, d] -> same shape, batched over experts
    (g = dispatch groups, sharded over the data axis)."""
    if x.ndim == 4:
        eq_in, eq_out = "egcd,edf->egcf", "egcf,efd->egcd"
        ax_h = ("experts", "expert_group", None, "ff")
        ax_o = ("experts", "expert_group", None, None)
    else:
        eq_in, eq_out = "ecd,edf->ecf", "ecf,efd->ecd"
        ax_h = ("experts", "expert_cap", "ff")
        ax_o = ("experts", "expert_cap", None)
    h = jnp.einsum(eq_in, x, p["w_in"])
    h = constrain(h, ax_h)
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum(eq_in, x, p["w_gate"])
        gate = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        h = gate * h
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum(eq_out, h, p["w_out"])
    return constrain(out, ax_o)


def moe_ffn(cfg: ModelConfig, p: Params, x: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [b, s, d] -> ([b, s, d], aux_loss).

    Scatter/gather dispatch (O(t*k*d) memory): tokens are scattered
    into per-expert capacity buffers at their occupancy slot, the
    expert FFNs run batched, and outputs are gathered back and
    gate-combined.  The token->expert-buffer scatter across the
    batch-sharded token axis and expert/capacity-sharded buffers is the
    expert-parallel all-to-all -- TeAAL's online rank swizzle
    [token, expert] -> [expert, slot].
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.n_experts
    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p["router"]

    # GROUP-LOCAL dispatch (perf iteration 8): tokens are routed within
    # ``g`` groups aligned to the data shards, so the token->buffer
    # scatter never crosses shards -- the cross-data partial-sum
    # all-reduce of the [e, c, d] buffers disappears entirely (the
    # expert weights are already all-gathered per layer by FSDP).
    # Capacity is per group (occupancy partition per shard).
    g = 16 if (t % 16 == 0 and t >= 16 * k) else 1
    tg = t // g
    capacity = max(1, int(m.capacity_factor * tg * k // e))
    capacity = -(-capacity // 64) * 64 if capacity > 64 else capacity

    lg = logits.reshape(g, tg, e)
    eid, slot, keep, gate = jax.vmap(
        lambda lx: route(lx, k, capacity))(lg)            # each [g, tg*k]

    tok_idx = jnp.arange(tg * k, dtype=jnp.int32) // k
    xg = xf.reshape(g, tg, d)
    xs = xg[:, tok_idx]                                     # [g, tg*k, d]
    xs = jnp.where(keep[..., None], xs, 0)
    slot_c = jnp.where(keep, slot, capacity)                # drop bucket

    # vmapped (BATCHED) scatter over the group dim: lowers with an
    # operand batch dim so SPMD keeps each group's scatter local to its
    # data shard (an explicit iota group index defeats that analysis
    # and re-introduces a cross-shard all-reduce of the buffers)
    def scatter_group(xs_g, eid_g, slot_g):
        bg = jnp.zeros((e, capacity + 1, d), x.dtype)
        return bg.at[eid_g, slot_g].add(xs_g, mode="drop")

    buf = jax.vmap(scatter_group)(xs, eid, slot_c)[:, :, :capacity]
    buf = constrain(buf, ("expert_group", "experts", None, None))

    out_buf = expert_ffn(cfg, p["experts"],
                         buf.transpose(1, 0, 2, 3))         # [e,g,c,d]
    out_buf = out_buf.transpose(1, 0, 2, 3)                 # [g,e,c,d]

    # combine: batched gather of each assignment's output
    y = jax.vmap(lambda ob, eg, sg: ob[eg, sg])(
        out_buf, eid, jnp.minimum(slot, capacity - 1))      # [g, tg*k, d]
    y = y * (gate * keep).astype(y.dtype)[..., None]
    out = jnp.sum(y.reshape(g, tg, k, d), axis=2).reshape(b, s, d)

    if m.n_shared:
        shared = expert_ffn(
            cfg, p["shared"],
            jnp.broadcast_to(xf[None], (m.n_shared, t, d)))
        out = out + shared.sum(0).reshape(b, s, d)
    # load-balance auxiliary loss (Switch-style)
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(eid.reshape(t * k), e, dtype=jnp.float32)
         * keep.reshape(t * k)[:, None]).reshape(t, k, e).sum(1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return constrain(out, ("batch", "seq", "embed")), aux


# ---------------------------------------------------------------------- #
# model assembly: transformer with MoE FFNs
# ---------------------------------------------------------------------- #
def init_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg),
        "attn": L.init_attention(cfg, k1),
        "ln2": L.init_rmsnorm(cfg),
        "moe": init_moe_layer(cfg, k2),
    }


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    ke, kl = jax.random.split(key)
    if cfg.scan_layers:
        blocks = jax.vmap(lambda k: init_block(cfg, k))(
            jax.random.split(kl, cfg.n_layers))
    else:
        blocks = [init_block(cfg, k)
                  for k in jax.random.split(kl, cfg.n_layers)]
    return {"embed": L.init_embedding(cfg, ke), "blocks": blocks,
            "ln_f": L.init_rmsnorm(cfg)}


def block_fwd(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = x + L.attention(cfg, p["attn"], L.norm(cfg, p["ln1"], x), pos)
    y, aux = moe_ffn(cfg, p["moe"], L.norm(cfg, p["ln2"], x))
    return x + y, aux


def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = L.embed(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        def body(carry, blk):
            y, a = carry
            y2, aux = block_fwd(cfg, blk, y, pos)
            return (y2, a + aux), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params["blocks"])
    else:
        bf = (jax.checkpoint(lambda blk, h: block_fwd(cfg, blk, h, pos))
              if cfg.remat else (lambda blk, h: block_fwd(cfg, blk, h, pos)))
        for blk in params["blocks"]:
            x, aux = bf(blk, x)
            aux_total = aux_total + aux
    x = L.norm(cfg, params["ln_f"], x)
    return L.lm_head(cfg, params["embed"], x), aux_total


def loss_fn(cfg: ModelConfig, params: Params,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    logits, aux = forward(cfg, params, batch["tokens"])
    return (L.softmax_xent(logits, batch["labels"])
            + cfg.moe.router_aux_weight * aux / cfg.n_layers)


# ---------------------------------------------------------------------- #
# decode
# ---------------------------------------------------------------------- #
init_cache = T.init_cache


def decode_block(cfg: ModelConfig, p: Params, x, ck, cv, pos):
    a, ck, cv = L.attention_decode(cfg, p["attn"],
                                   L.norm(cfg, p["ln1"], x), ck, cv, pos)
    x = x + a
    y, _ = moe_ffn(cfg, p["moe"], L.norm(cfg, p["ln2"], x))
    return x + y, ck, cv


def serve_step(cfg: ModelConfig, params: Params, cache: Params,
               token: jnp.ndarray, pos: jnp.ndarray):
    x = L.embed(cfg, params["embed"], token[:, None])
    if cfg.scan_layers:
        def body(carry, inp):
            blk, ck, cv = inp
            y, ck, cv = decode_block(cfg, blk, carry, ck, cv, pos)
            return y, (ck, cv)
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                             cache["v"]))
        cache = {"k": ks, "v": vs}
    else:
        ks, vs = [], []
        for i, blk in enumerate(params["blocks"]):
            x, ck, cv = decode_block(cfg, blk, x, cache["k"][i],
                                     cache["v"][i], pos)
            ks.append(ck)
            vs.append(cv)
        cache = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    x = L.norm(cfg, params["ln_f"], x)
    return L.lm_head(cfg, params["embed"], x)[:, 0], cache
