"""Decoder-only GQA transformer (granite, qwen3, qwen2, olmo, llava).

Layer stack is a ``jax.lax.scan`` over stacked parameters so 40-70
layer models lower to a compact HLO at 512 devices.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.logical import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------- #
# init
# ---------------------------------------------------------------------- #
def init_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg),
        "attn": L.init_attention(cfg, k1),
        "ln2": L.init_rmsnorm(cfg),
        "ffn": L.init_ffn(cfg, k2),
    }


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    ke, kl = jax.random.split(key)
    n = cfg.n_layers
    if cfg.scan_layers:
        blocks = jax.vmap(lambda k: init_block(cfg, k))(
            jax.random.split(kl, n))
    else:
        blocks = [init_block(cfg, k) for k in jax.random.split(kl, n)]
    return {
        "embed": L.init_embedding(cfg, ke),
        "blocks": blocks,
        "ln_f": L.init_rmsnorm(cfg),
    }


# ---------------------------------------------------------------------- #
# forward (train / prefill)
# ---------------------------------------------------------------------- #
def block_fwd(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              pos: jnp.ndarray) -> jnp.ndarray:
    if cfg.seq_parallel:
        # residual stream (and the norms) stay sequence-sharded; the
        # blocks all-gather on entry and reduce-scatter on exit
        x = constrain(x, ("batch", "sp", "embed"))
    x = x + L.attention(cfg, p["attn"], L.norm(cfg, p["ln1"], x), pos)
    x = x + L.ffn(cfg, p["ffn"], L.norm(cfg, p["ln2"], x))
    return x


def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            extra_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """tokens [b, s] -> logits [b, s(+p), vocab].  ``extra_embeds``
    (vlm patch stubs) are prepended to the token embeddings."""
    x = L.embed(cfg, params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        x = constrain(x, ("batch", "seq", "embed"))
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if cfg.scan_layers:
        def body(carry, blk):
            return block_fwd(cfg, blk, carry, pos), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        bf = (jax.checkpoint(lambda blk, h: block_fwd(cfg, blk, h, pos))
              if cfg.remat else (lambda blk, h: block_fwd(cfg, blk, h, pos)))
        for blk in params["blocks"]:
            x = bf(blk, x)
    x = L.norm(cfg, params["ln_f"], x)
    return L.lm_head(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]
            ) -> jnp.ndarray:
    logits = forward(cfg, params, batch["tokens"],
                     extra_embeds=batch.get("patches"))
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:     # vlm: drop patch positions
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    return L.softmax_xent(logits, labels)


# ---------------------------------------------------------------------- #
# decode (serve_step)
# ---------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    n, nkv, h = cfg.n_layers, cfg.n_kv_heads, cfg.hdim
    shape = (n, batch, max_len, nkv, h)
    return {"k": jnp.zeros(shape, dtype=dtype),
            "v": jnp.zeros(shape, dtype=dtype)}


def decode_block(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                 ck: jnp.ndarray, cv: jnp.ndarray, pos: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    a, ck, cv = L.attention_decode(cfg, p["attn"],
                                   L.norm(cfg, p["ln1"], x), ck, cv, pos)
    x = x + a
    x = x + L.ffn(cfg, p["ffn"], L.norm(cfg, p["ln2"], x))
    return x, ck, cv


def serve_step(cfg: ModelConfig, params: Params, cache: Params,
               token: jnp.ndarray, pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, Params]:
    """One decode step: token [b], pos [b] -> logits [b, vocab]."""
    x = L.embed(cfg, params["embed"], token[:, None])

    if cfg.scan_layers:
        def body(carry, inp):
            blk, ck, cv = inp
            y, ck, cv = decode_block(cfg, blk, carry, ck, cv, pos)
            return y, (ck, cv)
        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["k"],
                                    cache["v"]))
        cache = {"k": ks, "v": vs}
    else:
        ks, vs = [], []
        for i, blk in enumerate(params["blocks"]):
            x, ck, cv = decode_block(cfg, blk, x, cache["k"][i],
                                     cache["v"][i], pos)
            ks.append(ck)
            vs.append(cv)
        cache = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    x = L.norm(cfg, params["ln_f"], x)
    logits = L.lm_head(cfg, params["embed"], x)
    return logits[:, 0], cache
