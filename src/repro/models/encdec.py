"""Whisper-style encoder-decoder backbone  [arXiv:2212.04356].

The conv audio frontend is a STUB per the brief: the encoder consumes
precomputed frame embeddings ([b, enc_frames, d_model]) provided by
``input_specs()``.  The encoder is bidirectional; the decoder has
causal self-attention plus cross-attention into the encoder output.
Position handling uses RoPE in place of Whisper's learned absolute
embeddings (backbone-only fidelity; recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------------- #
# init
# ---------------------------------------------------------------------- #
def init_enc_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rmsnorm(cfg), "attn": L.init_attention(cfg, k1),
            "ln2": L.init_rmsnorm(cfg), "ffn": L.init_ffn(cfg, k2)}


def init_dec_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.init_rmsnorm(cfg), "self": L.init_attention(cfg, k1),
            "lnx": L.init_rmsnorm(cfg), "cross": L.init_attention(cfg, k2),
            "ln2": L.init_rmsnorm(cfg), "ffn": L.init_ffn(cfg, k3)}


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    ke, k1, k2 = jax.random.split(key, 3)
    if cfg.scan_layers:
        enc = jax.vmap(lambda k: init_enc_block(cfg, k))(
            jax.random.split(k1, cfg.enc_layers))
        dec = jax.vmap(lambda k: init_dec_block(cfg, k))(
            jax.random.split(k2, cfg.n_layers))
    else:
        enc = [init_enc_block(cfg, k)
               for k in jax.random.split(k1, cfg.enc_layers)]
        dec = [init_dec_block(cfg, k)
               for k in jax.random.split(k2, cfg.n_layers)]
    return {"embed": L.init_embedding(cfg, ke), "enc": enc, "dec": dec,
            "ln_enc": L.init_rmsnorm(cfg), "ln_f": L.init_rmsnorm(cfg)}


# ---------------------------------------------------------------------- #
# encoder
# ---------------------------------------------------------------------- #
def encode(cfg: ModelConfig, params: Params,
           frames: jnp.ndarray) -> jnp.ndarray:
    """frames: [b, enc_frames, d_model] (precomputed conv-stub output)."""
    x = frames
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def blk_fwd(p, h):
        h = h + L.attention(cfg, p["attn"], L.norm(cfg, p["ln1"], h), pos,
                            causal=False)
        return h + L.ffn(cfg, p["ffn"], L.norm(cfg, p["ln2"], h))

    if cfg.scan_layers:
        def body(carry, blk):
            return blk_fwd(blk, carry), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc"])
    else:
        bf = jax.checkpoint(blk_fwd) if cfg.remat else blk_fwd
        for blk in params["enc"]:
            x = bf(blk, x)
    return L.norm(cfg, params["ln_enc"], x)


# ---------------------------------------------------------------------- #
# decoder (teacher-forced)
# ---------------------------------------------------------------------- #
def _cross_kv(cfg: ModelConfig, p: Params, enc_out: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, _ = enc_out.shape
    nkv, h = cfg.n_kv_heads, cfg.hdim
    k = (enc_out @ p["wk"]).reshape(b, s, nkv, h)
    v = (enc_out @ p["wv"]).reshape(b, s, nkv, h)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(nkv, h)
        v = v + p["bv"].reshape(nkv, h)
    return k, v


def dec_block_fwd(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                  pos: jnp.ndarray, enc_out: jnp.ndarray) -> jnp.ndarray:
    x = x + L.attention(cfg, p["self"], L.norm(cfg, p["ln1"], x), pos)
    kv = _cross_kv(cfg, p["cross"], enc_out)
    x = x + L.attention(cfg, p["cross"], L.norm(cfg, p["lnx"], x), pos,
                        kv=kv)
    return x + L.ffn(cfg, p["ffn"], L.norm(cfg, p["ln2"], x))


def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            frames: jnp.ndarray) -> jnp.ndarray:
    enc_out = encode(cfg, params, frames)
    x = L.embed(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.scan_layers:
        def body(carry, blk):
            return dec_block_fwd(cfg, blk, carry, pos, enc_out), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec"])
    else:
        df = (jax.checkpoint(
            lambda blk, h: dec_block_fwd(cfg, blk, h, pos, enc_out))
            if cfg.remat
            else (lambda blk, h: dec_block_fwd(cfg, blk, h, pos, enc_out)))
        for blk in params["dec"]:
            x = df(blk, x)
    x = L.norm(cfg, params["ln_f"], x)
    return L.lm_head(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params: Params,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    logits = forward(cfg, params, batch["tokens"], batch["frames"])
    return L.softmax_xent(logits, batch["labels"])


# ---------------------------------------------------------------------- #
# decode: self-attn KV cache + precomputed cross-attn KV
# ---------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    n, nkv, h = cfg.n_layers, cfg.n_kv_heads, cfg.hdim
    return {
        "k": jnp.zeros((n, batch, max_len, nkv, h), dtype),
        "v": jnp.zeros((n, batch, max_len, nkv, h), dtype),
        # cross-attention K/V: computed once from the encoder output
        "xk": jnp.zeros((n, batch, cfg.enc_frames, nkv, h), dtype),
        "xv": jnp.zeros((n, batch, cfg.enc_frames, nkv, h), dtype),
    }


def prime_cache(cfg: ModelConfig, params: Params, cache: Params,
                frames: jnp.ndarray) -> Params:
    """Run the encoder and fill the cross-attention K/V."""
    enc_out = encode(cfg, params, frames)
    if cfg.scan_layers:
        def body(_, blk):
            k, v = _cross_kv(cfg, blk["cross"], enc_out)
            return 0, (k, v)
        _, (xk, xv) = jax.lax.scan(body, 0, params["dec"])
    else:
        ks = [_cross_kv(cfg, blk["cross"], enc_out)
              for blk in params["dec"]]
        xk = jnp.stack([k for k, _ in ks])
        xv = jnp.stack([v for _, v in ks])
    return {**cache, "xk": xk.astype(cache["xk"].dtype),
            "xv": xv.astype(cache["xv"].dtype)}


def _dec_block_step(cfg, p, x, ck, cv, xk, xv, pos):
    a, ck, cv = L.attention_decode(cfg, p["self"],
                                   L.norm(cfg, p["ln1"], x), ck, cv, pos)
    x = x + a
    x = x + L.attention(cfg, p["cross"], L.norm(cfg, p["lnx"], x),
                        pos[:, None], kv=(xk, xv))
    return x + L.ffn(cfg, p["ffn"], L.norm(cfg, p["ln2"], x)), ck, cv


def serve_step(cfg: ModelConfig, params: Params, cache: Params,
               token: jnp.ndarray, pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, Params]:
    x = L.embed(cfg, params["embed"], token[:, None])
    if cfg.scan_layers:
        def body(carry, inp):
            blk, ck, cv, xk, xv = inp
            y, ck, cv = _dec_block_step(cfg, blk, carry, ck, cv, xk, xv,
                                        pos)
            return y, (ck, cv)
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        cache = {**cache, "k": ks, "v": vs}
    else:
        ks, vs = [], []
        for i, blk in enumerate(params["dec"]):
            x, ck, cv = _dec_block_step(cfg, blk, x, cache["k"][i],
                                        cache["v"][i], cache["xk"][i],
                                        cache["xv"][i], pos)
            ks.append(ck); vs.append(cv)
        cache = {**cache, "k": jnp.stack(ks), "v": jnp.stack(vs)}
    x = L.norm(cfg, params["ln_f"], x)
    return L.lm_head(cfg, params["embed"], x)[:, 0], cache
