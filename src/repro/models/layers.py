"""Composable transformer building blocks (pure-JAX, pytree params).

Every block is a pair ``init_*`` (params) / ``apply`` function.  Blocks
honor the architectural options required by the assigned fleet:
qk_norm (qwen3), qkv bias (qwen2), non-parametric LayerNorm (olmo),
GQA with any kv-head count (MQA for granite), swiglu/gelu FFNs.

Sharding is expressed through logical-axis annotations
(:func:`repro.sharding.logical.constrain`), compiled to PartitionSpecs
by the TeAAL-mapping-driven rules in ``repro.sharding``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.logical import constrain

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------- #
# norms
# ---------------------------------------------------------------------- #
def init_rmsnorm(cfg: ModelConfig, dim: Optional[int] = None) -> Params:
    if cfg.nonparam_ln:
        return {}
    return {"scale": jnp.ones((dim or cfg.d_model,), dtype=jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if "scale" in p:
        y = y * p["scale"]
    return y.astype(dt)


def layernorm_np(x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Non-parametric LayerNorm (olmo): normalize, no scale/bias."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.nonparam_ln:
        return layernorm_np(x, cfg.norm_eps)
    return rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------- #
# rotary position embeddings
# ---------------------------------------------------------------------- #
def rope_freqs(cfg: ModelConfig) -> jnp.ndarray:
    h = cfg.hdim
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, h, 2,
                                                dtype=jnp.float32) / h))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray,
               freqs: jnp.ndarray) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; pos: [..., seq]."""
    angles = pos[..., :, None, None].astype(jnp.float32) * freqs  # [...,s,1,h/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    # cast each half BEFORE the concat: K/V resharding collectives sit
    # right after rope, and XLA otherwise gathers the f32 concat (2x
    # wire bytes) before the bf16 convert (perf iteration 11)
    dt = x.dtype
    out = jnp.concatenate([(x1 * cos - x2 * sin).astype(dt),
                           (x1 * sin + x2 * cos).astype(dt)], axis=-1)
    return out


# ---------------------------------------------------------------------- #
# attention (GQA, optional qk-norm / qkv-bias)
# ---------------------------------------------------------------------- #
def init_attention(cfg: ModelConfig, key: jax.Array) -> Params:
    d, h, nh, nkv = cfg.d_model, cfg.hdim, cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    dt = _dtype(cfg)
    p: Params = {
        "wq": (jax.random.normal(k1, (d, nh * h)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, nkv * h)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, nkv * h)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (nh * h, d)) * s).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * h,), dtype=dt)
        p["bk"] = jnp.zeros((nkv * h,), dtype=dt)
        p["bv"] = jnp.zeros((nkv * h,), dtype=dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((h,), dtype=jnp.float32)
        p["k_norm"] = jnp.ones((h,), dtype=jnp.float32)
    return p


def _qkv(cfg: ModelConfig, p: Params, x: jnp.ndarray,
         pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    nh, nkv, h = cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nh, h)
    k = k.reshape(b, s, nkv, h)
    v = v.reshape(b, s, nkv, h)
    if cfg.qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    freqs = rope_freqs(cfg)
    q = apply_rope(q, pos, freqs)
    k = apply_rope(k, pos, freqs)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    v = constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _attn_block(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                causal: bool, offset, scale: float) -> jnp.ndarray:
    """One query block: q [b, cq, nh, h] x k/v [b, sk, nh, h].

    The logits are constrained over ("heads", "kv_seq"): with the
    divisibility fallback this shards heads over `model` when the head
    count divides (grok/granite) and otherwise shards the KV sequence
    (qwen3/qwen2/llava) -- sequence-parallel attention, so the scores
    for one block never exceed ~1 GB/device at 32k context.
    """
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = constrain(logits, ("batch", "heads", None, "kv_seq"))
    if causal:
        qpos = offset + jnp.arange(q.shape[1])
        mask = qpos[:, None] >= jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", w, v)


def mha(cfg: ModelConfig, q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
        causal: bool = True,
        q_offset: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Reference attention: [b, sq, nh, h] x [b, sk, nkv, h].

    GQA keys/values are repeated to the full head count (a logical
    repeat XLA folds into the einsum) so sharding propagates through a
    plain 4D einsum -- the grouped 5D form breaks SPMD propagation.
    Long queries are processed in ``cfg.attn_chunk`` blocks under
    ``lax.map`` with an inner checkpoint, so only one block's scores
    are ever live (forward AND backward) -- the jnp analogue of the
    flash kernel's K1-temporal mapping.
    """
    b, sq, nh, h = q.shape
    _, sk, nkv, _ = k.shape
    if nh != nkv:
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    k = constrain(k, ("batch", "kv_seq", "heads", None))
    v = constrain(v, ("batch", "kv_seq", "heads", None))
    scale = 1.0 / math.sqrt(h)
    base = q_offset if q_offset is not None else 0

    chunk = cfg.attn_chunk
    if not chunk or sq <= chunk:
        return _attn_block(q, k, v, causal, base, scale)

    nq = -(-sq // chunk)
    pad = nq * chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qc = qp.reshape(b, nq, chunk, nh, h).transpose(1, 0, 2, 3, 4)
    offs = base + jnp.arange(nq) * chunk

    def body(args):
        qb, off = args
        return _attn_block(qb, k, v, causal, off, scale)

    out = jax.lax.map(jax.checkpoint(body), (qc, offs))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * chunk, nh, h)
    return out[:, :sq] if pad else out


def _res_axes(cfg: ModelConfig):
    """Residual-stream axes: sequence-sharded over `model` when
    Megatron-style sequence parallelism is on (perf iteration 12)."""
    return ("batch", "sp" if cfg.seq_parallel else "seq", "embed")


def attention(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              pos: jnp.ndarray, causal: bool = True,
              kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
              ) -> jnp.ndarray:
    """Full attention block (no cache).  ``kv`` overrides keys/values for
    cross-attention (whisper decoder)."""
    if cfg.seq_parallel:
        # the SP all-gather: un-shard seq before the column-parallel QKV
        x = constrain(x, ("batch", "seq", "embed"))
    b, s, d = x.shape
    q, k, v = _qkv(cfg, p, x, pos)
    if kv is not None:
        k, v = kv
        causal = False
    out = mha(cfg, q, k, v, causal=causal)
    out = out.reshape(b, s, cfg.n_heads * cfg.hdim)
    # cast at the row-parallel boundary so the partial-sum all-reduce
    # travels in bf16, not the f32 accumulator dtype (perf iter 10)
    return constrain((out @ p["wo"]).astype(x.dtype), _res_axes(cfg))


def attention_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     pos: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode with a KV cache.

    x: [b, 1, d]; cache_[kv]: [b, S, nkv, h]; pos: [b] absolute position.
    """
    b, _, d = x.shape
    q, k_new, v_new = _qkv(cfg, p, x, pos[:, None])
    idx = pos[:, None, None, None]
    oh = jax.nn.one_hot(pos, cache_k.shape[1], dtype=cache_k.dtype)
    cache_k = cache_k * (1 - oh)[..., None, None] \
        + oh[..., None, None] * k_new
    cache_v = cache_v * (1 - oh)[..., None, None] \
        + oh[..., None, None] * v_new
    cache_k = constrain(cache_k, ("batch", "kv_seq", "kv_heads", None))
    cache_v = constrain(cache_v, ("batch", "kv_seq", "kv_heads", None))
    # mask out cache slots beyond pos
    nh, nkv, h = cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    group = nh // nkv
    qr = q.reshape(b, 1, nkv, group, h)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qr, cache_k,
                        preferred_element_type=jnp.float32) / math.sqrt(h)
    valid = (jnp.arange(cache_k.shape[1])[None] <= pos[:, None])
    logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, cache_v).reshape(b, 1, nh * h)
    return out @ p["wo"], cache_k, cache_v


# ---------------------------------------------------------------------- #
# FFN
# ---------------------------------------------------------------------- #
def init_ffn(cfg: ModelConfig, key: jax.Array,
             d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    dt = _dtype(cfg)
    p = {
        "w_in": (jax.random.normal(k1, (d, f)) * s).astype(dt),
        "w_out": (jax.random.normal(k2, (f, d)) / math.sqrt(f)).astype(dt),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k3, (d, f)) * s).astype(dt)
    return p


def ffn(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.seq_parallel:
        x = constrain(x, ("batch", "seq", "embed"))
    h = x @ p["w_in"]
    h = constrain(h, ("batch", "seq", "ff"))
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    elif cfg.act == "geglu":                      # grok-1-style gated gelu
        h = jax.nn.gelu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return constrain((h @ p["w_out"]).astype(x.dtype), _res_axes(cfg))


# ---------------------------------------------------------------------- #
# embeddings / head
# ---------------------------------------------------------------------- #
def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab rounded up to a multiple of 256 so the vocab axis divides
    any production model-parallel degree (perf iteration 6: mamba2's
    50280 and whisper's 51865 are indivisible by 16, which replicated
    the full fp32 logits on every device -- the dominant HBM term).
    Pad logits are masked to -1e30 in lm_head."""
    return -(-cfg.vocab // 256) * 256


def init_embedding(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    pv = padded_vocab(cfg)
    p = {"tok": (jax.random.normal(k1, (pv, cfg.d_model))
                 * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(k2, (cfg.d_model, pv))
                     * 0.02).astype(dt)
    return p


def embed(cfg: ModelConfig, p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(p["tok"], tokens, axis=0)
    return constrain(x, ("batch", "seq", "embed"))


def lm_head(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Logits over the PADDED vocab (pad positions masked to -1e30 so
    softmax/xent/argmax are exact); callers may slice [..., :vocab]."""
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    logits = x @ w
    logits = constrain(logits, ("batch", "seq", "vocab"))
    pv = logits.shape[-1]
    if pv != cfg.vocab:
        mask = jnp.arange(pv) < cfg.vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Token-mean cross entropy in fp32.

    The gold logit is extracted with a masked reduction (not
    take_along_axis): an elementwise compare + sum keeps the vocab axis
    shardable under SPMD (a gather along a model-sharded vocab would
    force XLA to all-gather the full logits).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    gold = jnp.sum(jnp.where(labels[..., None] == vocab_iota, logits, 0.0),
                   axis=-1)
    return jnp.mean(logz - gold)
