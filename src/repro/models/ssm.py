"""Mamba2 SSD (state-space duality) layers  [arXiv:2405.21060].

The chunked SSD algorithm is this framework's instantiation of TeAAL's
*cascade-of-Einsums* decomposition (DESIGN.md): like the Toeplitz
expansion in the paper (Sec. 3.1), one monolithic recurrence

    Y[b, s, h, p] = sum_t<=s C[s] (prod decay) B[t] X[t]

is rewritten as a cascade over a partitioned S rank (uniform_shape
chunks):

    (1) intra-chunk:  Y_diag[c, l] = C[c, l] . L[c, l, l'] . B[c, l'] X[c, l']
    (2) chunk states: S[c]        = sum_l decay(l) B[c, l] X[c, l]
    (3) inter-chunk:  S'[c]       = scan over c (the carried recurrence)
    (4) state out:    Y_off[c, l] = C[c, l] . decay . S'[c-1]

Each stage is independently mappable -- stage (1) is the MXU-friendly
quadratic block (Pallas kernel ``ssd_chunk``), stages (2-4) are the
linear-cost recurrence.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.logical import constrain

Params = Dict[str, Any]


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    """(d_inner, n_heads, head_dim, d_state, conv_dim)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state          # x, B, C all pass the conv
    return d_in, nh, s.head_dim, s.d_state, conv_dim


# ---------------------------------------------------------------------- #
# init
# ---------------------------------------------------------------------- #
def init_mamba_layer(cfg: ModelConfig, key: jax.Array) -> Params:
    d = cfg.d_model
    d_in, nh, p, n, conv_dim = dims(cfg)
    s = cfg.ssm
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    proj_out = 2 * d_in + 2 * n + nh          # z, xBC, dt
    return {
        "w_in": (jax.random.normal(k1, (d, proj_out))
                 / math.sqrt(d)).astype(dt),
        "conv_w": (jax.random.normal(k2, (s.d_conv, conv_dim))
                   / math.sqrt(s.d_conv)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dtype=dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), dtype=jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.full((nh,), 1e-2, jnp.float32))),   # softplus^-1(0.01)
        "norm": jnp.ones((d_in,), dtype=jnp.float32),
        "w_out": (jax.random.normal(k3, (d_in, d))
                  / math.sqrt(d_in)).astype(dt),
    }


# ---------------------------------------------------------------------- #
# the SSD cascade (train / prefill)
# ---------------------------------------------------------------------- #
def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular segment sums: out[..., i, j] = sum_{j<k<=i} x[k];
    -inf above the diagonal (so exp() gives the causal decay mask)."""
    l = x.shape[-1]
    xx = jnp.repeat(x[..., None], l, axis=-1)          # [..., l, l]
    mask = jnp.tril(jnp.ones((l, l), bool), k=-1)
    xx = jnp.where(mask, xx, 0.0)
    out = jnp.cumsum(xx, axis=-2)
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
        chunk: int, init_state: Optional[jnp.ndarray] = None,
        use_kernel: bool = False
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked state-space dual form.

    x: [B, S, H, P] (pre-multiplied by dt); a: [B, S, H] (= A*dt, <=0);
    b, c: [B, S, N] (single group, broadcast over heads).
    Returns (y [B, S, H, P], final_state [B, H, P, N]).
    S must be a multiple of ``chunk``.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, H, P)
    ac = a.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)    # [B,H,nc,l]
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)

    a_cum = jnp.cumsum(ac, axis=-1)                           # [B,H,nc,l]

    # (1) intra-chunk (diagonal blocks) -- the quadratic, MXU-bound stage
    if use_kernel:
        from repro.kernels.ops import ssd_chunk
        y_diag = ssd_chunk(xc, ac, bc, cc)
    else:
        Lmask = jnp.exp(_segsum(ac))                          # [B,H,nc,l,l]
        g = jnp.einsum("bcln,bcsn->bcls", cc, bc,
                       preferred_element_type=jnp.float32)    # [B,nc,l,s]
        y_diag = jnp.einsum("bcls,bhcls,bcshp->bclhp",
                            g, Lmask, xc,
                            preferred_element_type=jnp.float32)

    # (2) per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)           # [B,H,nc,l]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        bc, decay_states, xc,
                        preferred_element_type=jnp.float32)   # [B,nc,H,P,N]

    # (3) inter-chunk recurrence (the carried scan over chunks)
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), states.dtype)
    chunk_decay = jnp.exp(a_cum[..., -1])                     # [B,H,nc]

    def step(carry, inp):
        s_c, d_c = inp                                        # [B,H,P,N],[B,H]
        new = carry * d_c[..., None, None] + s_c
        return new, carry                                     # emit state *before* this chunk

    final, prev_states = jax.lax.scan(
        step, init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # [B,nc,H,P,N]

    # (4) state->output conversion
    state_decay = jnp.exp(a_cum)                              # [B,H,nc,l]
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, final


def _conv1d(xbc: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
            state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Causal depthwise conv over time. xbc: [B, S, C]; w: [K, C].

    Uses one fused lax.conv (feature_group_count=C) -- the shift-and-sum
    form lowered to thousands of slice/multiply/add ops and was the #2
    HBM consumer of the mamba2 step (perf iteration 7)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(xbc.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[2])
    return jax.nn.silu(out + bias)


def mamba_layer(cfg: ModelConfig, pr: Params, x: jnp.ndarray,
                use_kernel: bool = False) -> jnp.ndarray:
    """Full-sequence forward.  x: [B, S, d_model]."""
    d_in, nh, p, n, conv_dim = dims(cfg)
    B, S, _ = x.shape
    zxbcdt = x @ pr["w_in"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    xbc = _conv1d(xbc, pr["conv_w"], pr["conv_b"])
    xs, b, c = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xs = constrain(xs.reshape(B, S, nh, p), ("batch", "seq", "heads", None))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + pr["dt_bias"])  # [B,S,nh]
    a = -jnp.exp(pr["A_log"]) * dt                                # [B,S,nh]
    # perf iteration 5: the big SSD streams (x*dt, B, C) travel in the
    # model dtype; the decay chain (a, cumsum, exp) and the einsum
    # accumulators stay fp32 (preferred_element_type in ssd()).
    xdt = (xs.astype(jnp.float32) * dt[..., None]).astype(x.dtype)

    y, _ = ssd(xdt, a, b, c, cfg.ssm.chunk, use_kernel=use_kernel)
    y = y + pr["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_in)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rmsnorm({"scale": pr["norm"]}, y, cfg.norm_eps)
    return (y.astype(x.dtype)) @ pr["w_out"]


# ---------------------------------------------------------------------- #
# single-token decode (linear recurrence)
# ---------------------------------------------------------------------- #
def init_layer_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    d_in, nh, p, n, conv_dim = dims(cfg)
    ssm_state = jnp.zeros((batch, nh, p, n), jnp.float32)
    conv_state = jnp.zeros((batch, cfg.ssm.d_conv - 1, conv_dim), dtype)
    return ssm_state, conv_state


def mamba_decode(cfg: ModelConfig, pr: Params, x: jnp.ndarray,
                 ssm_state: jnp.ndarray, conv_state: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [B, 1, d_model] -> (y, new_ssm_state, new_conv_state)."""
    d_in, nh, p, n, conv_dim = dims(cfg)
    B = x.shape[0]
    zxbcdt = x @ pr["w_in"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    xbc_out = _conv1d(xbc, pr["conv_w"], pr["conv_b"], state=conv_state)
    new_conv = jnp.concatenate([conv_state[:, 1:], xbc], axis=1)
    xs, b, c = jnp.split(xbc_out[:, 0], [d_in, d_in + n], axis=-1)
    xs = xs.reshape(B, nh, p).astype(jnp.float32)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + pr["dt_bias"])
    da = jnp.exp(-jnp.exp(pr["A_log"]) * dtv)                  # [B,nh]
    bx = (dtv[..., None] * xs)[..., None] \
        * b[:, None, None, :].astype(jnp.float32)              # [B,nh,p,n]
    new_state = ssm_state * da[..., None, None] + bx
    y = jnp.einsum("bhpn,bn->bhp", new_state,
                   c.astype(jnp.float32)) + pr["D"][None, :, None] * xs
    y = y.reshape(B, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rmsnorm({"scale": pr["norm"]}, y, cfg.norm_eps)
    return (y.astype(x.dtype)) @ pr["w_out"], new_state, new_conv


# ---------------------------------------------------------------------- #
# model assembly
# ---------------------------------------------------------------------- #
def init_block(cfg: ModelConfig, key: jax.Array) -> Params:
    return {"ln": L.init_rmsnorm(cfg), "mamba": init_mamba_layer(cfg, key)}


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    ke, kl = jax.random.split(key)
    if cfg.scan_layers:
        blocks = jax.vmap(lambda k: init_block(cfg, k))(
            jax.random.split(kl, cfg.n_layers))
    else:
        blocks = [init_block(cfg, k)
                  for k in jax.random.split(kl, cfg.n_layers)]
    return {"embed": L.init_embedding(cfg, ke), "blocks": blocks,
            "ln_f": L.init_rmsnorm(cfg)}


def block_fwd(cfg: ModelConfig, pr: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x + mamba_layer(cfg, pr["mamba"], L.norm(cfg, pr["ln"], x))


def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray
            ) -> jnp.ndarray:
    x = L.embed(cfg, params["embed"], tokens)
    if cfg.scan_layers:
        def body(carry, blk):
            return block_fwd(cfg, blk, carry), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        bf = (jax.checkpoint(lambda blk, h: block_fwd(cfg, blk, h))
              if cfg.remat else (lambda blk, h: block_fwd(cfg, blk, h)))
        for blk in params["blocks"]:
            x = bf(blk, x)
    x = L.norm(cfg, params["ln_f"], x)
    return L.lm_head(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params: Params,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    logits = forward(cfg, params, batch["tokens"])
    return L.softmax_xent(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    d_in, nh, p, n, conv_dim = dims(cfg)
    nl = cfg.n_layers
    return {
        "ssm": jnp.zeros((nl, batch, nh, p, n), jnp.float32),
        "conv": jnp.zeros((nl, batch, cfg.ssm.d_conv - 1, conv_dim), dtype),
    }


def serve_step(cfg: ModelConfig, params: Params, cache: Params,
               token: jnp.ndarray, pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, Params]:
    """SSM decode: O(1) in sequence length (no KV cache)."""
    x = L.embed(cfg, params["embed"], token[:, None])

    if cfg.scan_layers:
        def body(carry, inp):
            blk, ss, cs = inp
            y, ss, cs = _decode_block(cfg, blk, carry, ss, cs)
            return y, (ss, cs)
        x, (ssm_s, conv_s) = jax.lax.scan(
            body, x, (params["blocks"], cache["ssm"], cache["conv"]))
        cache = {"ssm": ssm_s, "conv": conv_s}
    else:
        sss, css = [], []
        for i, blk in enumerate(params["blocks"]):
            x, ss, cs = _decode_block(cfg, blk, x, cache["ssm"][i],
                                      cache["conv"][i])
            sss.append(ss)
            css.append(cs)
        cache = {"ssm": jnp.stack(sss), "conv": jnp.stack(css)}
    x = L.norm(cfg, params["ln_f"], x)
    return L.lm_head(cfg, params["embed"], x)[:, 0], cache


def _decode_block(cfg, blk, x, ss, cs):
    y, ss, cs = mamba_decode(cfg, blk["mamba"], L.norm(cfg, blk["ln"], x),
                             ss, cs)
    return x + y, ss, cs
