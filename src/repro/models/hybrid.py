"""Jamba-style hybrid Mamba+attention+MoE model  [arXiv:2403.19887].

The layer stack is organized into *superblocks* of ``cfg.hybrid_block``
layers (Jamba: 8).  Within a superblock, position ``hybrid_attn_idx``
(Jamba: 4) is an attention layer and all others are Mamba layers; the
FFN at odd positions is MoE and at even positions dense
(``moe_every=2``).  Every superblock has an identical pytree structure,
so the model scans over superblocks (72 layers = 9 identical
superblocks), keeping the 512-device HLO compact.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = Dict[str, Any]


def n_superblocks(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.hybrid_block == 0
    return cfg.n_layers // cfg.hybrid_block


def _is_attn(cfg: ModelConfig, pos: int) -> bool:
    return pos == cfg.hybrid_attn_idx


def _is_moe(cfg: ModelConfig, pos: int) -> bool:
    return cfg.moe is not None and pos % cfg.moe_every == cfg.moe_every - 1


# ---------------------------------------------------------------------- #
# init
# ---------------------------------------------------------------------- #
def init_superblock(cfg: ModelConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, cfg.hybrid_block)
    sb: Params = {}
    for i, k in enumerate(keys):
        k1, k2 = jax.random.split(k)
        layer: Params = {"ln1": L.init_rmsnorm(cfg),
                         "ln2": L.init_rmsnorm(cfg)}
        if _is_attn(cfg, i):
            layer["attn"] = L.init_attention(cfg, k1)
        else:
            layer["mamba"] = SSM.init_mamba_layer(cfg, k1)
        if _is_moe(cfg, i):
            layer["moe"] = MOE.init_moe_layer(cfg, k2)
        else:
            layer["ffn"] = L.init_ffn(cfg, k2)
        sb[f"layer{i}"] = layer
    return sb


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    ke, kl = jax.random.split(key)
    ns = n_superblocks(cfg)
    if cfg.scan_layers:
        blocks = jax.vmap(lambda k: init_superblock(cfg, k))(
            jax.random.split(kl, ns))
    else:
        blocks = [init_superblock(cfg, k) for k in jax.random.split(kl, ns)]
    return {"embed": L.init_embedding(cfg, ke), "blocks": blocks,
            "ln_f": L.init_rmsnorm(cfg)}


# ---------------------------------------------------------------------- #
# forward
# ---------------------------------------------------------------------- #
def superblock_fwd(cfg: ModelConfig, sb: Params, x: jnp.ndarray,
                   pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(cfg.hybrid_block):
        layer = sb[f"layer{i}"]
        h = L.norm(cfg, layer["ln1"], x)
        if _is_attn(cfg, i):
            x = x + L.attention(cfg, layer["attn"], h, pos)
        else:
            x = x + SSM.mamba_layer(cfg, layer["mamba"], h)
        h = L.norm(cfg, layer["ln2"], x)
        if _is_moe(cfg, i):
            y, aux = MOE.moe_ffn(cfg, layer["moe"], h)
            aux_total = aux_total + aux
        else:
            y = L.ffn(cfg, layer["ffn"], h)
        x = x + y
    return x, aux_total


def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = L.embed(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        def body(carry, sb):
            y, a = carry
            y2, aux = superblock_fwd(cfg, sb, y, pos)
            return (y2, a + aux), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params["blocks"])
    else:
        sf = (jax.checkpoint(lambda sb, h: superblock_fwd(cfg, sb, h, pos))
              if cfg.remat
              else (lambda sb, h: superblock_fwd(cfg, sb, h, pos)))
        for sb in params["blocks"]:
            x, aux = sf(sb, x)
            aux_total = aux_total + aux
    x = L.norm(cfg, params["ln_f"], x)
    return L.lm_head(cfg, params["embed"], x), aux_total


def loss_fn(cfg: ModelConfig, params: Params,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    logits, aux = forward(cfg, params, batch["tokens"])
    loss = L.softmax_xent(logits, batch["labels"])
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux / cfg.n_layers
    return loss


# ---------------------------------------------------------------------- #
# decode
# ---------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    ns = n_superblocks(cfg)
    n_mamba = cfg.hybrid_block - 1
    d_in, nh, p, n, conv_dim = SSM.dims(cfg)
    return {
        "k": jnp.zeros((ns, batch, max_len, cfg.n_kv_heads, cfg.hdim),
                       dtype),
        "v": jnp.zeros((ns, batch, max_len, cfg.n_kv_heads, cfg.hdim),
                       dtype),
        "ssm": jnp.zeros((ns, n_mamba, batch, nh, p, n), jnp.float32),
        "conv": jnp.zeros((ns, n_mamba, batch, cfg.ssm.d_conv - 1,
                           conv_dim), dtype),
    }


def superblock_decode(cfg: ModelConfig, sb: Params, x: jnp.ndarray,
                      ck, cv, ssm_s, conv_s, pos: jnp.ndarray):
    mi = 0
    new_ssm, new_conv = [], []
    for i in range(cfg.hybrid_block):
        layer = sb[f"layer{i}"]
        h = L.norm(cfg, layer["ln1"], x)
        if _is_attn(cfg, i):
            a, ck, cv = L.attention_decode(cfg, layer["attn"], h, ck, cv,
                                           pos)
            x = x + a
        else:
            y, ss, cs = SSM.mamba_decode(cfg, layer["mamba"], h,
                                         ssm_s[mi], conv_s[mi])
            new_ssm.append(ss)
            new_conv.append(cs)
            mi += 1
            x = x + y
        h = L.norm(cfg, layer["ln2"], x)
        if _is_moe(cfg, i):
            y, _ = MOE.moe_ffn(cfg, layer["moe"], h)
        else:
            y = L.ffn(cfg, layer["ffn"], h)
        x = x + y
    return x, ck, cv, jnp.stack(new_ssm), jnp.stack(new_conv)


def serve_step(cfg: ModelConfig, params: Params, cache: Params,
               token: jnp.ndarray, pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, Params]:
    x = L.embed(cfg, params["embed"], token[:, None])
    if cfg.scan_layers:
        def body(carry, inp):
            sb, ck, cv, ss, cs = inp
            y, ck, cv, ss, cs = superblock_decode(cfg, sb, carry, ck, cv,
                                                  ss, cs, pos)
            return y, (ck, cv, ss, cs)
        x, (ks, vs, sss, css) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"],
                      cache["ssm"], cache["conv"]))
        cache = {"k": ks, "v": vs, "ssm": sss, "conv": css}
    else:
        ks, vs, sss, css = [], [], [], []
        for i, sb in enumerate(params["blocks"]):
            x, ck, cv, ss, cs = superblock_decode(
                cfg, sb, x, cache["k"][i], cache["v"][i],
                cache["ssm"][i], cache["conv"][i], pos)
            ks.append(ck); vs.append(cv); sss.append(ss); css.append(cs)
        cache = {"k": jnp.stack(ks), "v": jnp.stack(vs),
                 "ssm": jnp.stack(sss), "conv": jnp.stack(css)}
    x = L.norm(cfg, params["ln_f"], x)
    return L.lm_head(cfg, params["embed"], x)[:, 0], cache
