"""Unified telemetry: hierarchical spans, metrics, trace export.

Disabled by default -- ``active_tracer()`` is ``None`` until a caller
installs a :class:`Tracer` (``set_tracer`` / ``trace_session`` / the
benchmark CLIs' ``--trace``), and every instrumentation site in the
execution layer no-ops on a single global read in that state.  See
DESIGN.md, "Telemetry contract".
"""
from repro.obs.export import (chrome_trace, summarize_trace, to_jsonl,
                              write_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram,
                               MetricsRegistry, metrics)
from repro.obs.spans import (NULL_SPAN, Span, Tracer, active_tracer,
                             maybe_span, set_tracer, trace_session,
                             traced)

__all__ = [
    "Tracer", "Span", "NULL_SPAN", "active_tracer", "set_tracer",
    "maybe_span", "trace_session", "traced",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "metrics",
    "chrome_trace", "to_jsonl", "write_trace", "summarize_trace",
]
