"""Trace/metrics exporters: Chrome trace JSON, JSONL, text summary.

``chrome_trace`` produces the Chrome-trace-event JSON object format
(https://ui.perfetto.dev loads it directly): spans are ``ph == "X"``
complete events with microsecond ``ts``/``dur``, downgrades / guard
trips / injected faults are ``ph == "i"`` instant events, and a
metadata event names the process.  The active metrics snapshot rides
along under ``otherData`` so one file carries the whole telemetry
story of a run.

``write_trace`` picks the format from the filename: ``*.jsonl`` gets
one event per line (streaming-friendly structured log), anything else
gets the Chrome JSON object.
"""
from __future__ import annotations

import json
import sys
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

from repro.obs.metrics import MetricsRegistry, metrics
from repro.obs.spans import Tracer, trace_session

__all__ = [
    "chrome_trace", "to_jsonl", "write_trace", "summarize_trace",
    "cli_trace",
]


def _sorted_events(tracer: Tracer) -> List[Dict[str, Any]]:
    with tracer._lock:
        evs = list(tracer.events)
    return sorted(evs, key=lambda e: (e["ts"], e["ph"] != "X"))


def chrome_trace(tracer: Tracer,
                 registry: Optional[MetricsRegistry] = None,
                 process_name: str = "repro") -> Dict[str, Any]:
    """Chrome-trace-event JSON object (Perfetto-loadable)."""
    reg = registry if registry is not None else metrics()
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": tracer._pid, "tid": 0,
        "ts": 0, "args": {"name": process_name},
    }]
    events.extend(_sorted_events(tracer))
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"metrics": reg.snapshot()},
    }


def to_jsonl(tracer: Tracer,
             registry: Optional[MetricsRegistry] = None) -> str:
    """One JSON event per line, time-ordered; final line is the
    metrics snapshot (``{"kind": "metrics", ...}``)."""
    reg = registry if registry is not None else metrics()
    lines = [json.dumps(ev, sort_keys=True)
             for ev in _sorted_events(tracer)]
    lines.append(json.dumps({"kind": "metrics", **reg.snapshot()},
                            sort_keys=True))
    return "\n".join(lines) + "\n"


def write_trace(path: Union[str, Path], tracer: Tracer,
                registry: Optional[MetricsRegistry] = None) -> Path:
    """Write the trace to ``path``; ``*.jsonl`` selects the JSONL
    structured log, anything else the Chrome JSON object."""
    p = Path(path)
    if p.suffix == ".jsonl":
        p.write_text(to_jsonl(tracer, registry))
    else:
        p.write_text(json.dumps(chrome_trace(tracer, registry),
                                indent=1) + "\n")
    return p


@contextmanager
def cli_trace(path: Optional[Union[str, Path]]):
    """``--trace PATH`` plumbing shared by the benchmark drivers:
    installs a fresh process-wide tracer for the block, writes the
    trace file on exit (even on error), and prints the text summary
    to stderr.  A ``None`` path makes the whole thing a no-op, so
    drivers can wrap their body unconditionally."""
    if path is None:
        yield None
        return
    with trace_session() as tr:
        try:
            yield tr
        finally:
            p = write_trace(path, tr)
            print(f"# wrote trace {p} "
                  f"({len(tr.events)} events; load in "
                  f"https://ui.perfetto.dev)", file=sys.stderr)
            print(summarize_trace(tr), file=sys.stderr)


def summarize_trace(tracer: Tracer,
                    registry: Optional[MetricsRegistry] = None) -> str:
    """Text summary: span wall time by category/name, instant-event
    tallies, then the metrics table."""
    reg = registry if registry is not None else metrics()
    by_name: Dict[tuple, List[float]] = {}
    inst: Dict[tuple, int] = {}
    for ev in _sorted_events(tracer):
        if ev["ph"] == "X":
            # stage/seam spans repeat per einsum -- aggregate on the
            # name up to the first ':' plus the label after it
            by_name.setdefault((ev["cat"], ev["name"]), []).append(
                ev.get("dur", 0.0))
        elif ev["ph"] == "i":
            key = (ev["cat"], ev["name"])
            inst[key] = inst.get(key, 0) + 1
    lines = [f"{'span (cat:name)':<52} {'count':>6} {'total_ms':>10} "
             f"{'mean_us':>10}"]
    for (cat, name), durs in sorted(
            by_name.items(), key=lambda kv: -sum(kv[1])):
        total_ms = sum(durs) / 1e3
        mean_us = sum(durs) / len(durs)
        lines.append(f"{cat + ':' + name:<52} {len(durs):>6} "
                     f"{total_ms:>10.3f} {mean_us:>10.1f}")
    if inst:
        lines.append("")
        lines.append(f"{'instant (cat:name)':<52} {'count':>6}")
        for (cat, name), n in sorted(inst.items()):
            lines.append(f"{cat + ':' + name:<52} {n:>6}")
    lines.append("")
    lines.append(reg.summary_table())
    return "\n".join(lines)
