"""Process-wide metrics registry: counters, gauges, histograms.

Naming scheme (see DESIGN.md "Telemetry contract"): dotted component
prefix, ``/``-separated label suffix --

    kernel.seam_seconds/<seam>/<backend>     histogram (seam latency)
    kernel.downgrade/<action>                counter   (retry/downgrade/
                                                        demote/unavailable)
    guards.violation/<check>                 counter
    vector.stage_seconds/<stage>             counter   (float seconds)
    dse.point/<status>                       counter   (ok/restored/...)
    dse.point_attempts                       counter
    dse.plan_cache/{hit,miss}                counter
    dse.result_cache/{hit,miss}              counter   (served without
                                                        the backend)
    dse.service/{requests,batches,           counter   (sweep-service
                 coalesced,rejected}                    front-end)
    dse.service/batch_size                   histogram (requests per
                                                        micro-batch)

Counters accept float increments (stage seconds accumulate into a
counter rather than a histogram: the per-stage distribution is already
on the trace as spans).  Histograms use fixed bucket upper bounds so
merging snapshots never re-bins.

The registry is cheap but not free; rare-event sites (downgrades,
guard violations, sweep points) update it unconditionally, while
per-seam latency observation only happens when a tracer is active --
that keeps the disabled hot path allocation-free.
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "metrics",
    "DEFAULT_LATENCY_BUCKETS",
]

#: seconds; spans ~1us .. ~1s, the range of a guarded seam call
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0,
)


class Counter:
    """Monotonically increasing value (float increments allowed)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: counts per upper bound + overflow.

    ``buckets`` are inclusive upper bounds; an observation greater
    than the last bound lands in the overflow bucket (reported as
    ``+Inf`` in snapshots).
    """

    __slots__ = ("name", "buckets", "counts", "total", "sum", "_lock")

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        self.name = name
        self.buckets: Tuple[float, ...] = tuple(buckets)
        self.counts: List[int] = [0] * (len(self.buckets) + 1)
        self.total = 0
        self.sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        idx = bisect_left(self.buckets, v)
        with self._lock:
            self.counts[idx] += 1
            self.total += 1
            self.sum += v

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "buckets": list(self.buckets) + ["+Inf"],
                "counts": list(self.counts),
                "count": self.total,
                "sum": round(self.sum, 9),
            }


class MetricsRegistry:
    """Named metric store; instruments are created on first use."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- accessors -----------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    name,
                    Histogram(name, buckets or DEFAULT_LATENCY_BUCKETS))
        return h

    # -- export --------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable view of every instrument."""
        with self._lock:
            counters = {n: c.value for n, c in self._counters.items()}
            gauges = {n: g.value for n, g in self._gauges.items()}
            hists = dict(self._histograms)
        return {
            "counters": {n: counters[n] for n in sorted(counters)},
            "gauges": {n: gauges[n] for n in sorted(gauges)},
            "histograms": {n: hists[n].snapshot() for n in sorted(hists)},
        }

    def summary_table(self) -> str:
        """Human-readable fixed-width table of the registry state."""
        snap = self.snapshot()
        lines: List[str] = []
        if snap["counters"]:
            lines.append(f"{'counter':<44} {'value':>14}")
            for name, v in snap["counters"].items():
                sval = f"{v:.6f}".rstrip("0").rstrip(".") \
                    if v != int(v) else str(int(v))
                lines.append(f"{name:<44} {sval:>14}")
        if snap["gauges"]:
            lines.append(f"{'gauge':<44} {'value':>14}")
            for name, v in snap["gauges"].items():
                lines.append(f"{name:<44} {v:>14.6g}")
        if snap["histograms"]:
            lines.append(
                f"{'histogram':<44} {'count':>8} {'sum':>12} "
                f"{'mean':>12}")
            for name, h in snap["histograms"].items():
                mean = h["sum"] / h["count"] if h["count"] else 0.0
                lines.append(f"{name:<44} {h['count']:>8} "
                             f"{h['sum']:>12.6f} {mean:>12.3e}")
        return "\n".join(lines) if lines else "(no metrics recorded)"

    def reset(self) -> None:
        """Drop every instrument (test isolation hook)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


#: the process-wide registry every instrumentation site writes to
_REGISTRY = MetricsRegistry()


def metrics() -> MetricsRegistry:
    """The process-wide :class:`MetricsRegistry`."""
    return _REGISTRY
