"""Hierarchical spans with a process-wide no-op default.

The execution layer is instrumented at four nesting levels::

    cascade:<spec>                  CascadeSimulator.run
      einsum:<output>               one mapped Einsum on a backend
        stage:<name>                vector-pipeline stage (materialize,
                                    pair-merge, lookup, finalize,
                                    reduce, output-build)
          seam:<name>               one guarded kernel-dispatch call

Tracing is **off by default**: ``active_tracer()`` returns ``None``
and every instrumentation site is a single cached-global read plus a
``None`` check (the same pattern the fault injector and guard knob
use in ``kernels/backends.py``), so the hot path stays at the
committed ``vector_rate`` when disabled.  ``maybe_span`` returns the
shared :data:`NULL_SPAN` singleton in that case -- no allocation on
the disabled path (asserted by ``tests/test_obs.py`` with
``tracemalloc``).

A :class:`Tracer` collects finished spans as Chrome-trace-event
dictionaries (``ph == "X"`` complete events, microsecond ``ts`` /
``dur`` relative to tracer start) plus instant events (``ph == "i"``)
for downgrades, guard trips, and injected faults.  Nesting is tracked
per-thread: each span records its parent span's name in
``args["parent"]`` so tests (and humans) can assert the hierarchy
without reconstructing it from time windows.  All mutation of the
shared event list is lock-protected -- the DSE engine traces from
worker threads.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = [
    "Tracer", "Span", "NULL_SPAN", "active_tracer", "set_tracer",
    "maybe_span", "trace_session", "traced",
]

#: process-wide active tracer; ``None`` = telemetry disabled
_TRACER: Optional["Tracer"] = None


def active_tracer() -> Optional["Tracer"]:
    """The installed :class:`Tracer`, or ``None`` when disabled."""
    return _TRACER


def set_tracer(tracer: Optional["Tracer"]) -> Optional["Tracer"]:
    """Install (or, with ``None``, remove) the process-wide tracer.

    Returns the previously installed tracer so callers can restore it.
    """
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


class _NullSpan:
    """Reusable no-op span: one shared instance, allocation-free."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, key: str, value: Any) -> None:
        pass


#: the shared disabled-path span (identity-tested by the overhead test)
NULL_SPAN = _NullSpan()


def maybe_span(name: str, cat: str = "",
               args: Optional[Dict[str, Any]] = None):
    """A span on the active tracer, or :data:`NULL_SPAN` when tracing
    is disabled.  The disabled path allocates nothing."""
    tr = _TRACER
    if tr is None:
        return NULL_SPAN
    return tr.span(name, cat, args)


class Span:
    """An open span; close via context-manager exit.

    ``set(key, value)`` attaches an arg visible in the exported trace
    (usable both while open and from the ``with`` body).
    """

    __slots__ = ("tracer", "name", "cat", "args", "_start_us", "parent")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[Dict[str, Any]]):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args: Dict[str, Any] = dict(args) if args else {}
        self._start_us = 0.0
        self.parent: Optional[str] = None

    def set(self, key: str, value: Any) -> None:
        self.args[key] = value

    def __enter__(self) -> "Span":
        tr = self.tracer
        stack = tr._stack()
        self.parent = stack[-1] if stack else None
        stack.append(self.name)
        self._start_us = tr.now_us()
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        tr = self.tracer
        end = tr.now_us()
        stack = tr._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        if self.parent is not None:
            self.args.setdefault("parent", self.parent)
        tr.add_span(self.name, self.cat, self._start_us,
                    end - self._start_us, self.args or None)
        return False


class Tracer:
    """Collects Chrome-trace events; thread-safe, microsecond clock.

    ``events`` is a list of finished trace-event dicts (``ph`` in
    ``{"X", "i"}``).  Timestamps are relative to tracer creation so a
    trace always starts near ``ts == 0``.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        self._local = threading.local()
        self._pid = os.getpid()
        self.events: List[Dict[str, Any]] = []

    # -- clock ---------------------------------------------------------
    def now_us(self) -> float:
        """Microseconds since tracer creation (monotonic)."""
        return (self._clock() - self._t0) * 1e6

    # -- per-thread nesting stack --------------------------------------
    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = []
            self._local.stack = stack
        return stack

    def current_span_name(self) -> Optional[str]:
        stack = self._stack()
        return stack[-1] if stack else None

    # -- span / event emission -----------------------------------------
    def span(self, name: str, cat: str = "",
             args: Optional[Dict[str, Any]] = None) -> Span:
        """An open :class:`Span`; use as a context manager."""
        return Span(self, name, cat, args)

    def add_span(self, name: str, cat: str, ts_us: float, dur_us: float,
                 args: Optional[Dict[str, Any]] = None,
                 tid: Optional[int] = None) -> None:
        """Record a finished span directly (used both by :class:`Span`
        and to synthesize stage spans from accumulated stage timers)."""
        ev: Dict[str, Any] = {
            "name": name, "cat": cat or "span", "ph": "X",
            "ts": round(ts_us, 3), "dur": round(max(dur_us, 0.0), 3),
            "pid": self._pid,
            "tid": tid if tid is not None else threading.get_ident(),
        }
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    def instant(self, name: str, cat: str = "",
                args: Optional[Dict[str, Any]] = None,
                ts_us: Optional[float] = None) -> None:
        """Record an instant event (downgrade, guard trip, fault)."""
        ev: Dict[str, Any] = {
            "name": name, "cat": cat or "event", "ph": "i",
            "ts": round(self.now_us() if ts_us is None else ts_us, 3),
            "pid": self._pid, "tid": threading.get_ident(),
            "s": "t",
        }
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    # -- queries (tests / summaries) -----------------------------------
    def spans(self, cat: Optional[str] = None) -> List[Dict[str, Any]]:
        with self._lock:
            evs = list(self.events)
        return [e for e in evs if e["ph"] == "X"
                and (cat is None or e["cat"] == cat)]

    def instants(self, cat: Optional[str] = None) -> List[Dict[str, Any]]:
        with self._lock:
            evs = list(self.events)
        return [e for e in evs if e["ph"] == "i"
                and (cat is None or e["cat"] == cat)]


class trace_session:
    """``with trace_session() as tr: ...`` -- install a fresh tracer
    for the block and restore the previous one after (used by the CLI
    ``--trace`` flags and by tests)."""

    def __init__(self, tracer: Optional[Tracer] = None):
        self.tracer = tracer if tracer is not None else Tracer()
        self._prev: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        self._prev = set_tracer(self.tracer)
        return self.tracer

    def __exit__(self, *exc: Any) -> bool:
        set_tracer(self._prev)
        return False


def traced(name: Optional[str] = None, cat: str = ""):
    """Decorator form: span around each call of the wrapped function
    (no-op when tracing is disabled)."""
    def deco(fn):
        span_name = name if name is not None else fn.__qualname__

        def wrapper(*a, **k):
            tr = _TRACER
            if tr is None:
                return fn(*a, **k)
            with tr.span(span_name, cat):
                return fn(*a, **k)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__wrapped__ = fn
        return wrapper
    return deco
