"""SIGMA [Qin et al., HPCA'20] as a TeAAL spec (paper Fig. 8c).

Deep-learning GEMM accelerator; A-stationary dataflow.  The cascade
pre-filters the stationary matrix: rows (K-fibers) of A whose matching
row of B is empty are removed before PEs are filled, so only useful
nonzeros occupy the (flexible, Benes-interconnected) PE array:

  S[k,m] = take(A[k,m], B[k,n], 0)   -- A where B's row k is non-empty
  T[k,m] = take(A[k,m], S[k,m], 0)   -- filtered stationary matrix
  Z[m,n] = T[k,m] * B[k,n]

Mapping (Fig. 8c): K split by shape 128 (the FlexDPE granularity),
(M, K0) flattened, and the flattened nonzeros distributed
16384-at-a-time (128 FlexDPEs x 128 PEs) by occupancy -- every PE gets
exactly one useful nonzero (SIGMA's headline feature).  MK00 is the
spatial rank; time is [K1, MK01, N.coord].

Hardware (Table 5): 500 MHz, 128 PEs per FlexDPE, 128 FlexDPEs, 32 MB
Data SRAM, 4 MB Bitmap SRAM, 960 GB/s SRAM bw, 1024 GB/s HBM bw.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.spec import AcceleratorSpec, load_spec

CLOCK_GHZ = 0.5
N_FLEXDPE = 128
PES_PER_DPE = 128
N_PES = N_FLEXDPE * PES_PER_DPE           # 16384
DRAM_GBS = 1024.0
SRAM_GBS = 960.0


def spec(k_tile: int = 128, stationary: int = N_PES,
         data_sram_mb: float = 32.0, bitmap_sram_mb: float = 4.0,
         dram_gbs: float = DRAM_GBS) -> AcceleratorSpec:
    d: Dict[str, Any] = {
        "name": "SIGMA",
        "einsum": {
            "declaration": {
                "A": ["K", "M"],
                "B": ["K", "N"],
                "S": ["K", "M"],
                "T": ["K", "M"],
                "Z": ["M", "N"],
            },
            "expressions": [
                "S[k, m] = take(A[k, m], B[k, n], 0)",
                "T[k, m] = take(A[k, m], S[k, m], 0)",
                "Z[m, n] = T[k, m] * B[k, n]",
            ],
        },
        "mapping": {
            "rank-order": {
                "A": ["K", "M"],
                "B": ["K", "N"],
                "S": ["K", "M"],
                "T": ["K", "M"],
                "Z": ["M", "N"],
            },
            "partitioning": {
                "Z": {
                    "K": [f"uniform_shape({k_tile})"],
                    "(M, K0)": ["flatten()"],
                    "MK0": [f"uniform_occupancy(T.{stationary})"],
                },
            },
            "loop-order": {
                "S": ["K", "M", "N"],
                "T": ["K", "M"],
                "Z": ["K1", "MK01", "MK00", "N"],
            },
            "spacetime": {
                "S": {"space": [], "time": ["K", "M", "N"]},
                "T": {"space": [], "time": ["K", "M"]},
                "Z": {"space": ["MK00"], "time": ["K1", "MK01", "N.coord"]},
            },
        },
        "format": {
            # SIGMA's bitmap format: B-type (uncompressed bitmap coords,
            # compressed payloads)
            "A": {"Bitmap": {"K": {"format": "B", "cbits": 1, "pbits": 32},
                             "M": {"format": "B", "cbits": 1, "pbits": 32}}},
            "B": {"Bitmap": {"K": {"format": "B", "cbits": 1, "pbits": 32},
                             "N": {"format": "B", "cbits": 1, "pbits": 32}}},
            "T": {"Bitmap": {"K1": {"format": "C", "cbits": 16, "pbits": 32},
                             "MK0": {"format": "B", "cbits": 1, "pbits": 32},
                             "K": {"format": "B", "cbits": 1, "pbits": 32},
                             "M": {"format": "B", "cbits": 1, "pbits": 32}}},
            "Z": {"Dense": {"M": {"format": "U", "cbits": 0, "pbits": 32},
                            "N": {"format": "U", "cbits": 0, "pbits": 32}}},
        },
        "architecture": {
            "clock_ghz": CLOCK_GHZ,
            "topologies": {
                "main": {
                    "name": "chip", "num": 1,
                    "local": [
                        {"name": "HBM", "class": "DRAM",
                         "bandwidth": dram_gbs},
                        {"name": "DataSRAM", "class": "Buffer",
                         "type": "buffet", "width": 64,
                         "depth": int(data_sram_mb * 1024 * 1024 / 64),
                         "bandwidth": SRAM_GBS},
                        {"name": "BitmapSRAM", "class": "Buffer",
                         "type": "buffet", "width": 64,
                         "depth": int(bitmap_sram_mb * 1024 * 1024 / 64),
                         "bandwidth": SRAM_GBS},
                        {"name": "FilterIsect", "class": "Intersection",
                         "type": "two_finger"},
                    ],
                    "subtree": [{
                        "name": "FlexDPE", "num": N_FLEXDPE,
                        "local": [],
                        "subtree": [{
                            "name": "PE", "num": PES_PER_DPE,
                            "local": [
                                {"name": "MulALU", "class": "Compute",
                                 "type": "mul"},
                                {"name": "AddTree", "class": "Compute",
                                 "type": "add"},
                            ],
                        }],
                    }],
                },
            },
        },
        "binding": {
            "S": {
                "topology": "main",
                "storage": [
                    {"component": "BitmapSRAM", "tensor": "A", "rank": "M",
                     "type": "coord", "config": "Bitmap", "style": "lazy"},
                    {"component": "BitmapSRAM", "tensor": "B", "rank": "N",
                     "type": "coord", "config": "Bitmap", "style": "lazy"},
                ],
                "compute": [],
            },
            "T": {
                "topology": "main",
                "storage": [],
                "compute": [],
            },
            "Z": {
                "topology": "main",
                "storage": [
                    # stationary nonzeros resident across the N stream
                    {"component": "DataSRAM", "tensor": "T", "rank": "MK00",
                     "type": "elem", "config": "Bitmap", "style": "lazy",
                     "evict-on": "MK01"},
                    {"component": "DataSRAM", "tensor": "B", "rank": "N",
                     "type": "elem", "config": "Bitmap", "style": "lazy"},
                ],
                "compute": [
                    {"component": "MulALU", "op": "mul"},
                    {"component": "AddTree", "op": "add"},
                ],
            },
        },
    }
    return load_spec(d)

def simulate(inputs, var_shapes, params=None, backend=None,
             model=True, semiring=None, **spec_kw):
    """Run this design on real tensors; delegates to
    repro.accelerators.simulate (``backend`` selects the execution
    engine: 'python' oracle | 'vector' columnar CSF | 'analytic'
    closed-form density model).

    The full cascade -- the take() filter pipeline, the K-tiled /
    (M, K0)-flattened / occupancy-distributed stationary matrix, and
    the leaf-bound output ranks -- lowers to the VectorPlan IR, so
    ``backend='vector'`` executes natively (``SimResult.fallback_reasons
    == {}``) instead of silently routing through the interpreter."""
    from repro.accelerators import simulate as _simulate

    return _simulate("sigma", inputs, var_shapes, params=params,
                     backend=backend, model=model, semiring=semiring,
                     **spec_kw)
