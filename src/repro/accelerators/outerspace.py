"""OuterSPACE [Pal et al., HPCA'18] as a TeAAL spec (paper Figs. 3, 5).

Outer-product SpMSpM in two phases:
  multiply: T[k,m,n] = A[k,m] * B[k,n]   (col of A x row of B)
  merge:    Z[m,n]   = T[k,m,n]          (sort + reduce linked lists)

Mapping (Fig. 3): the multiply phase flattens (K, M) and partitions the
nonzeros of A 256-at-a-time across 16 Processing Tiles x 16 PEs; the
merge phase partitions rows of T across 16 PTs x 8 PEs (half the PEs
are enabled during merge -- paper footnote 2).

Hardware (Table 5): 1.5 GHz, 16 PEs/PT, 16 PTs, 16 kB L0 cache per PT,
4 kB L1 cache per 4 PTs, 16 64-bit HBM channels @ 8000 MB/s.

Format: A is CSC, B is CSR (32-bit coords/values); T is the custom
array-of-linked-lists (Fig. 5c): an uncompressed array of list pointers
on M, coordinate/value nodes with next-pointers on (K,)N.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.spec import AcceleratorSpec, load_spec

# Table 5
CLOCK_GHZ = 1.5
N_PT = 16
PES_PER_PT = 16
MULTIPLY_PES = N_PT * PES_PER_PT          # 256
MERGE_PES = N_PT * (PES_PER_PT // 2)      # 128
DRAM_GBS = 16 * 8.0                       # 16 channels x 8000 MB/s


def spec(mult_batch: int = 256, mult_grp: int = 16,
         merge_batch: int = 128, merge_grp: int = 8,
         l0_kb: float = 16.0, l1_kb: float = 4.0,
         dram_gbs: float = DRAM_GBS) -> AcceleratorSpec:
    d: Dict[str, Any] = {
        "name": "OuterSPACE",
        "einsum": {
            "declaration": {
                "A": ["K", "M"],
                "B": ["K", "N"],
                "T": ["K", "M", "N"],
                "Z": ["M", "N"],
            },
            "expressions": [
                "T[k, m, n] = A[k, m] * B[k, n]",
                "Z[m, n] = T[k, m, n]",
            ],
        },
        "mapping": {
            "rank-order": {
                "A": ["K", "M"],          # CSC: offline swizzle of CSR A
                "B": ["K", "N"],
                "T": ["M", "K", "N"],
                "Z": ["M", "N"],
            },
            "partitioning": {
                "T": {
                    "(K, M)": ["flatten()"],
                    "KM": [f"uniform_occupancy(A.{mult_batch})",
                           f"uniform_occupancy(A.{mult_grp})"],
                },
                "Z": {
                    "M": [f"uniform_occupancy(T.{merge_batch})",
                          f"uniform_occupancy(T.{merge_grp})"],
                },
            },
            "loop-order": {
                "T": ["KM2", "KM1", "KM0", "N"],
                "Z": ["M2", "M1", "M0", "N", "K"],
            },
            "spacetime": {
                "T": {"space": ["KM1", "KM0"], "time": ["KM2", "N"]},
                "Z": {"space": ["M1", "M0"], "time": ["M2", "N", "K"]},
            },
        },
        "format": {
            "A": {"CSC": {"K": {"format": "C", "cbits": 32, "pbits": 32},
                          "M": {"format": "C", "cbits": 32, "pbits": 32}}},
            "B": {"CSR": {"K": {"format": "C", "cbits": 32, "pbits": 32},
                          "N": {"format": "C", "cbits": 32, "pbits": 32}}},
            "T": {"LinkedLists": {
                "M": {"format": "U", "cbits": 0, "pbits": 64},
                "K": {"format": "C", "cbits": 32, "pbits": 32},
                "N": {"format": "C", "cbits": 32, "pbits": 32,
                      "fhbits": 64, "layout": "interleaved"}}},
            "Z": {"CSR": {"M": {"format": "C", "cbits": 32, "pbits": 32},
                          "N": {"format": "C", "cbits": 32, "pbits": 32}}},
        },
        "architecture": {
            "clock_ghz": CLOCK_GHZ,
            "topologies": {
                "multiply": {
                    "name": "chip", "num": 1,
                    "local": [
                        {"name": "HBM", "class": "DRAM",
                         "bandwidth": dram_gbs},
                        {"name": "Seq", "class": "Sequencer",
                         "num_ranks": 4},
                    ],
                    "subtree": [{
                        "name": "PT", "num": N_PT,
                        "local": [
                            {"name": "L0", "class": "Buffer",
                             "type": "cache", "width": 64,
                             "depth": int(l0_kb * 1024 / 64)},
                        ],
                        "subtree": [{
                            "name": "PE", "num": PES_PER_PT,
                            "local": [
                                {"name": "MulALU", "class": "Compute",
                                 "type": "mul"},
                            ],
                        }],
                    }],
                },
                "merge": {
                    "name": "chip", "num": 1,
                    "local": [
                        {"name": "HBM", "class": "DRAM",
                         "bandwidth": dram_gbs},
                    ],
                    "subtree": [{
                        "name": "PT", "num": N_PT,
                        "local": [
                            {"name": "L0", "class": "Buffer",
                             "type": "buffet", "width": 8,
                             "depth": int(l0_kb * 1024 / 8)},
                            {"name": "SortNet", "class": "Merger",
                             "inputs": 64, "comparator_radix": 2,
                             "outputs": 1, "order": "opt",
                             "reduce": False},
                        ],
                        "subtree": [{
                            "name": "PE", "num": PES_PER_PT // 2,
                            "local": [
                                {"name": "AddALU", "class": "Compute",
                                 "type": "add"},
                            ],
                        }],
                    }],
                },
            },
        },
        "binding": {
            "T": {
                "topology": "multiply",
                "storage": [
                    # A nonzeros staged per 16-element group in the PT L0
                    {"component": "L0", "tensor": "A", "rank": "KM0",
                     "type": "elem", "config": "CSC", "style": "lazy"},
                    # B rows cached in L0 (reused across the 16 PEs of a PT)
                    {"component": "L0", "tensor": "B", "rank": "N",
                     "type": "elem", "config": "CSR", "style": "lazy"},
                ],
                "compute": [{"component": "MulALU", "op": "mul"}],
            },
            "Z": {
                "topology": "merge",
                "storage": [
                    # whole row of partial products loaded for the sort
                    {"component": "L0", "tensor": "T", "rank": "M0",
                     "type": "elem", "config": "LinkedLists",
                     "style": "eager", "evict-on": "M0"},
                ],
                "compute": [{"component": "AddALU", "op": "add"}],
            },
        },
    }
    return load_spec(d)

def simulate(inputs, var_shapes, params=None, backend=None,
             model=True, semiring=None, **spec_kw):
    """Run this design on real tensors; delegates to
    repro.accelerators.simulate (``backend`` selects the execution
    engine: 'python' oracle | 'vector' columnar CSF | 'analytic'
    closed-form density model).

    Both phases -- the (K, M)-flattened, occupancy-distributed multiply
    and the M-partitioned merge -- lower to the VectorPlan IR, so
    ``backend='vector'`` executes natively (``SimResult.fallback_reasons
    == {}``) instead of silently routing through the interpreter."""
    from repro.accelerators import simulate as _simulate

    return _simulate("outerspace", inputs, var_shapes, params=params,
                     backend=backend, model=model, semiring=semiring,
                     **spec_kw)
