"""ExTensor [Hegde et al., MICRO'19] as a TeAAL spec (paper Fig. 8b).

Hybrid dataflow, inner-product at the innermost level, with uniform
shape-based partitioning at two levels (LLC tiles, PE tiles) and
hierarchical skip-ahead intersection (implicit in fibertree co-iteration
semantics; the skip-ahead unit's cost model is in components.py).

  Z[m,n] = A[k,m] * B[k,n]

Partition sizes are symbolic (K1/K0/M1/M0/N1/N0) per the figure and
resolved through ``params`` -- the original evaluation tunes them per
matrix; defaults here target the LLC (30 MB) / PE buffer (64 kB) sizes
of Table 5 for ~10K-row matrices.

Hardware (Table 5): 1 GHz, 128 PEs, 64 kB PE buffer, 30 MB LLC,
68.256 GB/s memory bandwidth.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.spec import AcceleratorSpec, load_spec

CLOCK_GHZ = 1.0
N_PES = 128
PE_BUF_KB = 64.0
LLC_MB = 30.0
DRAM_GBS = 68.256

#: default symbolic partition sizes (overridable per matrix)
DEFAULT_PARAMS = {"K1": 1024, "K0": 128, "M1": 1024, "M0": 128,
                  "N1": 1024, "N0": 128}


def spec(dram_gbs: float = DRAM_GBS, llc_mb: float = LLC_MB,
         pe_buf_kb: float = PE_BUF_KB) -> AcceleratorSpec:
    d: Dict[str, Any] = {
        "name": "ExTensor",
        "einsum": {
            "declaration": {
                "A": ["K", "M"],
                "B": ["K", "N"],
                "Z": ["M", "N"],
            },
            "expressions": ["Z[m, n] = A[k, m] * B[k, n]"],
        },
        "mapping": {
            "rank-order": {
                "A": ["K", "M"],
                "B": ["K", "N"],
                "Z": ["M", "N"],
            },
            "partitioning": {
                "Z": {
                    "K": ["uniform_shape(K1)", "uniform_shape(K0)"],
                    "M": ["uniform_shape(M1)", "uniform_shape(M0)"],
                    "N": ["uniform_shape(N1)", "uniform_shape(N0)"],
                },
            },
            "loop-order": {
                "Z": ["N2", "K2", "M2", "M1", "N1", "K1",
                      "M0", "N0", "K0"],
            },
            "spacetime": {
                "Z": {"space": ["K1"],
                      "time": ["N2", "K2", "M2", "M1", "N1",
                               "M0", "N0", "K0"]},
            },
        },
        "format": {
            "A": {"HCSR": {
                "K2": {"format": "C", "cbits": 32, "pbits": 32},
                "K1": {"format": "C", "cbits": 32, "pbits": 32},
                "K0": {"format": "C", "cbits": 32, "pbits": 32},
                "K": {"format": "C", "cbits": 32, "pbits": 32},
                "M2": {"format": "C", "cbits": 32, "pbits": 32},
                "M1": {"format": "C", "cbits": 32, "pbits": 32},
                "M0": {"format": "C", "cbits": 32, "pbits": 32},
                "M": {"format": "C", "cbits": 32, "pbits": 64}}},
            "B": {"HCSR": {
                "K2": {"format": "C", "cbits": 32, "pbits": 32},
                "K1": {"format": "C", "cbits": 32, "pbits": 32},
                "K0": {"format": "C", "cbits": 32, "pbits": 32},
                "K": {"format": "C", "cbits": 32, "pbits": 32},
                "N2": {"format": "C", "cbits": 32, "pbits": 32},
                "N1": {"format": "C", "cbits": 32, "pbits": 32},
                "N0": {"format": "C", "cbits": 32, "pbits": 32},
                "N": {"format": "C", "cbits": 32, "pbits": 64}}},
            "Z": {"CSR": {
                "M2": {"format": "C", "cbits": 32, "pbits": 32},
                "M1": {"format": "C", "cbits": 32, "pbits": 32},
                "M0": {"format": "C", "cbits": 32, "pbits": 32},
                "M": {"format": "C", "cbits": 32, "pbits": 32},
                "N2": {"format": "C", "cbits": 32, "pbits": 32},
                "N1": {"format": "C", "cbits": 32, "pbits": 32},
                "N0": {"format": "C", "cbits": 32, "pbits": 32},
                "N": {"format": "C", "cbits": 32, "pbits": 64}}},
        },
        "architecture": {
            "clock_ghz": CLOCK_GHZ,
            "topologies": {
                "main": {
                    "name": "chip", "num": 1,
                    "local": [
                        {"name": "DRAM", "class": "DRAM",
                         "bandwidth": dram_gbs},
                        {"name": "LLC", "class": "Buffer",
                         "type": "cache", "width": 64,
                         "depth": int(llc_mb * 1024 * 1024 / 64)},
                        {"name": "TopIsect", "class": "Intersection",
                         "type": "skip_ahead"},
                    ],
                    "subtree": [{
                        "name": "PE", "num": N_PES,
                        "local": [
                            {"name": "PEBuf", "class": "Buffer",
                             "type": "buffet", "width": 8,
                             "depth": int(pe_buf_kb * 1024 / 8)},
                            {"name": "PEIsect", "class": "Intersection",
                             "type": "skip_ahead"},
                            {"name": "MulALU", "class": "Compute",
                             "type": "mul"},
                            {"name": "AddALU", "class": "Compute",
                             "type": "add"},
                        ],
                    }],
                },
            },
        },
        "binding": {
            "Z": {
                "topology": "main",
                "storage": [
                    # LLC tiles (eager: whole K1/N1 tile subtree on touch)
                    {"component": "LLC", "tensor": "A", "rank": "M1",
                     "type": "elem", "config": "HCSR", "style": "eager"},
                    {"component": "LLC", "tensor": "B", "rank": "N1",
                     "type": "elem", "config": "HCSR", "style": "eager"},
                    {"component": "LLC", "tensor": "Z", "rank": "N1",
                     "type": "elem", "config": "CSR", "style": "lazy"},
                    # PE tiles
                    {"component": "PEBuf", "tensor": "A", "rank": "M0",
                     "type": "elem", "config": "HCSR", "style": "eager",
                     "evict-on": "N1"},
                    {"component": "PEBuf", "tensor": "B", "rank": "N0",
                     "type": "elem", "config": "HCSR", "style": "eager",
                     "evict-on": "M0"},
                ],
                "compute": [
                    {"component": "MulALU", "op": "mul"},
                    {"component": "AddALU", "op": "add"},
                ],
            },
        },
    }
    return load_spec(d)

def simulate(inputs, var_shapes, params=None, backend=None,
             model=True, semiring=None, **spec_kw):
    """Run this design on real tensors; delegates to
    repro.accelerators.simulate (``backend`` selects the execution
    engine: 'python' oracle | 'vector' columnar CSF | 'analytic'
    closed-form density model)."""
    from repro.accelerators import simulate as _simulate

    return _simulate("extensor", inputs, var_shapes, params=params,
                     backend=backend, model=model, semiring=semiring,
                     **spec_kw)
