"""The Table 2 cascade zoo: Einsum cascades for accelerators/algorithms
beyond the four validated designs.  Each entry is a minimal spec
(einsum + default mapping) used to demonstrate the expressive range of
cascades-of-Einsums and to drive the benchmark that checks every
cascade evaluates correctly against the dense oracle.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.spec import AcceleratorSpec, load_spec


def eyeriss_conv() -> AcceleratorSpec:
    """Eyeriss CONV (Table 2): O[b,m,p,q] = I[b,c,p+r,q+s] * F[c,m,r,s]."""
    return load_spec({
        "name": "Eyeriss-CONV",
        "einsum": {
            "declaration": {
                "I": ["B", "C", "H", "W"],
                "F": ["C", "M", "R", "S"],
                "O": ["B", "M", "P", "Q"],
            },
            "expressions": [
                "O[b, m, p, q] = I[b, c, p + r, q + s] * F[c, m, r, s]",
            ],
        },
        "mapping": {},
    })


def toeplitz_conv() -> AcceleratorSpec:
    """Toeplitz expansion / im2col + matmul (Table 2), 2D."""
    return load_spec({
        "name": "Toeplitz-CONV",
        "einsum": {
            "declaration": {
                "I": ["B", "C", "H", "W"],
                "F": ["C", "M", "R", "S"],
                "T": ["B", "C", "P", "Q", "R", "S"],
                "O": ["B", "M", "P", "Q"],
            },
            "expressions": [
                "T[b, c, p, q, r, s] = I[b, c, p + r, q + s]",
                "O[b, m, p, q] = T[b, c, p, q, r, s] * F[c, m, r, s]",
            ],
        },
        "mapping": {},
    })


def tensaurus_mttkrp() -> AcceleratorSpec:
    """Tensaurus MTTKRP (Table 2): C[i,r] = T[i,j,k] * B[j,r] * A[k,r]."""
    return load_spec({
        "name": "Tensaurus-MTTKRP",
        "einsum": {
            "declaration": {
                "T": ["I", "J", "K"],
                "A": ["K", "R"],
                "B": ["J", "R"],
                "C": ["I", "R"],
            },
            "expressions": ["C[i, r] = T[i, j, k] * B[j, r] * A[k, r]"],
        },
        "mapping": {
            "loop-order": {"C": ["I", "J", "K", "R"]},
        },
    })


def factorized_mttkrp() -> AcceleratorSpec:
    """Factorized MTTKRP (Table 2): two-stage cascade."""
    return load_spec({
        "name": "Factorized-MTTKRP",
        "einsum": {
            "declaration": {
                "T": ["I", "J", "K"],
                "A": ["K", "R"],
                "B": ["J", "R"],
                "S": ["I", "J", "R"],
                "C": ["I", "R"],
            },
            "expressions": [
                "S[i, j, r] = T[i, j, k] * A[k, r]",
                "C[i, r] = S[i, j, r] * B[j, r]",
            ],
        },
        "mapping": {
            "loop-order": {"S": ["I", "J", "K", "R"],
                           "C": ["I", "J", "R"]},
        },
    })


def cooley_tukey_step() -> AcceleratorSpec:
    """One Cooley-Tukey FFT butterfly step (Table 2).

    E/O are the even/odd DFT halves; P holds twiddle factors.  Uses real
    arithmetic (the butterfly structure is what the cascade expresses).
    """
    return load_spec({
        "name": "FFT-Step",
        "einsum": {
            "declaration": {
                "P": ["U", "K0", "N1", "V"],
                "X": ["N1", "V"],
                "E": ["U", "K0"],
                "O": ["U", "K0"],
                "T": ["K0"],
                "Y0": ["K0"],
                "Y1": ["K0"],
            },
            "expressions": [
                "E[0, k0] = P[0, k0, n1, 0] * X[n1, 0]",
                "O[0, k0] = P[0, k0, n1, 0] * X[n1, 1]",
                "T[k0] = P[0, k0, 0, 1] * O[0, k0]",
                "Y0[k0] = E[0, k0] + T[k0]",
                "Y1[k0] = E[0, k0] - T[k0]",
            ],
        },
        "mapping": {},
    })


def rowwise_spmspm() -> AcceleratorSpec:
    """Unpartitioned Gustavson SpMSpM: the canonical workload of the
    vectorized (CSF) execution backend -- every rank co-iterates, so
    the whole loop nest runs on the columnar fast path."""
    return load_spec({
        "name": "Rowwise-SpMSpM",
        "einsum": {
            "declaration": {
                "A": ["M", "K"],
                "B": ["K", "N"],
                "Z": ["M", "N"],
            },
            "expressions": ["Z[m, n] = A[m, k] * B[k, n]"],
        },
        "mapping": {
            "loop-order": {"Z": ["M", "K", "N"]},
        },
    })


def sparse_add() -> AcceleratorSpec:
    """Elementwise sparse addition: exercises union (merge) co-iteration
    in both backends (the sorted-union kernel on the vector path)."""
    return load_spec({
        "name": "Sparse-Add",
        "einsum": {
            "declaration": {
                "A": ["M", "N"],
                "B": ["M", "N"],
                "Z": ["M", "N"],
            },
            "expressions": ["Z[m, n] = A[m, n] + B[m, n]"],
        },
        "mapping": {},
    })


def elementwise_3way() -> AcceleratorSpec:
    """Three-factor elementwise product: every rank co-iterates three
    drivers, exercising the nested (left-leaning) two-finger
    intersection chain and its lazy-pull instrumentation accounting on
    the vector path."""
    return load_spec({
        "name": "Elementwise-3way",
        "einsum": {
            "declaration": {
                "A": ["M", "N"],
                "B": ["M", "N"],
                "C": ["M", "N"],
                "Z": ["M", "N"],
            },
            "expressions": ["Z[m, n] = A[m, n] * B[m, n] * C[m, n]"],
        },
        "mapping": {},
    })


def sparse_add_3way() -> AcceleratorSpec:
    """Three-term elementwise sum: the k-ary sorted multi-way merge
    (``kernels.ops.union_k_keys``) on the vector path."""
    return load_spec({
        "name": "Sparse-Add-3way",
        "einsum": {
            "declaration": {
                "A": ["M", "N"],
                "B": ["M", "N"],
                "C": ["M", "N"],
                "Z": ["M", "N"],
            },
            "expressions": ["Z[m, n] = A[m, n] + B[m, n] + C[m, n]"],
        },
        "mapping": {},
    })


def broadcast_outer() -> AcceleratorSpec:
    """Broadcast along a driverless (dense) output rank: no input has
    an N rank, so the N loop enumerates the full coordinate range
    (``DenseEnumerate`` on the vector path)."""
    return load_spec({
        "name": "Broadcast-Outer",
        "einsum": {
            "declaration": {
                "A": ["M"],
                "B": ["M"],
                "Z": ["M", "N"],
            },
            "expressions": ["Z[m, n] = A[m] * B[m]"],
        },
        "mapping": {},
    })


ZOO: Dict[str, Any] = {
    "eyeriss-conv": eyeriss_conv,
    "toeplitz-conv": toeplitz_conv,
    "tensaurus-mttkrp": tensaurus_mttkrp,
    "factorized-mttkrp": factorized_mttkrp,
    "fft-step": cooley_tukey_step,
    "rowwise-spmspm": rowwise_spmspm,
    "sparse-add": sparse_add,
    "elementwise-3way": elementwise_3way,
    "sparse-add-3way": sparse_add_3way,
    "broadcast-outer": broadcast_outer,
}
