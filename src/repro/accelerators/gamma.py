"""Gamma [Zhang et al., ASPLOS'21] as a TeAAL spec (paper Fig. 8a).

Row-wise (Gustavson) SpMSpM with a tightly-pipelined multiply-merge:
  T[k,m,n] = take(A[k,m], B[k,n], 1)    -- fetch rows of B selected by A
  Z[m,n]   = T[k,m,n] * A[k,m]          -- scale + merge-reduce over K

Each PE processes rows of A (M0 spatial over 32 PEs); the per-PE
64-way hardware merger sorts the fetched B rows ([K,N] -> [N within K])
so reduction over K is concordant -- expressed as the rank swizzle of T
between the two (fused) Einsums.  B is *not* statically partitioned:
its rows are fetched by coordinate through the FiberCache (the
leader-follower occupancy split of K follows A, whose boundaries are
per-row and therefore dynamic -- see MappingResolver._partition_applies).

Hardware (Table 5): 1 GHz, 32 PEs, 64-way merger per PE, 3 MB
FiberCache, 16 64-bit HBM channels @ 8 GB/s.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.spec import AcceleratorSpec, load_spec

CLOCK_GHZ = 1.0
N_PES = 32
MERGER_RADIX = 64
FIBERCACHE_MB = 3.0
DRAM_GBS = 16 * 8.0


def spec(rows_per_round: int = 32, merge_radix: int = MERGER_RADIX,
         fibercache_mb: float = FIBERCACHE_MB,
         dram_gbs: float = DRAM_GBS) -> AcceleratorSpec:
    d: Dict[str, Any] = {
        "name": "Gamma",
        "einsum": {
            "declaration": {
                "A": ["K", "M"],
                "B": ["K", "N"],
                "T": ["K", "M", "N"],
                "Z": ["M", "N"],
            },
            "expressions": [
                "T[k, m, n] = take(A[k, m], B[k, n], 1)",
                "Z[m, n] = T[k, m, n] * A[k, m]",
            ],
        },
        "mapping": {
            "rank-order": {
                "A": ["M", "K"],
                "B": ["K", "N"],
                "T": ["M", "K", "N"],
                "Z": ["M", "N"],
            },
            "partitioning": {
                "T": {
                    "M": [f"uniform_occupancy(A.{rows_per_round})"],
                    "K": [f"uniform_occupancy(A.{merge_radix})"],
                },
                "Z": {
                    "M": [f"uniform_occupancy(A.{rows_per_round})"],
                    "K": [f"uniform_occupancy(A.{merge_radix})"],
                },
            },
            "loop-order": {
                "T": ["M1", "M0", "K1", "K0", "N"],
                "Z": ["M1", "M0", "K1", "N", "K0"],
            },
            "spacetime": {
                "T": {"space": ["M0", "K1"], "time": ["M1", "K0", "N"]},
                "Z": {"space": ["M0", "K1"], "time": ["M1", "N", "K0"]},
            },
        },
        "format": {
            "A": {"CSR": {"M": {"format": "C", "cbits": 32, "pbits": 32},
                          "K": {"format": "C", "cbits": 32, "pbits": 64}}},
            "B": {"CSR": {"K": {"format": "C", "cbits": 32, "pbits": 32},
                          "N": {"format": "C", "cbits": 32, "pbits": 64}}},
            "T": {"Stream": {"M": {"format": "C", "cbits": 32, "pbits": 32},
                             "K": {"format": "C", "cbits": 32, "pbits": 32},
                             "N": {"format": "C", "cbits": 32,
                                   "pbits": 64}}},
            "Z": {"CSR": {"M": {"format": "C", "cbits": 32, "pbits": 32},
                          "N": {"format": "C", "cbits": 32, "pbits": 64}}},
        },
        "architecture": {
            "clock_ghz": CLOCK_GHZ,
            "topologies": {
                "main": {
                    "name": "chip", "num": 1,
                    "local": [
                        {"name": "HBM", "class": "DRAM",
                         "bandwidth": dram_gbs},
                        # FiberCache: shared, banked, 3 MB
                        {"name": "FiberCache", "class": "Buffer",
                         "type": "cache", "width": 64,
                         "depth": int(fibercache_mb * 1024 * 1024 / 64),
                         "bandwidth": 512.0},
                    ],
                    "subtree": [{
                        "name": "PE", "num": N_PES,
                        "local": [
                            {"name": "Merger", "class": "Merger",
                             "inputs": merge_radix,
                             "comparator_radix": merge_radix,
                             "outputs": 1, "order": "fifo",
                             "reduce": True},
                            {"name": "MulALU", "class": "Compute",
                             "type": "mul"},
                            {"name": "AddALU", "class": "Compute",
                             "type": "add"},
                            {"name": "Isect", "class": "Intersection",
                             "type": "leader_follower", "leader": "A"},
                        ],
                    }],
                },
            },
        },
        "binding": {
            "T": {
                "topology": "main",
                "storage": [
                    # rows of B stream through the shared FiberCache
                    {"component": "FiberCache", "tensor": "B", "rank": "N",
                     "type": "elem", "config": "CSR", "style": "lazy"},
                    {"component": "FiberCache", "tensor": "A", "rank": "K0",
                     "type": "elem", "config": "CSR", "style": "lazy"},
                ],
                "compute": [],
            },
            "Z": {
                "topology": "main",
                "storage": [
                    # scaled partial rows live in the merger's buffers;
                    # Z accumulates through the FiberCache before drain
                    {"component": "FiberCache", "tensor": "Z", "rank": "N",
                     "type": "elem", "config": "CSR", "style": "lazy"},
                ],
                "compute": [
                    {"component": "MulALU", "op": "mul"},
                    {"component": "AddALU", "op": "add"},
                ],
            },
        },
    }
    return load_spec(d)

def simulate(inputs, var_shapes, params=None, backend=None,
             model=True, semiring=None, **spec_kw):
    """Run this design on real tensors; delegates to
    repro.accelerators.simulate (``backend`` selects the execution
    engine: 'python' oracle | 'vector' columnar CSF | 'analytic'
    closed-form density model)."""
    from repro.accelerators import simulate as _simulate

    return _simulate("gamma", inputs, var_shapes, params=params,
                     backend=backend, model=model, semiring=semiring,
                     **spec_kw)
