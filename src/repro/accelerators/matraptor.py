"""MatRaptor [Srivastava et al., MICRO'20] as a TeAAL spec (Table 1).

Row-wise product (Gustavson) SpMSpM with parallel summation: rows of A
are distributed round-robin across PEs (the C^2SR channel-cyclic
format); each PE scales the selected rows of B and merge-sums partial
rows through its sorting-queue array.

Cascade-wise MatRaptor is Gamma's row-wise form without the shared
FiberCache: the same take()/multiply cascade, mapped with M0 spatial
over 8 PEs and the queue array modeled as the per-PE merger (radix =
number of queues).  This is exactly the paper's point: closely-related
designs differ by mapping/binding point changes, not new simulators.

Hardware (MatRaptor paper): 2 GHz, 8 PEs, 12 sorting queues per PE,
16 GB/s/channel x 8 channels HBM.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.spec import AcceleratorSpec, load_spec

CLOCK_GHZ = 2.0
N_PES = 8
N_QUEUES = 12
DRAM_GBS = 128.0


def spec(rows_per_round: int = N_PES,
         n_queues: int = N_QUEUES) -> AcceleratorSpec:
    d: Dict[str, Any] = {
        "name": "MatRaptor",
        "einsum": {
            "declaration": {
                "A": ["K", "M"],
                "B": ["K", "N"],
                "T": ["K", "M", "N"],
                "Z": ["M", "N"],
            },
            "expressions": [
                "T[k, m, n] = take(A[k, m], B[k, n], 1)",
                "Z[m, n] = T[k, m, n] * A[k, m]",
            ],
        },
        "mapping": {
            "rank-order": {
                "A": ["M", "K"],
                "B": ["K", "N"],
                "T": ["M", "K", "N"],
                "Z": ["M", "N"],
            },
            "partitioning": {
                # C^2SR: rows cycled across PEs -> occupancy split of M
                "T": {"M": [f"uniform_occupancy(A.{rows_per_round})"],
                      "K": [f"uniform_occupancy(A.{n_queues})"]},
                "Z": {"M": [f"uniform_occupancy(A.{rows_per_round})"],
                      "K": [f"uniform_occupancy(A.{n_queues})"]},
            },
            "loop-order": {
                "T": ["M1", "M0", "K1", "K0", "N"],
                "Z": ["M1", "M0", "K1", "N", "K0"],
            },
            "spacetime": {
                "T": {"space": ["M0"], "time": ["M1", "K1", "K0", "N"]},
                "Z": {"space": ["M0"], "time": ["M1", "K1", "N", "K0"]},
            },
        },
        "format": {
            # C^2SR: per-channel row headers (fhbits on the K rank)
            "A": {"C2SR": {"M": {"format": "C", "cbits": 32, "pbits": 32},
                           "K": {"format": "C", "cbits": 32, "pbits": 64,
                                 "fhbits": 64}}},
            "B": {"C2SR": {"K": {"format": "C", "cbits": 32, "pbits": 32},
                           "N": {"format": "C", "cbits": 32, "pbits": 64,
                                 "fhbits": 64}}},
            "Z": {"C2SR": {"M": {"format": "C", "cbits": 32, "pbits": 32},
                           "N": {"format": "C", "cbits": 32,
                                 "pbits": 64}}},
        },
        "architecture": {
            "clock_ghz": CLOCK_GHZ,
            "topologies": {
                "main": {
                    "name": "chip", "num": 1,
                    "local": [
                        {"name": "HBM", "class": "DRAM",
                         "bandwidth": DRAM_GBS},
                    ],
                    "subtree": [{
                        "name": "PE", "num": N_PES,
                        "local": [
                            # the sorting-queue array: a radix-Q merger
                            {"name": "Queues", "class": "Merger",
                             "inputs": n_queues,
                             "comparator_radix": n_queues,
                             "outputs": 1, "order": "fifo",
                             "reduce": True},
                            {"name": "MulALU", "class": "Compute",
                             "type": "mul"},
                            {"name": "AddALU", "class": "Compute",
                             "type": "add"},
                            {"name": "Isect", "class": "Intersection",
                             "type": "leader_follower", "leader": "A"},
                        ],
                    }],
                },
            },
        },
        "binding": {
            "T": {"topology": "main", "storage": [], "compute": []},
            "Z": {"topology": "main", "storage": [],
                  "compute": [{"component": "MulALU", "op": "mul"},
                              {"component": "AddALU", "op": "add"}]},
        },
    }
    return load_spec(d)

def simulate(inputs, var_shapes, params=None, backend=None,
             model=True, semiring=None, **spec_kw):
    """Run this design on real tensors; delegates to
    repro.accelerators.simulate (``backend`` selects the execution
    engine: 'python' oracle | 'vector' columnar CSF | 'analytic'
    closed-form density model).

    The take() cascade with its C^2SR occupancy splits and
    leader-follower (A-led) intersection lowers to the VectorPlan IR,
    so ``backend='vector'`` executes natively
    (``SimResult.fallback_reasons == {}``) instead of silently routing
    through the interpreter."""
    from repro.accelerators import simulate as _simulate

    return _simulate("matraptor", inputs, var_shapes, params=params,
                     backend=backend, model=model, semiring=semiring,
                     **spec_kw)
