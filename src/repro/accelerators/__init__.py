"""The paper's accelerator designs as TeAAL specifications.

Each module exposes ``spec(**params) -> AcceleratorSpec`` mirroring the
published design (Figures 3, 8, 12; hardware parameters from Table 5),
plus the Table 2 cascade zoo in ``zoo``.  Every module also exposes
``simulate(inputs, var_shapes, ..., backend=...)`` threading the
pluggable execution backend ('python' | 'vector' | 'analytic', see
repro.core.iteration.ExecutorBackend) through to the simulator.
"""
from typing import Any, Dict, Optional

from . import (extensor, gamma, graphicionado, matraptor, outerspace,
               sigma, zoo)

REGISTRY = {
    "outerspace": outerspace.spec,
    "extensor": extensor.spec,
    "gamma": gamma.spec,
    "sigma": sigma.spec,
    "matraptor": matraptor.spec,
    "graphicionado": graphicionado.graphicionado_spec,
    "graphdyns": graphicionado.graphdyns_spec,
    "ours-vcp": graphicionado.improved_spec,
}

#: per-design partition-size defaults needed to resolve symbolic mappings
DEFAULT_PARAMS: Dict[str, Optional[Dict[str, int]]] = {
    "extensor": extensor.DEFAULT_PARAMS,
}


def simulate(design: "str | Any", inputs: Dict[str, Any],
             var_shapes: Dict[str, int],
             params: Optional[Dict[str, int]] = None,
             backend: "str | None" = None,
             model: bool = True, semiring=None, **spec_kw):
    """One-call entry point: run a design (REGISTRY name or an
    AcceleratorSpec) on real tensors with the selected execution
    backend; returns the SimResult."""
    from repro.core.generator import CascadeSimulator

    if isinstance(design, str):
        spec = REGISTRY[design](**spec_kw)
        if params is None:
            params = DEFAULT_PARAMS.get(design)
    else:
        if spec_kw:
            raise TypeError(
                "spec factory kwargs "
                f"{sorted(spec_kw)} require a registry name, not an "
                "already-built AcceleratorSpec")
        spec = design
    sim = CascadeSimulator(spec, params=params, semiring=semiring,
                           model=model, backend=backend)
    return sim.run(dict(inputs), var_shapes)


__all__ = ["REGISTRY", "DEFAULT_PARAMS", "simulate", "extensor", "gamma",
           "graphicionado", "matraptor", "outerspace", "sigma", "zoo"]
