"""The paper's accelerator designs as TeAAL specifications.

Each module exposes ``spec(**params) -> AcceleratorSpec`` mirroring the
published design (Figures 3, 8, 12; hardware parameters from Table 5),
plus the Table 2 cascade zoo in ``zoo``.
"""
from . import (extensor, gamma, graphicionado, matraptor, outerspace,
               sigma, zoo)

REGISTRY = {
    "outerspace": outerspace.spec,
    "extensor": extensor.spec,
    "gamma": gamma.spec,
    "sigma": sigma.spec,
    "matraptor": matraptor.spec,
    "graphicionado": graphicionado.graphicionado_spec,
    "graphdyns": graphicionado.graphdyns_spec,
    "ours-vcp": graphicionado.improved_spec,
}

__all__ = ["REGISTRY", "extensor", "gamma", "graphicionado", "matraptor",
           "outerspace", "sigma", "zoo"]
