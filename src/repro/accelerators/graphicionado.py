"""Vertex-centric programming accelerators (paper Sec. 8, Fig. 12).

Three designs over the same processing phase, differing in the apply
phase's data orchestration:

  * Graphicionado [Ham et al., MICRO'16]: applies *every* vertex each
    iteration (P1 = R + P0 unions the full property vector), edge-list
    graph format.
  * GraphDynS [Yan et al., MICRO'19]: builds MP = take(R, P0, 1) so only
    *touched* property partitions are loaded (a 256-partition bitmap ->
    uniform_shape partitioning with eager loads), filters write-back
    through the changed-mask M, CSR graph format.
  * Ours (Sec. 8 proposal): drops the partitioning -- properties are
    loaded and applied lazily only for vertices actually modified.

A specific algorithm manifests by redefining (+, x): SSSP uses
(min, +); BFS is SSSP on unit weights.  Properties are stored as
distance+1 so the additive identity (empty payload = 0) never collides
with a real distance.

Hardware (Table 5, used for all three): 1 GHz, 8 streams, 64 MB eDRAM,
68 GB/s memory bandwidth.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.spec import AcceleratorSpec, load_spec

CLOCK_GHZ = 1.0
N_STREAMS = 8
EDRAM_MB = 64.0
DRAM_GBS = 68.0

# processing phase shared by all three designs (Fig. 12, lines 1-3)
_PROCESS = [
    "SO[d, s] = take(G[d, s], A0[s], 0)",
    "R[d] = SO[d, s] * A0[s]",
]

def _arch(edram_mb: float = EDRAM_MB) -> Dict[str, Any]:
    """Table-5 hardware.  ``edram_mb`` is scalable so test/benchmark
    graphs (10^2-10^3 vertices vs the paper's 10^6-10^7) exercise the
    same capacity regime: the paper's graphs exceed the 64 MB eDRAM, so
    scaled-down graphs must exceed a scaled-down eDRAM (methodology
    note in EXPERIMENTS.md)."""
    return {
        "clock_ghz": CLOCK_GHZ,
        "topologies": {
            "main": {
                "name": "chip", "num": 1,
                "local": [
                    {"name": "DRAM", "class": "DRAM",
                     "bandwidth": DRAM_GBS},
                    {"name": "eDRAM", "class": "Buffer", "type": "cache",
                     "width": 64,
                     "depth": max(1, int(edram_mb * 1024 * 1024 / 64))},
                    # sparse-active-set probes: the smaller side leads
                    {"name": "Isect", "class": "Intersection",
                     "type": "leader_follower", "leader": "R"},
                ],
                "subtree": [{
                    "name": "Stream", "num": N_STREAMS,
                    "local": [
                        {"name": "ProcALU", "class": "Compute",
                         "type": "mul"},
                        {"name": "ApplyALU", "class": "Compute",
                         "type": "add"},
                    ],
                }],
            },
        },
    }


_ARCH = _arch()


def _format(edge_list: bool, weighted: bool) -> Dict[str, Any]:
    """Graph format: edge list re-stores the source ID per edge (64-bit
    coordinate on D); CSR stores each source once and can omit the
    weight payload for unweighted algorithms (BFS)."""
    pbits = 32 if weighted else 0
    if edge_list:
        g = {"S": {"format": "C", "cbits": 0, "pbits": 0},
             "D": {"format": "C", "cbits": 64, "pbits": 32}}
    else:
        g = {"S": {"format": "C", "cbits": 32, "pbits": 32},
             "D": {"format": "C", "cbits": 32, "pbits": pbits}}
    vec = {"format": "C", "cbits": 32, "pbits": 32}
    return {
        "G": {"default": g},
        "A0": {"default": {"S": dict(vec)}},
        "A1": {"default": {"D": dict(vec)}},
        "R": {"default": {"D": dict(vec)}},
        "P0": {"default": {"D": dict(vec), "D1": dict(vec),
                           "D0": dict(vec)}},
        "P1": {"default": {"D": dict(vec)}},
        "MP": {"default": {"D": dict(vec)}},
        "NP": {"default": {"D": dict(vec)}},
        "M": {"default": {"D": dict(vec)}},
        "SO": {"default": {"S": dict(vec), "D": dict(vec)}},
    }


def graphicionado_spec(weighted: bool = True,
                       edram_mb: float = EDRAM_MB) -> AcceleratorSpec:
    d: Dict[str, Any] = {
        "name": "Graphicionado",
        "einsum": {
            "declaration": {
                "G": ["D", "S"], "A0": ["S"], "SO": ["D", "S"],
                "R": ["D"], "P0": ["D"], "P1": ["D"], "M": ["D"],
                "A1": ["D"],
            },
            "expressions": _PROCESS + [
                "P1[d] = R[d] + P0[d]",
                "M[d] = P1[d] - P0[d]",
                "A1[d] = take(M[d], P1[d], 1)",
            ],
        },
        "mapping": {
            "rank-order": {
                "G": ["S", "D"], "SO": ["S", "D"],
            },
            "loop-order": {
                "SO": ["S", "D"],
                "R": ["S", "D"],
                "P1": ["D"],
                "M": ["D"],
                "A1": ["D"],
            },
        },
        "format": _format(edge_list=True, weighted=weighted),
        "architecture": _arch(edram_mb),
        "binding": {
            "SO": {"topology": "main",
                   "storage": [
                       {"component": "eDRAM", "tensor": "A0", "rank": "S",
                        "type": "elem", "style": "lazy"}],
                   "compute": []},
            "R": {"topology": "main",
                  "storage": [
                      {"component": "eDRAM", "tensor": "R", "rank": "D",
                       "type": "elem", "style": "lazy"}],
                  "compute": [{"component": "ProcALU", "op": "mul"}]},
            "P1": {"topology": "main", "storage": [],
                   "compute": [{"component": "ApplyALU", "op": "add"}]},
            "M": {"topology": "main", "storage": [], "compute": []},
            "A1": {"topology": "main", "storage": [], "compute": []},
        },
    }
    return load_spec(d)


def graphdyns_spec(weighted: bool = True,
                   n_partitions: int = 256,
                   n_vertices: int = 1 << 20,
                   edram_mb: float = EDRAM_MB) -> AcceleratorSpec:
    part = max(1, n_vertices // n_partitions)
    d: Dict[str, Any] = {
        "name": "GraphDynS",
        "einsum": {
            "declaration": {
                "G": ["D", "S"], "A0": ["S"], "SO": ["D", "S"],
                "R": ["D"], "P0": ["D"], "MP": ["D"], "NP": ["D"],
                "M": ["D"], "P1": ["D"], "A1": ["D"],
            },
            "expressions": _PROCESS + [
                "MP[d] = take(R[d], P0[d], 1)",
                "NP[d] = R[d] + MP[d]",
                "M[d] = NP[d] - MP[d]",
                "P0[d] = take(M[d], NP[d], 1)",
                "A1[d] = take(M[d], NP[d], 1)",
                "P1 = P0",
            ],
        },
        "mapping": {
            "rank-order": {
                "G": ["S", "D"], "SO": ["S", "D"],
            },
            "partitioning": {
                # the 256-entry presence bitmap over vertex properties
                "MP": {"D": [f"uniform_shape({part})"]},
            },
            "loop-order": {
                "SO": ["S", "D"],
                "R": ["S", "D"],
                "MP": ["D1", "D0"],
                "NP": ["D"],
                "M": ["D"],
                "P0": ["D"],
                "A1": ["D"],
            },
        },
        "format": _format(edge_list=False, weighted=weighted),
        "architecture": _arch(edram_mb),
        "binding": {
            "SO": {"topology": "main",
                   "storage": [
                       {"component": "eDRAM", "tensor": "A0", "rank": "S",
                        "type": "elem", "style": "lazy"}],
                   "compute": []},
            "R": {"topology": "main",
                  "storage": [
                      {"component": "eDRAM", "tensor": "R", "rank": "D",
                       "type": "elem", "style": "lazy"}],
                  "compute": [{"component": "ProcALU", "op": "mul"}]},
            "MP": {"topology": "main",
                   "storage": [
                       # bitmap-gated eager load of whole property blocks
                       {"component": "eDRAM", "tensor": "P0", "rank": "D1",
                        "type": "elem", "style": "eager"}],
                   "compute": []},
            "NP": {"topology": "main", "storage": [],
                   "compute": [{"component": "ApplyALU", "op": "add"}]},
            "M": {"topology": "main", "storage": [], "compute": []},
            "P0": {"topology": "main", "storage": [], "compute": []},
            "A1": {"topology": "main", "storage": [], "compute": []},
        },
    }
    return load_spec(d)


def improved_spec(weighted: bool = True,
                  edram_mb: float = EDRAM_MB) -> AcceleratorSpec:
    """Our Sec. 8 proposal: GraphDynS minus the partitioning -- only the
    properties of vertices actually modified are loaded / applied."""
    d: Dict[str, Any] = {
        "name": "Ours-VCP",
        "einsum": {
            "declaration": {
                "G": ["D", "S"], "A0": ["S"], "SO": ["D", "S"],
                "R": ["D"], "P0": ["D"], "MP": ["D"], "NP": ["D"],
                "M": ["D"], "P1": ["D"], "A1": ["D"],
            },
            "expressions": _PROCESS + [
                "MP[d] = take(R[d], P0[d], 1)",
                "NP[d] = R[d] + MP[d]",
                "M[d] = NP[d] - MP[d]",
                "P0[d] = take(M[d], NP[d], 1)",
                "A1[d] = take(M[d], NP[d], 1)",
                "P1 = P0",
            ],
        },
        "mapping": {
            "rank-order": {
                "G": ["S", "D"], "SO": ["S", "D"],
            },
            "loop-order": {
                "SO": ["S", "D"],
                "R": ["S", "D"],
                "MP": ["D"],
                "NP": ["D"],
                "M": ["D"],
                "P0": ["D"],
                "A1": ["D"],
            },
        },
        "format": _format(edge_list=False, weighted=weighted),
        "architecture": _arch(edram_mb),
        "binding": {
            "SO": {"topology": "main",
                   "storage": [
                       {"component": "eDRAM", "tensor": "A0", "rank": "S",
                        "type": "elem", "style": "lazy"}],
                   "compute": []},
            "R": {"topology": "main",
                  "storage": [
                      {"component": "eDRAM", "tensor": "R", "rank": "D",
                       "type": "elem", "style": "lazy"}],
                  "compute": [{"component": "ProcALU", "op": "mul"}]},
            "MP": {"topology": "main",
                   "storage": [
                       {"component": "eDRAM", "tensor": "P0", "rank": "D",
                        "type": "elem", "style": "lazy"}],
                   "compute": []},
            "NP": {"topology": "main", "storage": [],
                   "compute": [{"component": "ApplyALU", "op": "add"}]},
            "M": {"topology": "main", "storage": [], "compute": []},
            "P0": {"topology": "main", "storage": [], "compute": []},
            "A1": {"topology": "main", "storage": [], "compute": []},
        },
    }
    return load_spec(d)

def simulate(inputs, var_shapes, variant: str = "graphicionado",
             params=None, backend=None, model=True, semiring=None,
             **spec_kw):
    """Run one of the graph-accelerator variants; delegates to
    repro.accelerators.simulate (``backend`` selects the execution
    engine: 'python' oracle | 'vector' columnar CSF | 'analytic'
    closed-form density model)."""
    from repro.accelerators import simulate as _simulate

    return _simulate(variant, inputs, var_shapes, params=params,
                     backend=backend, model=model, semiring=semiring,
                     **spec_kw)
