"""Columnar CSF (compressed-sparse-fiber) tensor representation.

The fibertree interpreter (`core/fibertree.py`) stores one Python object
per fiber, which caps every accelerator model at toy sizes.  This module
stores the *same* tree as flat per-rank arrays -- the layout Sparseloop
and the Sparse Abstract Machine use for scaling this class of model:

  * ``coords[d]``   -- int32 array [n_d, width_d]: the coordinates of
                       every element at rank ``d``, in depth-first
                       (lexicographic) order.  ``width_d`` is 1 for
                       normal ranks and >1 for flattened (tuple-coord)
                       ranks.
  * ``segments[d]`` -- int32 array [n_{d-1} + 1] for d >= 1: element
                       ``i`` of rank ``d-1`` owns the child slice
                       ``coords[d][segments[d][i]:segments[d][i+1]]``.
                       Rank 0 is the root fiber (one implicit segment).
  * ``values``      -- float64 array [n_{L-1}]: leaf payloads aligned
                       with the innermost coords.

Conversion ``FTensor <-> CSF`` is lossless (same rank names, shapes,
coordinate order, upper-rank markers), and the TeAAL Section 3.2
content-preserving transformations -- rank swizzling, uniform-shape /
uniform-occupancy partitioning, rank flattening -- are reimplemented
here as vectorized array ops with semantics identical to the Fiber
implementations (asserted by tests/test_csf.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import guards
from .fibertree import Fiber, FTensor

COORD_DTYPE = np.int32
SEG_DTYPE = np.int32


def _as_coord_col(arr: Any) -> np.ndarray:
    a = np.asarray(arr)
    if a.dtype == COORD_DTYPE:       # hot path: no copy, no domain scan
        return a if a.ndim == 2 else a.reshape(-1, 1)
    if a.size:
        assert a.max() <= np.iinfo(COORD_DTYPE).max
    a = a.astype(COORD_DTYPE)
    if a.ndim == 1:
        a = a[:, None]
    return a


class CSF:
    """A named fibertree stored as flat per-rank arrays."""

    def __init__(self, name: str, ranks: Sequence[str],
                 coords: Sequence[np.ndarray],
                 segments: Sequence[Optional[np.ndarray]],
                 values: np.ndarray,
                 rank_shapes: Optional[Dict[str, Any]] = None,
                 default: Any = 0,
                 upper_ranks: Optional[set] = None):
        self.name = name
        self.ranks: List[str] = list(ranks)
        # coords[d]: [n_d, width_d] int; segments[d]: [n_{d-1}+1] (d>=1)
        self.coords: List[np.ndarray] = [_as_coord_col(c) for c in coords]
        self.segments: List[Optional[np.ndarray]] = [
            None if s is None else np.asarray(s).astype(SEG_DTYPE)
            for s in segments]
        self.values = np.asarray(values)
        self.rank_shapes: Dict[str, Any] = dict(rank_shapes or {})
        self.default = default
        self.upper_ranks: set = set(upper_ranks or ())
        assert len(self.coords) == len(self.ranks)
        assert len(self.segments) == len(self.ranks)
        assert self.segments[0] is None
        for d in range(1, len(self.ranks)):
            seg = self.segments[d]
            assert seg is not None and len(seg) == len(self.coords[d - 1]) + 1
            guards.check_monotone_segments(
                seg, f"csf:{self.name}:{self.ranks[d]}")
        assert len(self.values) == (len(self.coords[-1]) if self.ranks else 0)

    # ------------------------------------------------------------------ #
    # basics
    # ------------------------------------------------------------------ #
    @property
    def ndim(self) -> int:
        return len(self.ranks)

    @property
    def nnz(self) -> int:
        return int(len(self.values))

    def level_width(self, d: int) -> int:
        return int(self.coords[d].shape[1])

    def children(self, d: int, pos: int) -> Tuple[int, int]:
        """Child slice [start, end) in ``coords[d]`` of element ``pos``
        at rank ``d-1`` (``pos`` ignored for d == 0)."""
        if d == 0:
            return 0, len(self.coords[0])
        seg = self.segments[d]
        return int(seg[pos]), int(seg[pos + 1])

    # ------------------------------------------------------------------ #
    # conversion
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_ftensor(ft: FTensor) -> "CSF":
        L = len(ft.ranks)
        coords: List[List[Tuple[int, ...]]] = [[] for _ in range(L)]
        segments: List[List[int]] = [[0] for _ in range(L)]
        values: List[Any] = []

        def rec(fiber: Fiber, depth: int) -> None:
            for c, p in fiber:
                coords[depth].append(c if isinstance(c, tuple) else (c,))
                if depth == L - 1:
                    values.append(p)
                else:
                    assert isinstance(p, Fiber), \
                        f"{ft.name}: non-fiber payload above leaf rank"
                    rec(p, depth + 1)
                    segments[depth + 1].append(len(coords[depth + 1]))

        if L:
            rec(ft.root, 0)
        widths = [max((len(t) for t in coords[d]), default=1)
                  for d in range(L)]
        carr = [np.asarray(coords[d], dtype=np.int64).reshape(
                    len(coords[d]), widths[d]) for d in range(L)]
        segs: List[Optional[np.ndarray]] = [None] + [
            np.asarray(segments[d], dtype=np.int64) for d in range(1, L)]
        vals = np.asarray(values, dtype=np.float64) if values else \
            np.zeros(0, dtype=np.float64)
        return CSF(ft.name, ft.ranks, carr, segs, vals,
                   dict(ft.rank_shapes), ft.default, set(ft.upper_ranks))

    def to_ftensor(self) -> FTensor:
        L = self.ndim
        out = FTensor(self.name, self.ranks, Fiber(),
                      dict(self.rank_shapes), self.default,
                      set(self.upper_ranks))
        if L == 0 or self.nnz == 0:
            return out
        clists = [c.tolist() for c in self.coords]
        widths = [self.level_width(d) for d in range(L)]
        vals = self.values.tolist()

        def coord_of(d: int, i: int):
            row = clists[d][i]
            return tuple(row) if widths[d] > 1 else row[0]

        def build(d: int, lo: int, hi: int) -> Fiber:
            fiber = Fiber()
            for i in range(lo, hi):
                if d == L - 1:
                    fiber.append(coord_of(d, i), vals[i])
                else:
                    seg = self.segments[d + 1]
                    fiber.append(coord_of(d, i),
                                 build(d + 1, int(seg[i]), int(seg[i + 1])))
            return fiber

        out.root = build(0, 0, len(self.coords[0]))
        return out

    @staticmethod
    def from_coo(name: str, ranks: Sequence[str], coords: np.ndarray,
                 values: np.ndarray,
                 rank_shapes: Optional[Dict[str, int]] = None,
                 default: Any = 0) -> "CSF":
        """Build from COO points [nnz, ndim] + values (vectorized).

        Duplicate points are collapsed (last value wins, matching
        Fiber.insert overwrite semantics)."""
        pts = np.asarray(coords, dtype=np.int64)
        vals = np.asarray(values, dtype=np.float64)
        ranks = list(ranks)
        L = len(ranks)
        assert pts.ndim == 2 and pts.shape[1] == L
        if len(pts) == 0:
            return CSF(name, ranks, [np.zeros((0, 1)) for _ in range(L)],
                       [None] + [np.zeros(1) for _ in range(L - 1)],
                       np.zeros(0), rank_shapes, default)
        order = np.lexsort(tuple(pts[:, d] for d in range(L - 1, -1, -1)))
        pts, vals = pts[order], vals[order]
        # collapse duplicates: keep the last of each run
        same = np.all(pts[1:] == pts[:-1], axis=1)
        keep = np.append(~same, True)
        pts, vals = pts[keep], vals[keep]
        shapes = dict(rank_shapes or {})
        for d, r in enumerate(ranks):
            shapes.setdefault(r, int(pts[:, d].max()) + 1)
        return _from_sorted_points(name, ranks,
                                   [pts[:, d:d + 1] for d in range(L)],
                                   vals, shapes, default, set())

    @staticmethod
    def from_dense(name: str, ranks: Sequence[str], array: np.ndarray,
                   default: Any = 0) -> "CSF":
        array = np.asarray(array)
        assert array.ndim == len(ranks)
        pts = np.argwhere(array != 0)
        vals = array[tuple(pts.T)].astype(np.float64)
        shapes = {r: int(s) for r, s in zip(ranks, array.shape)}
        return CSF.from_coo(name, ranks, pts, vals, shapes, default)

    def to_dense(self) -> np.ndarray:
        assert all(self.level_width(d) == 1 for d in range(self.ndim)), \
            "to_dense on flattened ranks is undefined"
        pts = self.point_matrix()
        shape = [int(self.rank_shapes.get(r) or
                     (pts[:, d].max() + 1 if len(pts) else 1))
                 for d, r in enumerate(self.ranks)]
        out = np.full(shape, self.default, dtype=np.float64)
        if len(pts):
            out[tuple(pts.T)] = self.values
        return out

    # ------------------------------------------------------------------ #
    # structural queries
    # ------------------------------------------------------------------ #
    def expand_level(self, d: int) -> np.ndarray:
        """Parent index (position at rank d-1) of every element at rank
        ``d``; for d == 0 an all-zero array."""
        n = len(self.coords[d])
        if d == 0:
            return np.zeros(n, dtype=np.int64)
        seg = self.segments[d]
        counts = np.diff(seg)
        return np.repeat(np.arange(len(counts), dtype=np.int64), counts)

    def point_matrix(self) -> np.ndarray:
        """[nnz, sum(widths)] coordinate matrix of every leaf, with each
        upper rank's coordinate columns repeated down the tree."""
        L = self.ndim
        cols: List[np.ndarray] = []
        n_leaf = len(self.coords[-1])
        for d in range(L):
            c = self.coords[d]
            # replicate down to leaf level
            for dd in range(d + 1, L):
                seg = self.segments[dd]
                counts = np.diff(seg)
                c = np.repeat(c, counts, axis=0)
            assert len(c) == n_leaf
            cols.append(c)
        if not cols:
            return np.zeros((0, 0), dtype=np.int64)
        return np.concatenate(cols, axis=1)

    def content_points(self) -> np.ndarray:
        """Like ``point_matrix`` but with partition-upper rank columns
        dropped (content coordinates only -- the CSF analogue of
        FTensor.content_signature)."""
        L = self.ndim
        keep: List[np.ndarray] = []
        pm = self.point_matrix()
        col = 0
        for d in range(L):
            w = self.level_width(d)
            if self.ranks[d] not in self.upper_ranks:
                keep.append(pm[:, col:col + w])
            col += w
        return np.concatenate(keep, axis=1) if keep else pm

    # ------------------------------------------------------------------ #
    # content-preserving transformations (TeAAL Sec. 3.2, vectorized)
    # ------------------------------------------------------------------ #
    def swizzle(self, new_order: Sequence[str]) -> "CSF":
        new_order = list(new_order)
        assert sorted(new_order) == sorted(self.ranks), \
            f"swizzle {self.ranks} -> {new_order} is not a permutation"
        if new_order == self.ranks:
            return self.copy()
        widths = [self.level_width(d) for d in range(self.ndim)]
        pm = self.point_matrix()
        col_of: Dict[str, Tuple[int, int]] = {}
        col = 0
        for d, r in enumerate(self.ranks):
            col_of[r] = (col, widths[d])
            col += widths[d]
        cols = [pm[:, col_of[r][0]:col_of[r][0] + col_of[r][1]]
                for r in new_order]
        flat = np.concatenate(cols, axis=1) if cols else pm
        order = np.lexsort(tuple(flat[:, c]
                                 for c in range(flat.shape[1] - 1, -1, -1)))
        shapes = {r: self.rank_shapes.get(r) for r in new_order}
        return _from_sorted_points(
            self.name, new_order, [c[order] for c in cols],
            self.values[order], shapes, self.default, set(self.upper_ranks))

    def flatten_ranks(self, upper: str, lower: str) -> "CSF":
        """Flatten adjacent ranks into one tuple-coordinate rank named
        ``upper + lower`` (identical semantics to FTensor.flatten_ranks)."""
        iu = self.ranks.index(upper)
        assert iu + 1 < self.ndim and self.ranks[iu + 1] == lower, \
            f"{upper},{lower} must be adjacent in {self.ranks}"
        new_rank = upper + lower
        L = self.ndim
        seg_l = self.segments[iu + 1]
        counts = np.diff(seg_l)
        up_rep = np.repeat(self.coords[iu], counts, axis=0)
        merged = np.concatenate([up_rep, self.coords[iu + 1]], axis=1)

        coords = (self.coords[:iu] + [merged] + self.coords[iu + 2:])
        segments: List[Optional[np.ndarray]] = list(self.segments)
        if iu == 0:
            new_segments = [None] + segments[iu + 2:]
        else:
            # parent slice of the merged level: compose segments
            seg_u = self.segments[iu]
            new_seg = seg_l[seg_u]
            new_segments = segments[:iu] + [new_seg] + segments[iu + 2:]
        ranks = self.ranks[:iu] + [new_rank] + self.ranks[iu + 2:]
        shapes = {r: self.rank_shapes.get(r) for r in ranks}
        shapes[new_rank] = (self.rank_shapes.get(upper),
                            self.rank_shapes.get(lower))
        return CSF(self.name, ranks, coords, new_segments, self.values,
                   shapes, self.default, set(self.upper_ranks))

    def partition_uniform_shape(self, rank: str, size: int) -> "CSF":
        """Shape-based split: rank R -> [R1, R0], upper coordinates are
        (c // size) * size.  Matches FTensor.partition_uniform_shape."""
        depth = self.ranks.index(rank)
        if self.level_width(depth) != 1:
            raise ValueError("uniform_shape cannot partition flattened ranks")
        upper = (self.coords[depth][:, 0] // size) * size
        return self._partition(depth, upper[:, None])

    def partition_uniform_occupancy(self, rank: str, size: int) -> "CSF":
        """Occupancy-based split: boundaries every ``size`` elements of
        each fiber; upper coordinate = first coordinate of each chunk.
        Matches FTensor.partition_uniform_occupancy (self-leader form;
        leader-follower boundary adoption stays on the FTensor path)."""
        depth = self.ranks.index(rank)
        n = len(self.coords[depth])
        parent = self.expand_level(depth)
        if depth == 0:
            starts = np.zeros(1, dtype=np.int64)
        else:
            starts = self.segments[depth][:-1]
        # position within the owning fiber
        within = np.arange(n, dtype=np.int64) - starts[parent]
        chunk = within // size
        first = within - (within % size)     # fiber position of chunk head
        head = starts[parent] + first
        upper = self.coords[depth][head]     # coords of each chunk head
        return self._partition(depth, upper, chunk_key=chunk)

    def _partition(self, depth: int, upper: np.ndarray,
                   chunk_key: Optional[np.ndarray] = None) -> "CSF":
        """Insert a new level above ``depth`` grouping its elements by
        ``upper`` coordinate (within each parent fiber).  ``chunk_key``
        disambiguates groups whose upper coordinate could repeat."""
        rank = self.ranks[depth]
        parent = self.expand_level(depth)
        key = upper[:, 0] if chunk_key is None else chunk_key
        n = len(key)
        if n == 0:
            new_coords = np.zeros((0, upper.shape[1]), dtype=np.int64)
            new_seg = np.zeros(1, dtype=np.int64)
            group_of = np.zeros(0, dtype=np.int64)
        else:
            boundary = np.ones(n, dtype=bool)
            boundary[1:] = (parent[1:] != parent[:-1]) | (key[1:] != key[:-1])
            group_starts = np.flatnonzero(boundary)
            new_coords = upper[group_starts]
            group_of = np.cumsum(boundary) - 1
            # segments for the new level: child ranges in coords[depth]
            new_seg = np.append(group_starts, n)
            # segments for the parent level: group ranges per parent elem
            parent_of_group = parent[group_starts]

        upper_rank, lower_rank = rank + "1", rank + "0"
        ranks = (self.ranks[:depth] + [upper_rank, lower_rank]
                 + self.ranks[depth + 1:])

        if depth == 0:
            parent_seg: Optional[np.ndarray] = None
        else:
            n_parent = len(self.coords[depth - 1])
            cnt = np.zeros(n_parent, dtype=np.int64)
            if n:
                np.add.at(cnt, parent_of_group, 1)
            parent_seg = np.concatenate([[0], np.cumsum(cnt)])

        coords = (self.coords[:depth] + [new_coords, self.coords[depth]]
                  + self.coords[depth + 1:])
        segments: List[Optional[np.ndarray]] = (
            list(self.segments[:depth]) + [parent_seg, new_seg]
            + list(self.segments[depth + 1:]))
        shapes = {r: self.rank_shapes.get(r) for r in ranks}
        shapes[upper_rank] = self.rank_shapes.get(rank)
        shapes[lower_rank] = self.rank_shapes.get(rank)
        return CSF(self.name, ranks, coords, segments, self.values,
                   shapes, self.default,
                   set(self.upper_ranks) | {upper_rank})

    def rename_ranks(self, mapping: Dict[str, str]) -> "CSF":
        ranks = [mapping.get(r, r) for r in self.ranks]
        shapes = {mapping.get(r, r): s for r, s in self.rank_shapes.items()}
        return CSF(self.name, ranks, self.coords, self.segments, self.values,
                   shapes, self.default,
                   {mapping.get(r, r) for r in self.upper_ranks})

    def copy(self, name: Optional[str] = None) -> "CSF":
        return CSF(name or self.name, self.ranks,
                   [c.copy() for c in self.coords],
                   [None if s is None else s.copy() for s in self.segments],
                   self.values.copy(), dict(self.rank_shapes), self.default,
                   set(self.upper_ranks))


def _from_sorted_points(name: str, ranks: Sequence[str],
                        cols: List[np.ndarray], values: np.ndarray,
                        rank_shapes: Optional[Dict[str, Any]],
                        default: Any, upper_ranks: set,
                        leaf_unique: bool = False) -> "CSF":
    """Build a CSF from per-rank coordinate columns already sorted
    lexicographically outer->inner (one row per leaf).

    ``leaf_unique`` promises every row is a distinct point (e.g. the
    vector path's reduced groups): the innermost level then skips its
    boundary scan entirely -- every row starts a leaf fiber entry."""
    L = len(ranks)
    n = len(values)
    cols = [_as_coord_col(c) for c in cols]
    # prefix-change boundaries per level
    coords: List[np.ndarray] = []
    segments: List[Optional[np.ndarray]] = []
    if n == 0:
        return CSF(name, ranks, [np.zeros((0, c.shape[1])) for c in cols],
                   [None] + [np.zeros(1) for _ in range(L - 1)],
                   values, rank_shapes, default, upper_ranks)
    new_prefix = np.zeros(n, dtype=bool)
    new_prefix[0] = True
    prev_starts: Optional[np.ndarray] = None
    for d in range(L):
        c = cols[d]
        if leaf_unique and d == L - 1 and d > 0:
            # distinct rows: searchsorted(arange(n), x) == x, so the
            # level's starts are all rows and segments come straight
            # from the parent boundaries
            coords.append(c)
            assert prev_starts is not None
            segments.append(np.append(prev_starts, n).astype(np.int64))
            prev_starts = None
            break
        changed = np.zeros(n, dtype=bool)
        changed[0] = True
        if c.shape[1] == 1:              # skip the reduce over one column
            np.not_equal(c[1:, 0], c[:-1, 0], out=changed[1:])
        else:
            changed[1:] = np.any(c[1:] != c[:-1], axis=1)
        new_prefix = new_prefix | changed
        starts = np.flatnonzero(new_prefix)
        coords.append(c[starts])
        if d == 0:
            segments.append(None)
        else:
            # element i at level d-1 spans leaves
            # [prev_starts[i], prev_starts[i+1]); its children are the
            # level-d groups starting inside that span
            assert prev_starts is not None
            seg = np.searchsorted(starts, np.append(prev_starts, n))
            segments.append(seg.astype(np.int64))
        prev_starts = starts
    return CSF(name, ranks, coords, segments, values, rank_shapes,
               default, upper_ranks)
