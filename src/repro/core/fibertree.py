"""Fibertree abstraction (Sze et al. / TeAAL Section 2.1).

A fibertree represents an N-tensor as a tree with one level per rank.
Each level holds *fibers*: sorted sequences of (coordinate, payload)
elements, where payloads are scalars at the leaves and child fibers at
intermediate levels.

Supported content-preserving transformations (TeAAL Section 3.2):
  * rank flattening      -- combine two adjacent ranks (tuple coordinates)
  * rank partitioning    -- uniform_shape / uniform_occupancy (leader-follower)
  * rank swizzling       -- reorder tree levels

Dense <-> fibertree conversion is provided so every cascade evaluated on
fibertrees can be cross-checked against a dense einsum oracle.
"""
from __future__ import annotations

import bisect
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

Coord = Any  # int, or tuple of ints after flattening


class Fiber:
    """A sorted sequence of (coordinate, payload) elements."""

    __slots__ = ("coords", "payloads")

    def __init__(self, coords: Optional[List[Coord]] = None,
                 payloads: Optional[List[Any]] = None):
        self.coords: List[Coord] = list(coords) if coords else []
        self.payloads: List[Any] = list(payloads) if payloads else []
        assert len(self.coords) == len(self.payloads)

    # ------------------------------------------------------------------ #
    # basics
    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self.coords)

    def __iter__(self) -> Iterator[Tuple[Coord, Any]]:
        return zip(self.coords, self.payloads)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        items = ", ".join(f"{c}: {p!r}" for c, p in list(self)[:8])
        suffix = ", ..." if len(self) > 8 else ""
        return f"Fiber({{{items}{suffix}}})"

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Fiber):
            return NotImplemented
        return self.coords == other.coords and self.payloads == other.payloads

    def is_empty(self) -> bool:
        return not self.coords

    def lookup(self, coord: Coord) -> Optional[Any]:
        """Payload at ``coord`` or None."""
        i = bisect.bisect_left(self.coords, coord)
        if i < len(self.coords) and self.coords[i] == coord:
            return self.payloads[i]
        return None

    def insert(self, coord: Coord, payload: Any) -> None:
        i = bisect.bisect_left(self.coords, coord)
        if i < len(self.coords) and self.coords[i] == coord:
            self.payloads[i] = payload
        else:
            self.coords.insert(i, coord)
            self.payloads.insert(i, payload)

    def get_or_create(self, coord: Coord, default_factory: Callable[[], Any]):
        i = bisect.bisect_left(self.coords, coord)
        if i < len(self.coords) and self.coords[i] == coord:
            return self.payloads[i]
        payload = default_factory()
        self.coords.insert(i, coord)
        self.payloads.insert(i, payload)
        return payload

    def append(self, coord: Coord, payload: Any) -> None:
        """Fast-path insert when coord is known to be the largest so far."""
        assert not self.coords or coord > self.coords[-1], \
            f"append out of order: {coord} after {self.coords[-1]}"
        self.coords.append(coord)
        self.payloads.append(payload)

    # ------------------------------------------------------------------ #
    # co-iteration
    # ------------------------------------------------------------------ #
    def intersect(self, other: "Fiber") -> Iterator[Tuple[Coord, Any, Any]]:
        """Two-finger intersection: yields (coord, payload_a, payload_b)."""
        ia, ib = 0, 0
        a_c, b_c = self.coords, other.coords
        while ia < len(a_c) and ib < len(b_c):
            ca, cb = a_c[ia], b_c[ib]
            if ca == cb:
                yield ca, self.payloads[ia], other.payloads[ib]
                ia += 1
                ib += 1
            elif ca < cb:
                ia += 1
            else:
                ib += 1

    def union(self, other: "Fiber") -> Iterator[Tuple[Coord, Any, Any]]:
        """Yields (coord, payload_a_or_None, payload_b_or_None)."""
        ia, ib = 0, 0
        a_c, b_c = self.coords, other.coords
        while ia < len(a_c) or ib < len(b_c):
            if ib >= len(b_c) or (ia < len(a_c) and a_c[ia] < b_c[ib]):
                yield a_c[ia], self.payloads[ia], None
                ia += 1
            elif ia >= len(a_c) or b_c[ib] < a_c[ia]:
                yield b_c[ib], None, other.payloads[ib]
                ib += 1
            else:
                yield a_c[ia], self.payloads[ia], other.payloads[ib]
                ia += 1
                ib += 1

    def copy(self) -> "Fiber":
        return Fiber(
            list(self.coords),
            [p.copy() if isinstance(p, Fiber) else p for p in self.payloads],
        )


class FTensor:
    """A named fibertree: rank names (outer->inner) + root fiber + shapes.

    ``rank_shapes`` maps each rank name to its shape (max legal coordinate
    count); flattened ranks have tuple shapes; partitioned upper ranks
    inherit the source rank's shape.
    """

    def __init__(self, name: str, ranks: Sequence[str], root: Optional[Fiber] = None,
                 rank_shapes: Optional[Dict[str, Any]] = None,
                 default: Any = 0,
                 upper_ranks: Optional[set] = None):
        self.name = name
        self.ranks: List[str] = list(ranks)
        self.root: Fiber = root if root is not None else Fiber()
        self.rank_shapes: Dict[str, Any] = dict(rank_shapes or {})
        self.default = default
        # ranks created as the *upper* level of a partitioning: their
        # coordinates are partition starts, not content coordinates
        self.upper_ranks: set = set(upper_ranks or ())

    # ------------------------------------------------------------------ #
    # construction / conversion
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_dense(name: str, ranks: Sequence[str], array: np.ndarray,
                   default: Any = 0) -> "FTensor":
        array = np.asarray(array)
        assert array.ndim == len(ranks)

        def build(sub: np.ndarray) -> Fiber:
            fiber = Fiber()
            if sub.ndim == 1:
                for c in np.nonzero(sub)[0]:
                    fiber.append(int(c), sub[c].item())
            else:
                # keep a coordinate if any value beneath it is nonzero
                flat = sub.reshape(sub.shape[0], -1)
                for c in np.nonzero(np.any(flat != 0, axis=1))[0]:
                    fiber.append(int(c), build(sub[c]))
            return fiber

        shapes = {r: int(s) for r, s in zip(ranks, array.shape)}
        return FTensor(name, ranks, build(array), shapes, default)

    def to_dense(self) -> np.ndarray:
        """Materialize to a dense numpy array (unflattened ranks only)."""
        shape = [self._int_shape(r) for r in self.ranks]
        out = np.full(shape, self.default, dtype=np.float64)

        def fill(fiber: Fiber, idx: Tuple[int, ...]):
            for c, p in fiber:
                if isinstance(p, Fiber):
                    fill(p, idx + (c,))
                else:
                    out[idx + (c,)] = p

        fill(self.root, ())
        return out

    def _int_shape(self, rank: str) -> int:
        s = self.rank_shapes.get(rank)
        if s is None:
            # derive from data
            s = 0
            for path, _ in self.iter_leaves():
                s = max(s, path[self.ranks.index(rank)] + 1)
            self.rank_shapes[rank] = s
        return int(s)

    def copy(self, name: Optional[str] = None) -> "FTensor":
        return FTensor(name or self.name, self.ranks, self.root.copy(),
                       dict(self.rank_shapes), self.default,
                       set(self.upper_ranks))

    # ------------------------------------------------------------------ #
    # queries
    # ------------------------------------------------------------------ #
    @property
    def nnz(self) -> int:
        return sum(1 for _ in self.iter_leaves())

    @property
    def is_empty(self) -> bool:
        """True when the tensor holds no leaves.  O(depth), unlike
        ``nnz == 0`` which walks every leaf before comparing."""
        return next(self.iter_leaves(), None) is None

    def iter_leaves(self) -> Iterator[Tuple[Tuple[Coord, ...], Any]]:
        def rec(fiber: Fiber, path: Tuple[Coord, ...]):
            for c, p in fiber:
                if isinstance(p, Fiber):
                    yield from rec(p, path + (c,))
                else:
                    yield path + (c,), p

        yield from rec(self.root, ())

    def lookup(self, coords: Sequence[Coord]) -> Any:
        node: Any = self.root
        for c in coords:
            if not isinstance(node, Fiber):
                raise KeyError("path too deep")
            node = node.lookup(c)
            if node is None:
                return None
        return node

    def content_signature(self) -> List[Tuple[Tuple[Coord, ...], Any]]:
        """Multiset of (fully-unflattened point, value): the *content*.

        Flattened tuple coordinates are expanded and partitioned paths are
        collapsed to the innermost (original) coordinate so that content
        preservation can be asserted across any transformation chain.
        """
        sig = []
        for path, val in self.iter_leaves():
            flat: List[Coord] = []
            for rank, c in zip(self.ranks, path):
                if rank in self.upper_ranks:
                    continue                  # partition start, not content
                if isinstance(c, tuple):
                    flat.extend(c)
                else:
                    flat.append(c)
            sig.append((tuple(flat), val))
        return sorted(sig, key=lambda x: (str(x[0]), repr(x[1])))

    # ------------------------------------------------------------------ #
    # content-preserving transformations
    # ------------------------------------------------------------------ #
    def swizzle(self, new_order: Sequence[str]) -> "FTensor":
        """Rank swizzle: reorder fibertree levels to ``new_order``."""
        new_order = list(new_order)
        assert sorted(new_order) == sorted(self.ranks), \
            f"swizzle {self.ranks} -> {new_order} is not a permutation"
        if new_order == self.ranks:
            return self.copy()
        perm = [self.ranks.index(r) for r in new_order]
        out = FTensor(self.name, new_order, Fiber(),
                      {r: self.rank_shapes.get(r) for r in new_order},
                      self.default, set(self.upper_ranks))
        for path, val in self.iter_leaves():
            new_path = [path[i] for i in perm]
            node = out.root
            for c in new_path[:-1]:
                node = node.get_or_create(c, Fiber)
            node.insert(new_path[-1], val)
        return out

    def flatten_ranks(self, upper: str, lower: str) -> "FTensor":
        """Flatten adjacent ranks ``upper``, ``lower`` into one tuple-coord
        rank named ``upper+lower`` (TeAAL Fig. 2, first transformation)."""
        iu = self.ranks.index(upper)
        assert iu + 1 < len(self.ranks) and self.ranks[iu + 1] == lower, \
            f"{upper},{lower} must be adjacent in {self.ranks}"
        new_rank = upper + lower

        def rec(fiber: Fiber, depth: int) -> Fiber:
            if depth == iu:
                out = Fiber()
                for cu, pu in fiber:
                    assert isinstance(pu, Fiber)
                    for cl, pl in pu:
                        cu_t = cu if isinstance(cu, tuple) else (cu,)
                        cl_t = cl if isinstance(cl, tuple) else (cl,)
                        out.append(cu_t + cl_t, pl)
                return out
            out = Fiber()
            for c, p in fiber:
                out.append(c, rec(p, depth + 1))
            return out

        ranks = self.ranks[:iu] + [new_rank] + self.ranks[iu + 2:]
        shapes = {r: self.rank_shapes.get(r) for r in ranks}
        shapes[new_rank] = (self.rank_shapes.get(upper),
                            self.rank_shapes.get(lower))
        return FTensor(self.name, ranks, rec(self.root, 0), shapes,
                       self.default, set(self.upper_ranks))

    # -- partitioning ---------------------------------------------------- #
    def partition_uniform_shape(self, rank: str, size: int) -> "FTensor":
        """Shape-based split: boundaries at multiples of ``size``.

        Rank R becomes [R1, R0]; upper coordinates are i*size (the first
        legal coordinate of the partition, TeAAL Sec. 2.1/3.2.1).
        Renaming of already-partitioned ranks is handled by the mapping
        layer; here the new ranks are literally named ``rank+'1'``/``'0'``.
        """
        return self._partition(rank, lambda fiber: _shape_boundaries(fiber, size))

    def partition_uniform_occupancy(self, rank: str, size: int,
                                    leader: Optional["FTensor"] = None,
                                    leader_rank: Optional[str] = None) -> "FTensor":
        """Occupancy-based split with leader-follower semantics.

        If ``leader`` is None (or is this tensor) boundaries equalize *this*
        tensor's fiber occupancies; otherwise boundaries are adopted from the
        leader's fibers at ``leader_rank`` (matched by shared parent
        coordinates, TeAAL Sec. 3.2.1).
        """
        if leader is None or leader is self:
            return self._partition(
                rank, lambda fiber: _occupancy_boundaries(fiber, size))
        lrank = leader_rank or rank
        table = leader.boundary_table(lrank, size)
        # Shared parent ranks: leader ranks above ``lrank`` matched against
        # follower ranks above ``rank``.  A follower rank that was already
        # partitioned matches through its innermost level (e.g. follower
        # 'M0' matches leader 'M'), because only that level carries the
        # original coordinates used as leader-table keys.
        above_self = self.ranks[: self.ranks.index(rank)]
        above_leader = leader.ranks[: leader.ranks.index(lrank)]
        shared: List[str] = []
        for lr in above_leader:
            if lr in above_self:
                shared.append(lr)
            elif lr + "0" in above_self:
                shared.append(lr + "0")

        def chooser(fiber: Fiber, parent: Dict[str, Coord]) -> List[Coord]:
            key = tuple(parent[r] for r in shared)
            bounds = table.get(key)
            if bounds is None:
                # follower fiber with no matching leader fiber: fall back to
                # equalizing its own occupancy (empty leader partition).
                return _occupancy_boundaries(fiber, size)
            return bounds

        return self._partition(rank, chooser, pass_parent=True)

    def boundary_table(self, rank: str, size: int) -> Dict[Tuple, List[Coord]]:
        """Occupancy boundaries of every fiber at ``rank``, keyed by the
        coordinates of the ranks above it (outer->inner)."""
        depth = self.ranks.index(rank)
        table: Dict[Tuple, List[Coord]] = {}

        def rec(fiber: Fiber, d: int, path: Tuple[Coord, ...]):
            if d == depth:
                table[path] = _occupancy_boundaries(fiber, size)
                return
            for c, p in fiber:
                rec(p, d + 1, path + (c,))

        rec(self.root, 0, ())
        return table

    def _partition(self, rank: str, boundary_fn, pass_parent: bool = False
                   ) -> "FTensor":
        depth = self.ranks.index(rank)

        def rec(fiber: Fiber, d: int, parent: Dict[str, Coord]) -> Fiber:
            if d == depth:
                bounds = (boundary_fn(fiber, parent) if pass_parent
                          else boundary_fn(fiber))
                upper = Fiber()
                if not bounds:
                    return upper
                for bi, start in enumerate(bounds):
                    end = bounds[bi + 1] if bi + 1 < len(bounds) else None
                    lo = bisect.bisect_left(fiber.coords, start)
                    hi = (bisect.bisect_left(fiber.coords, end)
                          if end is not None else len(fiber.coords))
                    if lo == hi:
                        continue
                    upper.append(start, Fiber(fiber.coords[lo:hi],
                                              fiber.payloads[lo:hi]))
                return upper
            out = Fiber()
            for c, p in fiber:
                sub_parent = dict(parent)
                sub_parent[self.ranks[d]] = c
                out.append(c, rec(p, d + 1, sub_parent))
            return out

        new_upper, new_lower = rank + "1", rank + "0"
        ranks = (self.ranks[:depth] + [new_upper, new_lower]
                 + self.ranks[depth + 1:])
        shapes = {r: self.rank_shapes.get(r) for r in ranks}
        shapes[new_upper] = self.rank_shapes.get(rank)
        shapes[new_lower] = self.rank_shapes.get(rank)
        return FTensor(self.name, ranks, rec(self.root, 0, {}), shapes,
                       self.default, set(self.upper_ranks) | {new_upper})

    def rename_ranks(self, mapping: Dict[str, str]) -> "FTensor":
        ranks = [mapping.get(r, r) for r in self.ranks]
        shapes = {mapping.get(r, r): s for r, s in self.rank_shapes.items()}
        return FTensor(self.name, ranks, self.root, shapes, self.default,
                       {mapping.get(r, r) for r in self.upper_ranks})


# ---------------------------------------------------------------------- #
# boundary helpers
# ---------------------------------------------------------------------- #
def _shape_boundaries(fiber: Fiber, size: int) -> List[Coord]:
    if not fiber.coords:
        return []
    last = fiber.coords[-1]
    if isinstance(last, tuple):
        raise ValueError("uniform_shape cannot partition flattened ranks")
    return [i * size for i in range(int(last) // size + 1)]


def _occupancy_boundaries(fiber: Fiber, size: int) -> List[Coord]:
    """First coordinate of each occupancy-``size`` chunk of ``fiber``."""
    return [fiber.coords[i] for i in range(0, len(fiber.coords), size)]
