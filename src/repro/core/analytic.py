"""AnalyticBackend: closed-form evaluation of mapped loop nests.

The third execution engine (after ``PythonBackend`` and
``VectorBackend``): it never materializes output data.  Instead it
propagates per-rank occupancy expectations (``core/density.py``)
through the lowered loop nest -- the Sparseloop-style statistical
model, applied at the per-rank stream granularity the Sparse Abstract
Machine advocates -- and emits the same ``(einsum, tensor, rank,
kind)`` aggregate instrumentation keys the other backends emit, so
``metrics.evaluate``, the energy table, and ``Report`` work unchanged.

Modes (see DESIGN.md for the exactness contract):

  * ``calibrated`` (default) -- per-rank stats from a one-pass scan of
    the real exec-form tensors.  Aggregate action counts are **exact**
    on plans whose frontier covers every fiber of each tensor (dense /
    single-driver levels) and unbiased estimates under co-iteration.
  * ``hypergeometric`` / ``uniform`` -- pure statistical models from
    (shape, nnz) / (shape, density); no tensor scan at all.

Cascade intermediates are never materialized: their predicted output
stats are kept on the backend and re-projected (mean field) into the
consuming Einsum's execution order.  Semirings with vectorized forms
(arith, min-plus, or-and) and affine / constant index maps are modeled
natively: affine lookups apply the halo / boundary-occupancy hit
fraction from ``density.affine_hit_fraction``, and the output-collision
model is shared across semirings because the interpreter folds every
collision sequentially (idempotence licenses the vectorized reduceat
execution but does not change the count contract).  Plans outside the
supported class (flattened ranks, update-in-place outputs,
interpreter-only semirings, ...) fall back to ``PythonBackend`` per
Einsum, recording the reason in ``last_fallback_reason``.
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .density import (TensorDensity, affine_hit_fraction, expected_distinct,
                      occupancy_overlap, union_size)
from .einsum import BinOp, Literal, Semiring, Take, TensorAccess
from .fibertree import FTensor
from .iteration import EinsumExecutor, ExecutorBackend, PythonBackend
from .mapping import EinsumPlan
from .trace import Instrumentation, NullInstr


class _Unsupported(Exception):
    """Plan shape the analytic path does not cover (-> fallback)."""


def _bump(uniq: Dict[Tuple, float], key: Tuple, distinct: float) -> None:
    """Accumulate the distinct-element footprint behind an aggregate
    touch key (capped against the emitted n at emit time)."""
    uniq[key] = uniq.get(key, 0.0) + max(distinct, 0.0)


# ---------------------------------------------------------------------- #
# expression analysis
# ---------------------------------------------------------------------- #
def _classify_expr(expr) -> Tuple[str, List[TensorAccess]]:
    """('product', accesses) for pure multiplicative / take chains,
    ('sum', [lhs, rhs]) for two-term additions; raises otherwise."""
    accs: List[TensorAccess] = []

    def rec(e) -> bool:
        if isinstance(e, TensorAccess):
            accs.append(e)
            return True
        if isinstance(e, Literal):
            return True
        if isinstance(e, Take):
            return all(rec(a) for a in e.args)
        if isinstance(e, BinOp) and e.op == "*":
            return rec(e.lhs) and rec(e.rhs)
        return False

    if rec(expr) and accs:
        return "product", accs
    if (isinstance(expr, BinOp) and expr.op in "+-"
            and isinstance(expr.lhs, TensorAccess)
            and isinstance(expr.rhs, TensorAccess)):
        return "sum", [expr.lhs, expr.rhs]
    raise _Unsupported(f"expression shape {expr}")


def _index_kind(idx) -> str:
    """'bare' | 'const' | 'affine' for one access index."""
    if idx is None or idx.is_bare:
        return "bare"
    if not idx.terms:
        return "const"
    return "affine"


# ---------------------------------------------------------------------- #
# the backend
# ---------------------------------------------------------------------- #
class AnalyticBackend(ExecutorBackend):
    """Statistical / calibrated analytic execution engine."""

    name = "analytic"
    materializes = False

    def __init__(self, mode: str = "calibrated",
                 densities: Optional[Dict[str, float]] = None,
                 fallback: bool = True,
                 calib_cache: Optional[Dict] = None,
                 cache_token: Optional[str] = None):
        assert mode in ("calibrated", "uniform", "hypergeometric"), mode
        self.mode = mode
        self.densities = dict(densities or {})
        self.fallback = fallback
        self._oracle = PythonBackend()
        #: predicted stats of analytically-executed outputs, by name
        self._predicted: Dict[str, TensorDensity] = {}
        #: calibration cache: (token, tensor, exec_order) -> TensorDensity.
        #: Shared across backend instances by the DSE engine.
        self._calib: Dict[Tuple, TensorDensity] = (
            calib_cache if calib_cache is not None else {})
        #: set by the DSE engine to a per-(workload, mapping) token;
        #: caching is disabled when None (safe standalone default).
        self.cache_token = cache_token
        self.last_path: Optional[str] = None       # 'analytic' | 'fallback'
        self.last_fallback_reason: Optional[str] = None

    # ------------------------------------------------------------------ #
    # generator hooks
    # ------------------------------------------------------------------ #
    def prepare_inputs(self, plan: EinsumPlan,
                       tensors: Dict[str, FTensor],
                       var_shapes: Dict[str, int]) -> bool:
        """Return True when this Einsum needs exec-form tensor data
        (uncached calibration, or an unsupported plan that will fall
        back to the oracle).  False lets the generator skip
        ``transform_all`` entirely -- the memoized-calibration fast
        path the DSE engine relies on."""
        try:
            ex = self._executor(plan)
            self._analyze(ex, plan)
        except (_Unsupported, ValueError):
            # ValueError from EinsumExecutor mirrors _run_analytic's
            # conversion to a fallback: the oracle will need real data
            return True
        if self.cache_token is None:
            return True
        for t in plan.einsum.input_names:
            if t not in plan.tensors:
                return True
            ft = tensors.get(t)
            if ft is not None and ft.is_empty and t in self._predicted:
                continue                    # unmaterialized intermediate
            key = (self.cache_token, t, tuple(plan.tensors[t].exec_order))
            if key not in self._calib:
                return True
        return False

    def notify_copy(self, dst: str, src: str) -> None:
        """Follow whole-tensor aliases the generator short-circuits so
        predicted stats survive renames (e.g. 'P1 = P0')."""
        pred = self._predicted.get(src)
        if pred is not None:
            self._predicted[dst] = pred.renamed(dst, extra_source=src)

    def merge_estimate(self, tensor: str, stored_ranks: Sequence[str],
                       prefix_depth: int,
                       var_shapes: Dict[str, int]
                       ) -> Optional[List[Tuple[int, int]]]:
        """Analytic estimate of the online rank-swizzle (merger) work
        for an unmaterialized intermediate: one aggregate event with
        the total element count and the mean sorted-run count per merge
        (the fiber occupancy at the first discordant level)."""
        pred = self._predicted.get(tensor)
        if pred is None or pred.nnz <= 0:
            return None
        var_map = {r: (r.lower(),) for r in stored_ranks}
        shapes = {v: float(s) for v, s in var_shapes.items()}
        td = pred.project(list(stored_ranks), var_map, shapes)
        p = max(0, min(prefix_depth, len(td.levels) - 1))
        lists = max(1, int(round(td.levels[p].occupancy)))
        return [(int(round(td.nnz)), lists)]

    # ------------------------------------------------------------------ #
    def execute(self, plan, tensors, var_shapes, semiring=None, instr=None,
                out_initial=None, isect_strategy="two_finger",
                isect_leader=None) -> FTensor:
        instr = instr or NullInstr()
        semiring = semiring or Semiring.arithmetic()
        try:
            out = self._run_analytic(plan, tensors, var_shapes, semiring,
                                     instr, out_initial, isect_strategy,
                                     isect_leader)
            self.last_path = "analytic"
            self.last_fallback_reason = None
            return out
        except _Unsupported as exc:
            if not self.fallback:
                raise
            self.last_path = "fallback"
            self.last_fallback_reason = str(exc)
            return self._oracle.execute(
                plan, tensors, var_shapes, semiring=semiring, instr=instr,
                out_initial=out_initial, isect_strategy=isect_strategy,
                isect_leader=isect_leader)

    # ------------------------------------------------------------------ #
    # supported-plan analysis
    # ------------------------------------------------------------------ #
    @staticmethod
    def _executor(plan: EinsumPlan,
                  tensors: Optional[Dict[str, FTensor]] = None
                  ) -> EinsumExecutor:
        return EinsumExecutor(plan, tensors or {}, {}, instr=NullInstr())

    def _analyze(self, ex: EinsumExecutor, plan: EinsumPlan):
        einsum = ex.einsum
        if not einsum.output.indices and isinstance(einsum.expr,
                                                    TensorAccess):
            return "copy", [einsum.expr], []
        if any(not ix.is_bare for ix in einsum.output.indices):
            raise _Unsupported("non-bare output indices")
        if ex.unmatched_out:
            raise _Unsupported("output ranks bound at the leaf")
        if any(ri.flattened for ri in plan.loop_order):
            raise _Unsupported("flattened loop ranks")
        kind, accs = _classify_expr(einsum.expr)
        # affine / constant index maps are supported: they lower onto
        # catch-up lookups (see _lookup_schedule) with a halo-occupancy
        # hit fraction, mirroring the vector pipeline's Lookup.index
        order = [a.tensor for a in accs]
        levels: List[Tuple[str, List[Tuple[str, int]]]] = []
        for li, ri in enumerate(plan.loop_order):
            drv = [(t, ex.drive[t][li]) for t in order if li in ex.drive[t]]
            levels.append((ri.name, drv))
        if kind == "sum":
            all_levels = frozenset(range(len(plan.loop_order)))
            for t in order:
                if frozenset(ex.drive[t]) != all_levels:
                    raise _Unsupported("summands with unaligned ranks")
        return kind, accs, levels

    # ------------------------------------------------------------------ #
    # tensor stats acquisition
    # ------------------------------------------------------------------ #
    def _stats_for(self, t: str, plan: EinsumPlan,
                   tensors: Dict[str, Any],
                   var_shapes: Dict[str, int]) -> TensorDensity:
        exec_order = plan.tensors[t].exec_order
        key = ((self.cache_token, t, tuple(exec_order))
               if self.cache_token is not None else None)
        if key is not None and key in self._calib:
            return self._calib[key]
        shapes = {v: float(s) for v, s in (var_shapes or {}).items()}
        ft = tensors.get(t)
        nnz = ft.nnz if ft is not None else 0
        if ft is not None and nnz > 0:
            if self.mode == "calibrated":
                td = TensorDensity.calibrated(ft, var_map=plan.var_map,
                                              var_shapes=shapes)
            else:
                doms = [self._rank_domain(r, plan, shapes, ft)
                        for r in exec_order]
                if self.mode == "uniform":
                    total = 1.0
                    for d in doms:
                        total *= max(d, 1.0)
                    td = TensorDensity.uniform(t, exec_order, doms,
                                               nnz / max(total, 1.0),
                                               var_map=plan.var_map)
                else:
                    td = TensorDensity.hypergeometric(
                        t, exec_order, doms, nnz, var_map=plan.var_map)
            if key is not None:
                self._calib[key] = td
            return td
        pred = self._predicted.get(t)
        if pred is not None:
            return pred.project(exec_order, plan.var_map, shapes)
        dens = self.densities.get(t)
        if dens is not None:
            # declared density: pure-statistical evaluation, no data
            doms = [self._rank_domain(r, plan, shapes, ft)
                    for r in exec_order]
            return TensorDensity.uniform(t, exec_order, doms, dens,
                                         var_map=plan.var_map)
        # genuinely empty input: zero stats
        from .density import LevelStats
        lv = [LevelStats(r, 1.0 if d == 0 else 0.0, 0.0,
                         self._rank_domain(r, plan, shapes, ft))
              for d, r in enumerate(exec_order)]
        return TensorDensity(t, list(exec_order), lv, 0.0)

    @staticmethod
    def _rank_domain(rank: str, plan: EinsumPlan,
                     var_shapes: Dict[str, float],
                     ft: Optional[FTensor]) -> float:
        if ft is not None:
            s = ft.rank_shapes.get(rank)
            if isinstance(s, (int, float)) and s:
                return float(s)
        dom = 1.0
        known = False
        for v in plan.var_map.get(rank, (rank.lower(),)):
            s = var_shapes.get(v)
            if s:
                dom *= float(s)
                known = True
        return dom if known else 0.0

    # ------------------------------------------------------------------ #
    # the analytic walk
    # ------------------------------------------------------------------ #
    def _run_analytic(self, plan, tensors, var_shapes, semiring, instr,
                      out_initial, isect_strategy, isect_leader) -> FTensor:
        if out_initial is not None:
            raise _Unsupported("update-in-place output")
        if not semiring.has_vector_forms:
            raise _Unsupported(
                f"semiring {semiring.name} has no vectorized forms")
        try:
            ex = self._executor(plan, {t: v for t, v in tensors.items()
                                       if isinstance(v, FTensor)})
        except ValueError as e:
            raise _Unsupported(str(e))
        kind, accs, levels = self._analyze(ex, plan)
        name = plan.output
        shapes = {v: float(s) for v, s in (var_shapes or {}).items()}

        stats = {a.tensor: self._stats_for(a.tensor, plan, tensors,
                                           var_shapes)
                 for a in accs}
        counts: Counter = Counter()

        uniq: Dict[Tuple, float] = {}

        if kind == "copy":
            src = accs[0].tensor
            n = stats[src].nnz
            rank = plan.tensors[src].exec_order[-1] \
                if plan.tensors.get(src) else ""
            counts[("touch", src, rank, "payload", "r")] += n
            counts[("touch", name, rank, "payload", "w")] += n
            uniq[("touch", src, rank, "payload", "r")] = n
            uniq[("touch", name, rank, "payload", "w")] = n
            self._emit(instr, name, counts, uniq)
            self._predicted[name] = stats[src].renamed(name,
                                                       extra_source=src)
            return FTensor(name, list(plan.tensors[src].exec_order)
                           if plan.tensors.get(src) else [])

        leaf_depth = {t: len(plan.tensors[t].exec_order) - 1
                      for t in stats}
        lookups = self._lookup_schedule(ex, plan, accs)
        essential = ex._essential
        present: Dict[str, float] = {t: 1.0 for t in stats}
        points = 1.0
        pts_after: List[float] = []

        # depth-(-1) lookups: constant indices resolvable before the loop
        points = self._apply_lookups(lookups.get(-1, []), points, present,
                                     stats, leaf_depth, essential, counts,
                                     uniq, plan, shapes)

        for li, (rank, drv) in enumerate(levels):
            ri = plan.loop_order[li]
            dom = self._level_domain(ri, plan, shapes, drv, stats)
            if kind == "sum":
                points = self._union_level(rank, drv, dom, points, present,
                                           stats, leaf_depth, counts, uniq)
            elif not drv:
                # driverless: dense range over the rank's var
                if ri.flattened:
                    raise _Unsupported(f"driverless flattened rank {rank}")
                shape = shapes.get(ri.vars[0])
                if not shape:
                    raise _Unsupported(f"unknown shape for var "
                                       f"{ri.vars[0]!r}")
                counts[("iterate", rank)] += points * shape
                counts[("advance", rank)] += points * shape
                points *= shape
            elif len(drv) == 1:
                t, d = drv[0]
                occ = stats[t].occ(d)
                enum = points * occ
                counts[("touch", t, rank, "coord", "r")] += enum
                _bump(uniq, ("touch", t, rank, "coord", "r"),
                      stats[t].levels[d].elems)
                counts[("iterate", rank)] += enum
                counts[("advance", rank)] += enum
                if d == leaf_depth[t]:
                    counts[("touch", t, rank, "payload", "r")] += enum
                    _bump(uniq, ("touch", t, rank, "payload", "r"),
                          stats[t].nnz)
                points = enum
            else:
                aligned = plan.created_ranks.get(rank) == "upper"
                points = self._isect_level(rank, drv, dom, points, stats,
                                           leaf_depth, counts, uniq,
                                           isect_strategy, isect_leader,
                                           aligned=aligned)
            if ri.binds:
                points = self._apply_lookups(
                    lookups.get(li, []), points, present, stats,
                    leaf_depth, essential, counts, uniq, plan, shapes)
            pts_after.append(points)

        # ---- leaf evaluation + output accumulation
        p_nz, muls, adds_expr = self._eval_model(ex.einsum.expr, present)
        counts[("compute", "mul")] += points * muls
        counts[("compute", "add")] += points * adds_expr
        C = points * p_nz
        D = self._distinct_outputs(ex, plan, shapes, pts_after, C)
        out_rank = plan.tensors[name].exec_order[-1]
        counts[("touch", name, out_rank, "payload", "w")] += C
        counts[("touch", name, out_rank, "payload", "r")] += max(C - D, 0.0)
        counts[("compute", "add")] += max(C - D, 0.0)
        _bump(uniq, ("touch", name, out_rank, "payload", "w"), D)
        # accumulation reads hit data produced on chip: no cold fills
        uniq[("touch", name, out_rank, "payload", "r")] = 0.0

        self._emit(instr, name, counts, uniq)
        self._predicted[name] = self._predict_output(
            ex, plan, shapes, pts_after, D,
            sources=[a.tensor for a in accs], stats=stats)
        out_ranks = plan.tensors[name].exec_order
        return FTensor(name, list(out_ranks),
                       rank_shapes={r: None for r in out_ranks},
                       upper_ranks={r for r in out_ranks
                                    if plan.created_ranks.get(r) == "upper"})

    # ------------------------------------------------------------------ #
    def _level_domain(self, ri, plan, shapes, drv, stats) -> float:
        dom = 1.0
        known = False
        for v in ri.vars:
            s = shapes.get(v)
            if s:
                dom *= s
                known = True
        if known:
            return dom
        for t, d in drv:
            got = stats[t].domain(d)
            if got:
                return got
        return 0.0

    def _lookup_schedule(self, ex: EinsumExecutor, plan: EinsumPlan,
                         accs) -> Dict[int, List[Tuple[str, int, str, Any]]]:
        """loop level -> [(tensor, depth, rank, affine_index)] catch-up
        descents, mirroring ``EinsumExecutor._catch_up`` timing: a
        non-driving level descends at the first binding loop level where
        its index vars are all bound (level -1 for constant indices).
        ``affine_index`` is the declared non-bare ``AffineIndex`` at
        that level, or None for bare variable lookups."""
        var_bound_at: Dict[str, int] = {}
        for lj, rj in enumerate(plan.loop_order):
            if rj.binds:
                for v in rj.vars:
                    var_bound_at[v] = lj
        out: Dict[int, List[Tuple[str, int, str, Any]]] = {}
        for acc in accs:
            t = acc.tensor
            tp = plan.tensors[t]
            drive = ex.drive[t]
            inv = {d: l for l, d in drive.items()}
            prev = -1
            for d, rank in enumerate(tp.exec_order):
                if d in inv:
                    prev = max(prev, inv[d])
                    continue
                idx = ex._level_index(acc, tp, d)
                if idx is not None and idx.is_bare:
                    idx = None
                vars_ = (idx.vars if idx is not None
                         else ex._level_vars(acc, tp, d, rank))
                lv = max((var_bound_at.get(v, len(plan.loop_order))
                          for v in vars_), default=-1)
                if lv >= len(plan.loop_order):
                    raise _Unsupported(f"{t}: unbound lookup level {rank}")
                lv = max(lv, prev)
                out.setdefault(lv, []).append((t, d, rank, idx))
                prev = lv
        return out

    def _apply_lookups(self, items, points, present, stats, leaf_depth,
                       essential, counts, uniq, plan, shapes) -> float:
        for t, d, rank, idx in items:
            td = stats[t]
            counts[("touch", t, rank, "coord", "r")] += points * present[t]
            _bump(uniq, ("touch", t, rank, "coord", "r"),
                  td.levels[d].elems)
            if plan.created_ranks.get(rank) == "upper":
                p_hit = 1.0          # range positioning (bisect) hits
            else:
                dom = td.domain(d)
                p_hit = min(td.occ(d) / dom, 1.0) if dom > 0 else 1.0
                if idx is not None:
                    # affine / constant probe: only the in-range part of
                    # the probe span can hit (conv halo / boundary crop)
                    p_hit *= affine_hit_fraction(idx.terms, idx.const,
                                                 shapes, dom)
            if t in essential:
                points *= p_hit
            else:
                present[t] *= p_hit
            if d == leaf_depth[t]:
                counts[("touch", t, rank, "payload", "r")] += \
                    points * present[t]
                _bump(uniq, ("touch", t, rank, "payload", "r"), td.nnz)
        return points

    def _isect_level(self, rank, drv, dom, points, stats, leaf_depth,
                     counts, uniq, strategy, leader,
                     aligned: bool = False) -> float:
        """Fold >= 2 drivers at one loop rank through pairwise
        intersection, emitting the two-finger / leader-follower count
        model (see DESIGN.md for the formulas).  ``aligned`` marks
        partition-created upper ranks: both tensors tile the same
        coordinate grid, so their tile fibers intersect (nearly)
        completely rather than hypergeometrically."""
        (ta, da) = drv[0]
        occ_a = stats[ta].occ(da)
        merged = [ta]
        first = True
        for (tb, db) in [x for x in drv[1:]]:
            occ_b = stats[tb].occ(db)
            # correlated pair: an intermediate intersecting a tensor its
            # own structure was computed from (Gamma's T against A) --
            # the independence model would miss nearly every match
            corr = (tb in stats[ta].derived_from
                    or ta in stats[tb].derived_from)
            if aligned or corr:
                m_per = min(occ_a, occ_b)
            else:
                m_per = occupancy_overlap(occ_a, occ_b, dom or
                                          max(occ_a, occ_b, 1.0))
            if strategy == "leader_follower" and first:
                if ta == leader:
                    lead, lo, foll, fo = ta, occ_a, tb, occ_b
                elif tb == leader:
                    lead, lo, foll, fo = tb, occ_b, ta, occ_a
                elif occ_a <= occ_b:
                    lead, lo, foll, fo = ta, occ_a, tb, occ_b
                else:
                    lead, lo, foll, fo = tb, occ_b, ta, occ_a
                counts[("touch", lead, rank, "coord", "r")] += points * lo
                counts[("touch", foll, rank, "coord", "r")] += points * lo
                ld = dict(drv)
                _bump(uniq, ("touch", lead, rank, "coord", "r"),
                      stats[lead].levels[ld[lead]].elems)
                _bump(uniq, ("touch", foll, rank, "coord", "r"),
                      stats[foll].levels[ld[foll]].elems)
                counts[("isect_step", rank, lead)] += points * lo
            else:
                fa = occ_b / (occ_b + 1.0) if occ_b > 0 else 0.0
                fb = occ_a / (occ_a + 1.0) if occ_a > 0 else 0.0
                adv_a, adv_b = occ_a * fa, occ_b * fb
                if occ_a > 0 and occ_b > 0:
                    touched_a = min(adv_a + 1.0, occ_a)
                    touched_b = min(adv_b + 1.0, occ_b)
                else:
                    touched_a = touched_b = 0.0
                if first:
                    counts[("touch", ta, rank, "coord", "r")] += \
                        points * touched_a
                    _bump(uniq, ("touch", ta, rank, "coord", "r"),
                          stats[ta].levels[da].elems)
                counts[("touch", tb, rank, "coord", "r")] += \
                    points * touched_b
                _bump(uniq, ("touch", tb, rank, "coord", "r"),
                      stats[tb].levels[db].elems)
                for t in merged:
                    counts[("isect_step", rank, t)] += points * adv_a
                counts[("isect_step", rank, tb)] += points * adv_b
            counts[("isect_match", rank)] += points * m_per
            occ_a = m_per
            merged.append(tb)
            first = False
        matches = points * occ_a
        counts[("iterate", rank)] += matches
        counts[("advance", rank)] += matches
        for (t, d) in drv:
            if d == leaf_depth[t]:
                counts[("touch", t, rank, "payload", "r")] += matches
                _bump(uniq, ("touch", t, rank, "payload", "r"),
                      stats[t].nnz)
        return matches

    def _union_level(self, rank, drv, dom, points, present, stats,
                     leaf_depth, counts, uniq) -> float:
        if len(drv) != 2:
            raise _Unsupported(f"union with {len(drv)} drivers at {rank}")
        (ta, da), (tb, db) = drv
        occ_a, occ_b = stats[ta].occ(da), stats[tb].occ(db)
        pa, pb = present[ta], present[tb]
        u_both = union_size(occ_a, occ_b, dom or max(occ_a + occ_b, 1.0))
        # per-point union size, conditioned on which sides are present
        u = (pa * pb * u_both + pa * (1.0 - pb) * occ_a
             + (1.0 - pa) * pb * occ_b)
        counts[("touch", ta, rank, "coord", "r")] += points * pa * occ_a
        counts[("touch", tb, rank, "coord", "r")] += points * pb * occ_b
        _bump(uniq, ("touch", ta, rank, "coord", "r"),
              stats[ta].levels[da].elems)
        _bump(uniq, ("touch", tb, rank, "coord", "r"),
              stats[tb].levels[db].elems)
        counts[("iterate", rank)] += points * u
        counts[("advance", rank)] += points * u
        if da == leaf_depth[ta]:
            counts[("touch", ta, rank, "payload", "r")] += \
                points * pa * occ_a
            _bump(uniq, ("touch", ta, rank, "payload", "r"),
                  stats[ta].nnz)
        if db == leaf_depth[tb]:
            counts[("touch", tb, rank, "payload", "r")] += \
                points * pb * occ_b
            _bump(uniq, ("touch", tb, rank, "payload", "r"),
                  stats[tb].nnz)
        if u > 0:
            present[ta] = pa * occ_a / u
            present[tb] = pb * occ_b / u
        return points * u

    # ------------------------------------------------------------------ #
    def _eval_model(self, expr, present: Dict[str, float]
                    ) -> Tuple[float, float, float]:
        """(P(value nonzero), expected muls, expected adds) per leaf
        iteration point, mirroring ``EinsumExecutor._eval``'s
        zero-short-circuit count semantics."""
        if isinstance(expr, Literal):
            return (1.0 if expr.value else 0.0), 0.0, 0.0
        if isinstance(expr, TensorAccess):
            return present.get(expr.tensor, 1.0), 0.0, 0.0
        if isinstance(expr, Take):
            p, m, a = 1.0, 0.0, 0.0
            for arg in expr.args:
                pp, mm, aa = self._eval_model(arg, present)
                p *= pp
                m += mm
                a += aa
            return p, m, a
        if isinstance(expr, BinOp):
            pl, ml, al = self._eval_model(expr.lhs, present)
            pr, mr, ar = self._eval_model(expr.rhs, present)
            if expr.op == "*":
                return pl * pr, ml + mr + pl * pr, al + ar
            if expr.op == "+":
                return (pl + pr - pl * pr, ml + mr, al + ar + pl * pr)
            # '-': the interpreter always counts one add
            return (pl + pr - pl * pr, ml + mr, al + ar + 1.0)
        raise _Unsupported(f"bad expr {expr!r}")

    def _distinct_outputs(self, ex, plan, shapes, pts_after, C) -> float:
        out_levels = sorted(ex.out_descend)
        if not out_levels or C <= 0:
            return 0.0
        last = out_levels[-1]
        if set(out_levels) == set(range(last + 1)):
            # loop prefix descends output ranks only: every frontier
            # path at the last output level is a distinct output (exact)
            return min(pts_after[last], C)
        # reduction ranks interleave before the innermost output rank:
        # group by the clean output prefix, then a collision model over
        # the remaining output-coordinate space
        j = -1
        while j + 1 in ex.out_descend:
            j += 1
        G = pts_after[j] if j >= 0 else 1.0
        if G <= 0:
            return 0.0
        # total output-coordinate space: partitioned copies of one var
        # jointly bind it, so the product runs over distinct vars; each
        # clean-prefix group then owns a 1/G share of that space
        out_vars = set()
        for li in out_levels:
            out_vars.update(plan.loop_order[li].vars)
        total = 1.0
        for v in out_vars:
            total *= max(shapes.get(v, 1.0), 1.0)
        S = max(total / G, 1.0)
        return min(G * expected_distinct(S, C / G), C)

    def _predict_output(self, ex, plan, shapes, pts_after, D,
                        sources: Sequence[str] = (),
                        stats: Optional[Dict[str, TensorDensity]] = None
                        ) -> TensorDensity:
        """Per-level stats of the just-evaluated output, in its exec
        order: exact frontier ratios along the clean output prefix,
        then the remaining distinct coordinates distributed across the
        post-prefix output ranks in proportion to each level's frontier
        growth."""
        import math

        from .density import LevelStats

        name = plan.output
        out_ranks = plan.tensors[name].exec_order
        lv_of_depth = {d: l for l, d in ex.out_descend.items()}
        n_out = len(out_ranks)

        occs: List[Optional[float]] = []
        G = 1.0
        clean = True
        for depth in range(n_out):
            li = lv_of_depth.get(depth)
            if clean and li == depth and li < len(pts_after):
                prev = pts_after[li - 1] if li > 0 else 1.0
                occ = pts_after[li] / prev if prev > 0 else 0.0
                G *= max(occ, 0.0)
                occs.append(max(occ, 0.0))
            else:
                clean = False
                occs.append(None)
        R = D / G if G > 0 else 0.0
        open_idx = [i for i, o in enumerate(occs) if o is None]
        if open_idx:
            weights = []
            for i in open_idx:
                li = lv_of_depth[i]
                prev = pts_after[li - 1] if li > 0 else 1.0
                growth = pts_after[li] / prev if prev > 0 else 1.0
                weights.append(math.log(max(growth, 1.0 + 1e-9)))
            W = sum(weights)
            for i, w in zip(open_idx, weights):
                share = (w / W) if W > 0 else 1.0 / len(open_idx)
                occs[i] = max(R ** share, 1.0) if R >= 1.0 else \
                    max(R, 0.0) ** (1.0 / len(open_idx))

        levels: List[LevelStats] = []
        fibers = 1.0
        rank_marginals: Dict[str, float] = {}
        marginals: Dict[str, float] = {}
        domains: Dict[str, float] = {}
        for r, occ in zip(out_ranks, occs):
            dom = 1.0
            for v in plan.var_map.get(r, (r.lower(),)):
                s = shapes.get(v)
                if s:
                    dom *= s
                    domains[v] = s
                marginals[v] = min(marginals.get(v, 1.0) * max(occ, 1.0),
                                   s or float("inf")) if occ else \
                    marginals.get(v, 1.0)
            elems = fibers * (occ or 0.0)
            levels.append(LevelStats(r, fibers, elems, dom))
            rank_marginals[r] = occ or 0.0
            fibers = elems
        derived = frozenset(sources) | frozenset(
            x for s in (stats or {}).values() for x in s.derived_from)
        return TensorDensity(name, list(out_ranks), levels, D,
                             marginals=marginals, domains=domains,
                             rank_marginals=rank_marginals,
                             derived_from=derived)

    # ------------------------------------------------------------------ #
    def _emit(self, instr: Instrumentation, name: str,
              counts: Counter,
              uniq: Optional[Dict[Tuple, float]] = None) -> None:
        instr.begin_einsum(name)
        for key in sorted(counts, key=repr):
            n = int(round(counts[key]))
            if n <= 0:
                continue
            tag = key[0]
            if tag == "touch":
                _, tensor, rank, kindk, rw = key
                u = None
                if uniq is not None and key in uniq:
                    uv = uniq[key]
                    u = int(round(min(uv, n)))
                    if uv > 0:
                        u = max(u, 1)       # 0 is reserved for on-chip
                instr.touch(name, tensor, rank, (), kindk, rw, n=n,
                            unique=u)
            elif tag == "iterate":
                instr.iterate(name, key[1], n=n)
            elif tag == "advance":
                instr.advance(name, key[1], n=n)
            elif tag == "compute":
                instr.compute(name, key[1], n=n)
            elif tag == "isect_step":
                instr.isect_step(name, key[1], key[2], n=n)
            elif tag == "isect_match":
                instr.isect_match(name, key[1], n=n)
        instr.end_einsum(name)
