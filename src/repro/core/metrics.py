"""Action counts -> execution time and energy (TeAAL Sec. 4.3).

Execution time uses the paper's bottleneck analysis: per fusion block,
sum each component's busy time across the block's Einsums, take the
maximum component (the bottleneck), and sum block times across the
cascade.  DRAM is a component (bytes / bandwidth).

Energy uses an Accelergy-style per-action table (45 nm-class constants,
same structure Accelergy would emit; Accelergy itself is not available
offline -- noted in DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .cascade import fusion_blocks
from .components import PerformanceModel
from .mapping import EinsumPlan
from .spec import AcceleratorSpec

# ---------------------------------------------------------------------- #
# energy table (pJ) -- 45nm-class, Accelergy-style
# ---------------------------------------------------------------------- #
ENERGY_TABLE_PJ: Dict[str, float] = {
    "dram_per_byte": 32.0,        # HBM-class ~4 pJ/bit
    "sram_small_per_byte": 0.6,   # <= 64 KiB scratchpads
    "sram_large_per_byte": 1.2,   # MB-class caches / LLC
    "mul": 2.0,                   # 32-bit multiply
    "add": 0.5,                   # 32-bit add
    "isect_step": 0.3,            # comparator + pointer bump
    "merge_elem": 0.8,            # one element through one merger pass
    "seq_step": 0.1,              # sequencer coordinate enumeration
}

SMALL_BUFFER_BYTES = 64 * 1024


@dataclass
class ComponentTime:
    name: str
    seconds: float


@dataclass
class BlockReport:
    einsums: List[str]
    component_seconds: Dict[str, float]
    bottleneck: str
    seconds: float


@dataclass
class Report:
    """Summary statistics for one cascade execution on one design."""
    design: str
    blocks: List[BlockReport]
    seconds: float
    dram_read_bytes: float
    dram_write_bytes: float
    dram_bytes_per_einsum: Dict[str, float]
    energy_pj: float
    energy_breakdown_pj: Dict[str, float]
    action_counts: Dict[str, float]
    #: einsum -> reason, for Einsums the selected backend silently
    #: executed through the Python oracle instead of its fast path
    #: (filled by the generator; empty for PythonBackend runs)
    fallback_reasons: Dict[str, str] = field(default_factory=dict)
    #: einsum -> structured kernel-dispatch DowngradeEvents (guarded
    #: chain retries / downgrades / demotions recorded during that
    #: Einsum's execution; empty when all seams ran on their primary)
    downgrade_events: Dict[str, list] = field(default_factory=dict)
    #: {stage: host wall seconds} aggregated across the cascade from a
    #: profiling backend (VectorBackend pipeline stages: materialize /
    #: pair-merge / lookup / finalize / reduce / output-build); empty
    #: unless the backend profiled
    stage_seconds: Dict[str, float] = field(default_factory=dict)

    @property
    def dram_bytes(self) -> float:
        return self.dram_read_bytes + self.dram_write_bytes

    def summary(self) -> str:
        lines = [f"design={self.design} time={self.seconds:.6e}s "
                 f"dram={self.dram_bytes / 1e6:.3f}MB "
                 f"energy={self.energy_pj / 1e6:.3f}uJ"]
        for b in self.blocks:
            lines.append(f"  block {'+'.join(b.einsums)}: "
                         f"{b.seconds:.3e}s bottleneck={b.bottleneck}")
        return "\n".join(lines)


def evaluate(spec: AcceleratorSpec, plans: Dict[str, EinsumPlan],
             model: PerformanceModel) -> Report:
    """Produce the Report after the cascade has been executed through
    ``model`` (the PerformanceModel must already contain the counts)."""
    clock = spec.arch.clock_ghz
    model.finalize()
    blocks = fusion_blocks(spec, plans)

    block_reports: List[BlockReport] = []
    total = 0.0
    for block in blocks:
        comp_secs: Dict[str, float] = {}
        dram_bytes = 0.0
        for name in block:
            em = model.models[name]
            for cname, secs in em.component_seconds(clock).items():
                comp_secs[cname] = comp_secs.get(cname, 0.0) + secs
            dram_bytes += model.dram_bytes_per_einsum.get(name, 0.0)
        comp_secs[model.dram.name] = dram_bytes / (model.dram.bandwidth_gbs
                                                   * 1e9)
        bottleneck = max(comp_secs, key=comp_secs.get) if comp_secs else "-"
        secs = comp_secs.get(bottleneck, 0.0)
        block_reports.append(BlockReport(block, comp_secs, bottleneck, secs))
        total += secs

    # ---- energy
    acts: Dict[str, float] = {}
    for name, em in model.models.items():
        for k, v in em.action_counts().items():
            acts[k] = acts.get(k, 0.0) + v
    acts["dram_bytes"] = model.dram.total_bytes

    breakdown: Dict[str, float] = {}
    breakdown["dram"] = acts.get("dram_bytes", 0.0) \
        * ENERGY_TABLE_PJ["dram_per_byte"]
    # SRAM: approximate per-access bytes by fill/drain + access volume
    sram_bytes = 0.0
    for name, em in model.models.items():
        for (cname, tensor, kind), lvl in em._levels.items():
            per = ENERGY_TABLE_PJ["sram_small_per_byte"] \
                if lvl.width * lvl.depth <= SMALL_BUFFER_BYTES \
                else ENERGY_TABLE_PJ["sram_large_per_byte"]
            breakdown["sram"] = breakdown.get("sram", 0.0) + \
                (lvl.access_bytes + lvl.fill_bytes + lvl.drain_bytes) * per
    breakdown["mul"] = acts.get("mul", 0.0) * ENERGY_TABLE_PJ["mul"]
    breakdown["add"] = acts.get("add", 0.0) * ENERGY_TABLE_PJ["add"]
    breakdown["isect"] = acts.get("isect_step", 0.0) \
        * ENERGY_TABLE_PJ["isect_step"]
    breakdown["merge"] = acts.get("merge_elem", 0.0) \
        * ENERGY_TABLE_PJ["merge_elem"]
    energy = sum(breakdown.values())

    return Report(
        design=spec.name,
        blocks=block_reports,
        seconds=total,
        dram_read_bytes=model.dram.read_bytes,
        dram_write_bytes=model.dram.write_bytes,
        dram_bytes_per_einsum=dict(model.dram_bytes_per_einsum),
        energy_pj=energy,
        energy_breakdown_pj=breakdown,
        action_counts=acts,
    )


# ---------------------------------------------------------------------- #
# shared three-term bottleneck roofline (also used by launch/roofline)
# ---------------------------------------------------------------------- #
@dataclass
class RooflineTerms:
    """The same bottleneck-analysis structure applied to a TPU chip:
    compute / memory / collective, seconds each; max dominates."""
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def seconds(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(flops: float, bytes_hbm: float, bytes_collective: float,
             chips: int, peak_flops: float = 197e12,
             hbm_gbs: float = 819e9, link_gbs: float = 50e9
             ) -> RooflineTerms:
    """TPU v5e constants by default (bf16 peak, HBM bw, per-link ICI)."""
    return RooflineTerms(
        compute_s=flops / (chips * peak_flops),
        memory_s=bytes_hbm / (chips * hbm_gbs),
        collective_s=bytes_collective / (chips * link_gbs),
    )
