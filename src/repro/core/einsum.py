"""Extended Einsum notation (TeAAL Section 2.2 / 3.1).

Parses statements such as::

    Z[m, n] = A[k, m] * B[k, n]
    T[k, m, n] = take(A[k, m], B[k, n], 1)
    O[q] = I[q+s] * F[s]
    Y1[k0] = E[0, k0] - T[k0]
    P1 = P0                       (whole-tensor copy)

An Einsum specifies (1) the tensors and their ranks, (2) an iteration
space (the Cartesian product of all legal index-variable values) and
(3) the computation at each point.  Reduction over index variables
absent from the output uses the cascade's ``add`` operator (semiring-
redefinable, e.g. ``min`` for SSSP).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------- #
# AST
# ---------------------------------------------------------------------- #


@dataclass(frozen=True)
class AffineIndex:
    """An affine index expression: sum(coeff_i * var_i) + const."""
    terms: Tuple[Tuple[str, int], ...]   # ((var, coeff), ...)
    const: int = 0

    @property
    def vars(self) -> Tuple[str, ...]:
        return tuple(v for v, _ in self.terms)

    @property
    def is_bare(self) -> bool:
        return (len(self.terms) == 1 and self.terms[0][1] == 1
                and self.const == 0)

    def evaluate(self, bindings: Dict[str, int]) -> int:
        return self.const + sum(c * bindings[v] for v, c in self.terms)

    def __str__(self) -> str:
        parts = []
        for v, c in self.terms:
            parts.append(v if c == 1 else f"{c}{v}")
        if self.const or not parts:
            parts.append(str(self.const))
        return "+".join(parts)


@dataclass(frozen=True)
class TensorAccess:
    tensor: str
    indices: Tuple[AffineIndex, ...]

    @property
    def vars(self) -> Tuple[str, ...]:
        out: List[str] = []
        for idx in self.indices:
            for v in idx.vars:
                if v not in out:
                    out.append(v)
        return tuple(out)

    def __str__(self) -> str:
        return f"{self.tensor}[{', '.join(map(str, self.indices))}]"


@dataclass(frozen=True)
class Take:
    """take(a, b, which): 0 if either input is 0, else input ``which``."""
    args: Tuple["Expr", ...]
    which: int

    @property
    def vars(self) -> Tuple[str, ...]:
        out: List[str] = []
        for a in self.args:
            for v in expr_vars(a):
                if v not in out:
                    out.append(v)
        return tuple(out)

    def __str__(self) -> str:
        return f"take({', '.join(map(str, self.args))}, {self.which})"


@dataclass(frozen=True)
class BinOp:
    op: str                     # '*', '+', '-'
    lhs: "Expr"
    rhs: "Expr"

    def __str__(self) -> str:
        return f"({self.lhs} {self.op} {self.rhs})"


@dataclass(frozen=True)
class Literal:
    value: float

    def __str__(self) -> str:
        return str(self.value)


Expr = Any  # TensorAccess | Take | BinOp | Literal


def expr_vars(expr: Expr) -> Tuple[str, ...]:
    if isinstance(expr, (TensorAccess, Take)):
        return expr.vars
    if isinstance(expr, BinOp):
        out = list(expr_vars(expr.lhs))
        for v in expr_vars(expr.rhs):
            if v not in out:
                out.append(v)
        return tuple(out)
    return ()


def expr_accesses(expr: Expr) -> List[TensorAccess]:
    if isinstance(expr, TensorAccess):
        return [expr]
    if isinstance(expr, Take):
        out: List[TensorAccess] = []
        for a in expr.args:
            out.extend(expr_accesses(a))
        return out
    if isinstance(expr, BinOp):
        return expr_accesses(expr.lhs) + expr_accesses(expr.rhs)
    return []


@dataclass
class Einsum:
    """One mapped-Einsum statement: output access, RHS expression."""
    output: TensorAccess
    expr: Expr
    text: str = ""

    @property
    def out_vars(self) -> Tuple[str, ...]:
        return self.output.vars

    @property
    def in_vars(self) -> Tuple[str, ...]:
        return expr_vars(self.expr)

    @property
    def all_vars(self) -> Tuple[str, ...]:
        out = list(self.out_vars)
        for v in self.in_vars:
            if v not in out:
                out.append(v)
        return tuple(out)

    @property
    def reduced_vars(self) -> Tuple[str, ...]:
        return tuple(v for v in self.in_vars if v not in self.out_vars)

    @property
    def inputs(self) -> List[TensorAccess]:
        return expr_accesses(self.expr)

    @property
    def input_names(self) -> List[str]:
        seen: List[str] = []
        for a in self.inputs:
            if a.tensor not in seen:
                seen.append(a.tensor)
        return seen

    def __str__(self) -> str:
        return self.text or f"{self.output} = {self.expr}"


# ---------------------------------------------------------------------- #
# Parser
# ---------------------------------------------------------------------- #
_TOKEN_RE = re.compile(
    r"\s*(?:(?P<name>[A-Za-z_][A-Za-z_0-9]*)|(?P<num>\d+(?:\.\d+)?)"
    r"|(?P<sym>[\[\](),+\-*=]))")


class _Tokens:
    def __init__(self, text: str):
        self.toks: List[Tuple[str, str]] = []
        pos = 0
        while pos < len(text):
            m = _TOKEN_RE.match(text, pos)
            if not m or m.end() == pos:
                if text[pos:].strip():
                    raise SyntaxError(f"bad einsum token at: {text[pos:]!r}")
                break
            pos = m.end()
            for kind in ("name", "num", "sym"):
                if m.group(kind) is not None:
                    self.toks.append((kind, m.group(kind)))
                    break
        self.i = 0

    def peek(self) -> Optional[Tuple[str, str]]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> Tuple[str, str]:
        tok = self.peek()
        if tok is None:
            raise SyntaxError("unexpected end of einsum")
        self.i += 1
        return tok

    def expect(self, value: str) -> None:
        kind, v = self.next()
        if v != value:
            raise SyntaxError(f"expected {value!r}, got {v!r}")


def parse_einsum(text: str) -> Einsum:
    """Parse one statement ``LHS = RHS``.

    Memoized: parsing is a pure function of ``text`` and every AST
    node is a frozen dataclass, so specs built from the same
    expression share one parse.  Design-space sweeps rebuild specs
    per point and this dominates spec-construction cost otherwise.
    """
    cached = _PARSE_CACHE.get(text)
    if cached is None:
        lhs_text, rhs_text = text.split("=", 1)
        output = _parse_access(lhs_text.strip())
        expr = _parse_expr(_Tokens(rhs_text.strip()))
        cached = Einsum(output=output, expr=expr, text=text.strip())
        if len(_PARSE_CACHE) >= 4096:
            _PARSE_CACHE.clear()
        _PARSE_CACHE[text] = cached
    return cached


_PARSE_CACHE: Dict[str, Einsum] = {}


def _parse_access(text: str) -> TensorAccess:
    toks = _Tokens(text)
    kind, name = toks.next()
    assert kind == "name"
    if toks.peek() is None:           # bare tensor: P1 = P0
        return TensorAccess(name, ())
    toks.expect("[")
    indices: List[AffineIndex] = []
    while True:
        indices.append(_parse_affine(toks))
        kind, v = toks.next()
        if v == "]":
            break
        assert v == ","
    return TensorAccess(name, tuple(indices))


def _parse_affine(toks: _Tokens) -> AffineIndex:
    terms: List[Tuple[str, int]] = []
    const = 0
    sign = 1
    while True:
        kind, v = toks.next()
        if kind == "num":
            nxt = toks.peek()
            if nxt and nxt[1] == "*":          # 2*p
                toks.next()
                kind2, var = toks.next()
                assert kind2 == "name"
                terms.append((var, sign * int(v)))
            else:
                const += sign * int(float(v))
        elif kind == "name":
            terms.append((v, sign))
        else:
            raise SyntaxError(f"bad index term {v!r}")
        nxt = toks.peek()
        if nxt and nxt[1] in "+-":
            sign = 1 if nxt[1] == "+" else -1
            toks.next()
            continue
        break
    return AffineIndex(tuple(terms), const)


def _parse_expr(toks: _Tokens) -> Expr:
    node = _parse_term(toks)
    while True:
        nxt = toks.peek()
        if nxt and nxt[1] in "+-":
            op = toks.next()[1]
            rhs = _parse_term(toks)
            node = BinOp(op, node, rhs)
        else:
            return node


def _parse_term(toks: _Tokens) -> Expr:
    node = _parse_factor(toks)
    while True:
        nxt = toks.peek()
        if nxt and nxt[1] == "*":
            toks.next()
            rhs = _parse_factor(toks)
            node = BinOp("*", node, rhs)
        else:
            return node


def _parse_factor(toks: _Tokens) -> Expr:
    kind, v = toks.next()
    if kind == "num":
        return Literal(float(v))
    if kind == "sym" and v == "(":
        node = _parse_expr(toks)
        toks.expect(")")
        return node
    assert kind == "name", f"unexpected {v!r}"
    if v == "take":
        toks.expect("(")
        args: List[Expr] = []
        while True:
            args.append(_parse_expr(toks))
            kind2, v2 = toks.next()
            if v2 == ")":
                break
            assert v2 == ","
        which_lit = args.pop()
        assert isinstance(which_lit, Literal), "take() needs literal selector"
        return Take(tuple(args), int(which_lit.value))
    nxt = toks.peek()
    if nxt and nxt[1] == "[":
        toks.next()
        indices: List[AffineIndex] = []
        while True:
            indices.append(_parse_affine(toks))
            kind2, v2 = toks.next()
            if v2 == "]":
                break
            assert v2 == ","
        return TensorAccess(v, tuple(indices))
    return TensorAccess(v, ())


# ---------------------------------------------------------------------- #
# Semirings and dense-oracle evaluation
# ---------------------------------------------------------------------- #
@dataclass
class Semiring:
    """Redefinable (+, *) pair (TeAAL Sec. 8: e.g. SSSP uses (min, +)).

    The scalar callables (`add`/`mul`/`sub`) drive the fibertree
    interpreter; the vectorized forms (`add_vec`/`mul_vec`/`sub_vec`)
    drive the columnar `VectorBackend`.  A semiring without vectorized
    forms (``add_vec is None``) is interpreter-only: the vector lowering
    raises `_Unsupported` and the cascade falls back to the oracle.

    `add_ufunc` is set only when ``ufunc.reduceat`` over a group is
    bit-identical to a sequential left fold of `add` (true for `min`,
    which is exact under any association; NOT true for float `np.add`,
    whose reduce uses pairwise summation).  `annihilator` is the value
    that means "empty payload" in the fibertree (0 for every semiring
    here); `is_idempotent` marks ``add(x, x) == x`` reductions, which
    the analytic backend's collision model exploits.
    """
    add: Callable[[Any, Any], Any] = lambda a, b: a + b
    mul: Callable[[Any, Any], Any] = lambda a, b: a * b
    sub: Callable[[Any, Any], Any] = lambda a, b: a - b
    add_identity: Any = 0.0
    name: str = "arith"
    add_vec: Optional[Callable[[Any, Any], Any]] = None
    mul_vec: Optional[Callable[[Any, Any], Any]] = None
    sub_vec: Optional[Callable[[Any, Any], Any]] = None
    add_ufunc: Optional[Any] = None      # segmented-reduceat-safe ufunc
    annihilator: float = 0.0
    is_idempotent: bool = False

    @property
    def has_vector_forms(self) -> bool:
        return (self.add_vec is not None and self.mul_vec is not None
                and self.sub_vec is not None)

    @staticmethod
    def arithmetic() -> "Semiring":
        # add_ufunc stays None: np.add.reduce pairwise-sums floats, which
        # is not bit-identical to the interpreter's sequential fold.
        return Semiring(add_vec=np.add, mul_vec=np.multiply,
                        sub_vec=np.subtract)

    @staticmethod
    def min_plus() -> "Semiring":
        """SSSP: reduce with min, combine with +.  The additive identity is
        +inf, and 'zero' (the annihilator / empty payload) stays 0 in the
        fibertree which callers must account for."""
        return Semiring(add=min, mul=lambda a, b: a + b,
                        sub=lambda a, b: a - b,
                        add_identity=float("inf"), name="min_plus",
                        add_vec=np.minimum, mul_vec=np.add,
                        sub_vec=np.subtract, add_ufunc=np.minimum,
                        is_idempotent=True)

    @staticmethod
    def or_and() -> "Semiring":
        """BFS frontier expansion: reduce with OR, combine with AND.

        No `add_ufunc`: a single-contribution group must keep its raw
        payload (the interpreter never calls `add` for it), which any
        boolean reduceat would collapse to 1.0."""
        return Semiring(add=lambda a, b: float(bool(a) or bool(b)),
                        mul=lambda a, b: float(bool(a) and bool(b)),
                        sub=lambda a, b: float(bool(a) and not bool(b)),
                        add_identity=0.0, name="or_and",
                        add_vec=lambda a, b: np.where(
                            (a != 0) | (b != 0), 1.0, 0.0),
                        mul_vec=lambda a, b: (
                            (a != 0) & (b != 0)).astype(np.float64),
                        sub_vec=lambda a, b: (
                            (a != 0) & (b == 0)).astype(np.float64),
                        is_idempotent=True)


def eval_expr_point(expr: Expr, bindings: Dict[str, int],
                    tensors: Dict[str, np.ndarray],
                    semiring: Semiring) -> float:
    """Evaluate the RHS expression at one iteration-space point (dense)."""
    if isinstance(expr, Literal):
        return expr.value
    if isinstance(expr, TensorAccess):
        arr = tensors[expr.tensor]
        idx = tuple(ix.evaluate(bindings) for ix in expr.indices)
        for d, (i, s) in enumerate(zip(idx, arr.shape)):
            if i < 0 or i >= s:
                return 0.0
        return float(arr[idx]) if idx else float(arr)
    if isinstance(expr, Take):
        vals = [eval_expr_point(a, bindings, tensors, semiring)
                for a in expr.args]
        if any(v == 0 for v in vals):
            return 0.0
        return vals[expr.which]
    if isinstance(expr, BinOp):
        lv = eval_expr_point(expr.lhs, bindings, tensors, semiring)
        rv = eval_expr_point(expr.rhs, bindings, tensors, semiring)
        if expr.op == "*":
            # semiring mul with annihilator 0 (empty payload)
            if lv == 0 or rv == 0:
                return 0.0
            return semiring.mul(lv, rv)
        if expr.op == "+":
            if lv == 0:
                return rv
            if rv == 0:
                return lv
            return semiring.add(lv, rv)
        if expr.op == "-":
            return semiring.sub(lv, rv)
    raise TypeError(f"bad expr {expr!r}")


def dense_reference(einsum: Einsum, tensors: Dict[str, np.ndarray],
                    shapes: Dict[str, int],
                    semiring: Optional[Semiring] = None) -> np.ndarray:
    """Dense oracle: brute-force the full iteration space.

    Intended for validation on small tensors; the fibertree path
    (repro.core.generator) is the real evaluator.
    """
    semiring = semiring or Semiring.arithmetic()
    if not einsum.output.indices:        # bare copy: P1 = P0
        src = einsum.expr
        assert isinstance(src, TensorAccess)
        return np.array(tensors[src.tensor], copy=True)

    out_vars = list(einsum.out_vars)
    # one output dim per INDEX (constant indices -- e.g. E[0, k0] in the
    # FFT cascade -- still occupy a dimension); size = max value + 1
    max_bind = {v: shapes[v.upper()] - 1 for v in einsum.all_vars}
    out_shape = tuple(ix.evaluate(max_bind) + 1
                      for ix in einsum.output.indices)
    out = np.zeros(out_shape)
    filled = np.zeros(out_shape, dtype=bool)
    all_vars = list(einsum.all_vars)
    ranges = [range(shapes[v.upper()]) for v in all_vars]

    def rec(d: int, bindings: Dict[str, int]):
        if d == len(all_vars):
            val = eval_expr_point(einsum.expr, bindings, tensors, semiring)
            if val == 0:
                return
            oidx = tuple(ix.evaluate(bindings) for ix in einsum.output.indices)
            if any(i < 0 or i >= s for i, s in zip(oidx, out_shape)):
                return
            if filled[oidx]:
                out[oidx] = semiring.add(out[oidx], val)
            else:
                out[oidx] = val
                filled[oidx] = True
            return
        for val in ranges[d]:
            bindings[all_vars[d]] = val
            rec(d + 1, bindings)
        del bindings[all_vars[d]]

    rec(0, {})
    return out
