"""Per-rank occupancy / density models for the analytic backend.

Sparseloop (Wu et al.) showed that statistical density models make
execution-based evaluation of sparse accelerators *analytical*: instead
of walking every nonzero, propagate the expected fiber occupancy at
each rank of each tensor through the mapped loop nest.  This module
provides the distributions; ``core/analytic.py`` does the propagation.

Three occupancy models, all describing a tensor in a given rank order
as one ``LevelStats`` per rank (number of fibers at that level, total
elements, coordinate domain):

  * ``uniform``        -- i.i.d. Bernoulli(p) nonzeros: the occupancy of
                          a fiber at rank d is ``shape_d`` times the
                          probability that a subtree below is nonempty.
  * ``hypergeometric`` -- exactly ``nnz`` nonzeros placed uniformly
                          without replacement (fixed-budget sampling);
                          expectations via the hypergeometric inclusion
                          probability, computed in log space.
  * ``calibrated``     -- exact per-level totals from a one-pass scan of
                          a real tensor's CSF arrays (`len(coords[d])`
                          per level).  Expected counts derived from
                          calibrated stats are *exact* whenever the
                          analytic frontier covers every fiber of the
                          tensor (single-driver / dense-rank plans);
                          they are unbiased estimates under
                          intersection (see DESIGN.md).

``mean_field_levels`` rebuilds per-level stats for an arbitrary rank
order from (nnz, per-var marginals) -- the statistical bridge used for
cascade intermediates that the analytic backend never materializes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .fibertree import Fiber, FTensor


# ---------------------------------------------------------------------- #
# small combinatorial helpers
# ---------------------------------------------------------------------- #
def expected_distinct(domain: float, balls: float) -> float:
    """Expected number of distinct bins hit when ``balls`` balls land
    i.i.d. uniformly in ``domain`` bins: D * (1 - (1 - 1/D)^balls)."""
    if domain <= 0 or balls <= 0:
        return 0.0
    if domain <= 1:
        return 1.0
    # stable for large domain / small balls
    return domain * -math.expm1(balls * math.log1p(-1.0 / domain))


def occupancy_overlap(occ_a: float, occ_b: float, domain: float) -> float:
    """E[|A ∩ B|] for two independent uniform subsets of sizes occ_a,
    occ_b drawn from a domain of ``domain`` coordinates (the
    hypergeometric expectation n*K/N)."""
    if domain <= 0:
        return 0.0
    return min(occ_a * occ_b / domain, occ_a, occ_b)


def union_size(occ_a: float, occ_b: float, domain: float) -> float:
    """E[|A ∪ B|] under the same model."""
    return occ_a + occ_b - occupancy_overlap(occ_a, occ_b, domain)


def affine_span(terms: Sequence[Tuple[str, int]], const: float,
                var_shapes: Dict[str, float]) -> Tuple[float, float]:
    """[lo, hi] range of ``const + sum(coeff * v)`` when each var ``v``
    sweeps [0, shape_v); the probe span of an affine index map."""
    lo = hi = float(const)
    for v, cf in terms:
        s = max(float(var_shapes.get(v) or 1.0), 1.0)
        ext = float(cf) * (s - 1.0)
        if ext >= 0:
            hi += ext
        else:
            lo += ext
    return lo, hi


def affine_hit_fraction(terms: Sequence[Tuple[str, int]], const: float,
                        var_shapes: Dict[str, float],
                        domain: float) -> float:
    """Expected fraction of affine probes that land inside the target
    coordinate domain [0, domain) -- the halo / boundary-occupancy
    correction for affine-shifted lookups (e.g. conv's ``h = p + r``
    against an input of height H).

    Model: the probe value is uniform over its span [lo, hi] (exact for
    a single unit-coefficient term; a boundary-linear approximation for
    multi-term sums).  Valid-padding conv (H = P + R - 1) gives exactly
    1.0; shifted or cropped windows shed the out-of-range halo."""
    lo, hi = affine_span(terms, const, var_shapes)
    width = hi - lo + 1.0
    if width <= 0:
        return 0.0
    if domain <= 0:
        return 1.0                       # unknown domain: no correction
    overlap = min(hi + 1.0, domain) - max(lo, 0.0)
    return max(0.0, min(overlap / width, 1.0))


def stat_misses(n: float, unique: float, nbytes: float,
                capacity_bytes: float) -> float:
    """Expected misses of an aggregate touch under the Sparseloop-style
    statistical residency model: ``unique`` compulsory misses, plus --
    when the touched footprint exceeds capacity -- capacity misses on
    the reuse accesses proportional to the non-resident fraction of the
    working set.  The single scalar closed form shared by
    ``components.StorageLevel.touch_stat`` and its point-axis
    vectorization below (both must stay bit-identical)."""
    footprint = unique * nbytes
    misses = float(unique)
    if footprint > capacity_bytes and n > unique:
        misses += (n - unique) * (1.0 - capacity_bytes / footprint)
    return misses


def batched_stat_misses(n: float, unique: float, nbytes, capacity_bytes):
    """``stat_misses`` broadcast across a *point axis*: ``nbytes`` and
    ``capacity_bytes`` are arrays with one entry per design point (the
    swept scalar params -- e.g. a FiberCache capacity axis), evaluated
    in one numpy pass instead of a Python loop per point.

    Bit-identity contract: every arithmetic op mirrors the scalar
    closed form exactly (same +,-,*,/ on float64; no transcendentals),
    so ``batched_stat_misses(n, u, b, caps)[i] == stat_misses(n, u,
    b[i], caps[i])`` bitwise -- asserted by a parity test."""
    import numpy as np
    nbytes = np.asarray(nbytes, dtype=np.float64)
    caps = np.asarray(capacity_bytes, dtype=np.float64)
    footprint = unique * nbytes
    base = np.full(np.broadcast(footprint, caps).shape, float(unique))
    if n <= unique:
        return base
    with np.errstate(divide="ignore", invalid="ignore"):
        reuse = (n - unique) * (1.0 - caps / footprint)
    return np.where(footprint > caps, base + reuse, base)


def _log_nonempty_prob(inner: float, nnz: float, total: float) -> float:
    """log P(a block of ``inner`` positions holds >= 1 of ``nnz``
    nonzeros placed without replacement among ``total`` positions):
    1 - C(total - inner, nnz) / C(total, nnz)."""
    if nnz <= 0 or total <= 0 or inner >= total:
        return 0.0 if nnz > 0 and inner >= total else -math.inf
    # log C(total-inner, nnz) - log C(total, nnz)
    #   = sum_{i=0..nnz-1} log((total-inner-i) / (total-i))
    if total - inner < nnz:
        return 0.0                      # guaranteed nonempty
    lg = (math.lgamma(total - inner + 1) - math.lgamma(total - inner - nnz + 1)
          - math.lgamma(total + 1) + math.lgamma(total - nnz + 1))
    p_empty = math.exp(lg)
    return math.log1p(-p_empty) if p_empty < 1.0 else -math.inf


# ---------------------------------------------------------------------- #
# the stats records
# ---------------------------------------------------------------------- #
@dataclass
class LevelStats:
    """Occupancy statistics of one rank (level) of a tensor in a fixed
    rank order.  ``fibers`` is the expected number of fibers at this
    level (== elements at the level above, 1 at the root); ``elems`` the
    expected total number of coordinates across those fibers."""
    rank: str
    fibers: float
    elems: float
    domain: float                        # coordinate domain size

    @property
    def occupancy(self) -> float:
        """Expected coordinates per fiber, conditioned on the fiber
        existing."""
        return self.elems / self.fibers if self.fibers > 0 else 0.0


@dataclass
class TensorDensity:
    """Per-level occupancy stats of one tensor in one rank order, plus
    the order-independent summary (nnz, per-var marginals) used to
    re-derive stats for other rank orders."""
    name: str
    ranks: List[str]
    levels: List[LevelStats]
    nnz: float
    #: var -> expected number of distinct coordinates of that var
    marginals: Dict[str, float] = field(default_factory=dict)
    #: var -> coordinate domain size
    domains: Dict[str, float] = field(default_factory=dict)
    #: rank name -> expected per-fiber occupancy of that rank (carried
    #: across reorderings of predicted intermediates, where rank names
    #: -- including partition-created ones -- are shared between the
    #: producing and consuming plans)
    rank_marginals: Dict[str, float] = field(default_factory=dict)
    #: source tensors this tensor's structure was computed from
    #: (transitively); used to flag correlated intersections
    derived_from: frozenset = frozenset()

    def occ(self, depth: int) -> float:
        return self.levels[depth].occupancy

    def domain(self, depth: int) -> float:
        return self.levels[depth].domain

    # ------------------------------------------------------------------ #
    # calibrated: one-pass scan of real data
    # ------------------------------------------------------------------ #
    @staticmethod
    def calibrated(ft: "FTensor | Any",
                   var_map: Optional[Dict[str, Tuple[str, ...]]] = None,
                   var_shapes: Optional[Dict[str, float]] = None
                   ) -> "TensorDensity":
        """Exact per-level element totals from one pass over the tensor.

        Accepts an ``FTensor`` (walked once) or a ``CSF`` (read off the
        level arrays directly)."""
        from .csf import CSF                      # local: avoid cycle
        if isinstance(ft, CSF):
            name, ranks = ft.name, list(ft.ranks)
            per_level = [float(len(ft.coords[d])) for d in range(ft.ndim)]
            shapes = dict(ft.rank_shapes)
        else:
            name, ranks = ft.name, list(ft.ranks)
            per_level = [0.0] * len(ranks)

            def walk(fiber: Fiber, depth: int) -> None:
                per_level[depth] += len(fiber)
                if depth + 1 < len(ranks):
                    for _, child in fiber:
                        walk(child, depth + 1)

            if ranks:
                walk(ft.root, 0)
            shapes = dict(ft.rank_shapes)
        levels: List[LevelStats] = []
        fibers = 1.0
        for d, r in enumerate(ranks):
            dom = _rank_domain(r, shapes.get(r), var_map, var_shapes)
            levels.append(LevelStats(r, fibers, per_level[d], dom))
            fibers = per_level[d]
        nnz = per_level[-1] if per_level else 0.0
        return TensorDensity(name, ranks, levels, nnz,
                             marginals=_marginals_from_levels(
                                 ranks, levels, var_map),
                             domains=_domains_from_levels(
                                 ranks, levels, var_map))

    # ------------------------------------------------------------------ #
    # statistical models
    # ------------------------------------------------------------------ #
    @staticmethod
    def uniform(name: str, ranks: Sequence[str],
                shapes: Sequence[float], density: float,
                var_map: Optional[Dict[str, Tuple[str, ...]]] = None
                ) -> "TensorDensity":
        """i.i.d. Bernoulli(density) nonzeros over the dense shape."""
        density = min(max(density, 0.0), 1.0)
        ranks = list(ranks)
        levels: List[LevelStats] = []
        fibers = 1.0
        inner = [float(math.prod(shapes[d + 1:])) for d in range(len(ranks))]
        for d, r in enumerate(ranks):
            # P(a coordinate at this level is present) given its prefix
            # exists: 1 - (1-p)^(inner positions)
            if density >= 1.0:
                p_nonempty = 1.0
            else:
                p_nonempty = -math.expm1(inner[d] * math.log1p(-density)) \
                    if inner[d] > 0 else density
            elems = fibers * shapes[d] * p_nonempty
            levels.append(LevelStats(r, fibers, elems, float(shapes[d])))
            fibers = elems
        nnz = float(math.prod(shapes)) * density
        if levels:
            levels[-1] = LevelStats(levels[-1].rank, levels[-1].fibers,
                                    nnz, levels[-1].domain)
        return TensorDensity(name, ranks, levels, nnz,
                             marginals=_marginals_from_levels(
                                 ranks, levels, var_map),
                             domains=_domains_from_levels(
                                 ranks, levels, var_map))

    @staticmethod
    def hypergeometric(name: str, ranks: Sequence[str],
                       shapes: Sequence[float], nnz: float,
                       var_map: Optional[Dict[str, Tuple[str, ...]]] = None
                       ) -> "TensorDensity":
        """Exactly ``nnz`` nonzeros placed uniformly without
        replacement over the dense shape."""
        ranks = list(ranks)
        total = float(math.prod(shapes)) if shapes else 0.0
        nnz = min(float(nnz), total)
        levels: List[LevelStats] = []
        fibers = 1.0
        for d, r in enumerate(ranks):
            inner = float(math.prod(shapes[d + 1:]))
            lp = _log_nonempty_prob(inner, nnz, total)
            p_nonempty = math.exp(lp) if lp > -math.inf else 0.0
            # expected distinct prefixes of length d+1 across the whole
            # tensor; per-fiber occupancy follows by dividing by fibers
            n_prefix = float(math.prod(shapes[:d + 1]))
            elems = n_prefix * p_nonempty
            levels.append(LevelStats(r, fibers, elems, float(shapes[d])))
            fibers = elems
        if levels:
            levels[-1] = LevelStats(levels[-1].rank, levels[-1].fibers,
                                    nnz, levels[-1].domain)
        return TensorDensity(name, ranks, levels, nnz,
                             marginals=_marginals_from_levels(
                                 ranks, levels, var_map),
                             domains=_domains_from_levels(
                                 ranks, levels, var_map))

    # ------------------------------------------------------------------ #
    # reorder / re-derive (mean field)
    # ------------------------------------------------------------------ #
    def renamed(self, name: str, extra_source: Optional[str] = None
                ) -> "TensorDensity":
        """Deep-ish copy under a new tensor name, optionally recording
        one more provenance source (whole-tensor alias/copy)."""
        derived = self.derived_from
        if extra_source is not None:
            derived = derived | frozenset([extra_source])
        return TensorDensity(name, list(self.ranks), list(self.levels),
                             self.nnz, dict(self.marginals),
                             dict(self.domains), dict(self.rank_marginals),
                             derived)

    def project(self, ranks: Sequence[str],
                var_map: Dict[str, Tuple[str, ...]],
                var_shapes: Dict[str, float]) -> "TensorDensity":
        """Stats for a *different* rank order of the same content, via
        the mean-field model (exact totals are order-dependent; this is
        the documented statistical bridge for predicted intermediates
        and online-swizzled tensors)."""
        if list(ranks) == self.ranks:
            return self
        return mean_field_density(self.name, ranks, var_map, self.nnz,
                                  self.marginals, self.domains or
                                  {v: var_shapes.get(v, 0.0)
                                   for v in var_shapes},
                                  rank_marginals=self.rank_marginals,
                                  derived_from=self.derived_from)


# ---------------------------------------------------------------------- #
def _rank_domain(rank: str, shape: Any,
                 var_map: Optional[Dict[str, Tuple[str, ...]]],
                 var_shapes: Optional[Dict[str, float]]) -> float:
    if isinstance(shape, (int, float)) and shape:
        return float(shape)
    if var_map and var_shapes:
        vars_ = var_map.get(rank, (rank.lower(),))
        dom = 1.0
        known = False
        for v in vars_:
            s = var_shapes.get(v)
            if s:
                dom *= float(s)
                known = True
        if known:
            return dom
    return 0.0


def _marginals_from_levels(ranks: Sequence[str], levels: List[LevelStats],
                           var_map: Optional[Dict[str, Tuple[str, ...]]]
                           ) -> Dict[str, float]:
    """Distinct-coordinate estimate per index var: the occupancy of the
    var's *outermost* level (distinct values across the whole tensor
    approximated by the first level that spans the var)."""
    out: Dict[str, float] = {}
    for r, lv in zip(ranks, levels):
        vars_ = (var_map or {}).get(r, (r.lower(),))
        for v in vars_:
            if v not in out:
                out[v] = max(lv.elems, 1.0) if lv.elems > 0 else 0.0
    return out


def _domains_from_levels(ranks: Sequence[str], levels: List[LevelStats],
                         var_map: Optional[Dict[str, Tuple[str, ...]]]
                         ) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for r, lv in zip(ranks, levels):
        vars_ = (var_map or {}).get(r, (r.lower(),))
        if len(vars_) == 1 and lv.domain:
            out.setdefault(vars_[0], lv.domain)
    return out


def mean_field_density(name: str, ranks: Sequence[str],
                       var_map: Dict[str, Tuple[str, ...]],
                       nnz: float, var_marginals: Dict[str, float],
                       var_domains: Dict[str, float],
                       rank_marginals: Optional[Dict[str, float]] = None,
                       derived_from: frozenset = frozenset()
                       ) -> TensorDensity:
    """Build per-level stats for an arbitrary rank order from the
    order-independent summary (nnz + marginals).

    Walks the ranks outer->inner keeping U = expected leaves below one
    fiber; the occupancy at each level is the expected number of
    distinct coordinates among U leaves whose coordinate is uniform
    over the rank's available values.  A per-rank marginal (known
    per-fiber occupancy of the same rank name in another order, e.g. a
    partition-created M0 of width 32) takes precedence; otherwise vars
    that span several ranks split their var marginal evenly in log
    space across the occurrences."""
    ranks = list(ranks)
    rank_marginals = rank_marginals or {}
    occur: Dict[str, int] = {}
    for r in ranks:
        for v in var_map.get(r, (r.lower(),)):
            occur[v] = occur.get(v, 0) + 1
    levels: List[LevelStats] = []
    fibers = 1.0
    U = max(nnz, 0.0)
    for r in ranks:
        vars_ = var_map.get(r, (r.lower(),))
        dom = rank_marginals.get(r)
        if dom is None:
            dom = 1.0
            for v in vars_:
                m = var_marginals.get(v, var_domains.get(v, 1.0))
                k = occur.get(v, 1)
                dom *= max(m ** (1.0 / k), 1.0) if m > 0 else 1.0
        occ = min(expected_distinct(dom, U), U) if U > 0 else 0.0
        occ = max(occ, 1.0) if U > 0 else 0.0
        elems = fibers * occ
        levels.append(LevelStats(r, fibers, elems, dom))
        fibers = elems
        U = U / occ if occ > 0 else 0.0
    if levels and nnz > 0:
        levels[-1] = LevelStats(levels[-1].rank, levels[-1].fibers,
                                max(nnz, levels[-1].fibers),
                                levels[-1].domain)
    return TensorDensity(name, ranks, levels,
                         levels[-1].elems if levels else 0.0,
                         marginals=dict(var_marginals),
                         domains=dict(var_domains),
                         rank_marginals=dict(rank_marginals),
                         derived_from=derived_from)
