"""VectorBackend: columnar per-rank co-iteration over CSF arrays.

Execution is a three-stage pipeline (DESIGN.md):

  1. ``core/vplan.py`` lowers the ``EinsumPlan`` into the **VectorPlan
     IR** -- a per-loop-rank list of typed ops (``Drive`` /
     ``Intersect`` / ``UnionK`` / ``DenseEnumerate`` / ``Lookup``) plus
     a ``Reduce``; every unsupported-plan decision happens there, so
     once lowering succeeds execution cannot bail mid-flight (the one
     data-dependent exception, ``_CapacityExceeded`` on int64 key
     overflow, also routes to the interpreter fallback).
  2. For the columnar entry point (``execute_csf``) a **pre-pass**
     applies the Einsum's Section-3.2 transform recipe (flatten /
     uniform partitioning / swizzle) directly on the CSF arrays.
  3. This module **executes** the IR one rank at a time: the set of
     live iteration points at each loop level (the frontier) is a
     struct-of-arrays, and each IR op maps onto a batched kernel
     primitive via ``_DISPATCH`` -- segment expansion, offset-keyed
     sorted intersection / k-ary union / probe gathers
     (``repro.kernels.ops``: Pallas kernels on TPU, ``searchsorted``
     lowerings on CPU), and a segmented in-order reduction.

Instrumentation counts are emitted in aggregate (one ``n``-weighted
call per action kind) and match the interpreter's per-element counts
exactly -- including the lazy-pull semantics of nested two-finger
intersections, leader-follower probing, and catch-up lookups; output
fibertrees are bit-identical, including float accumulation order.
Semirings with vectorized forms (min-plus, or-and) parameterize leaf
compute and the segmented reduction; affine / constant access indices
translate coordinates on the ``Lookup`` probe stream; update-in-place
outputs seed the reduction groups from the existing tensor's points.
Plans still outside the IR -- bare copies, sums of non-atomic or
rank-unaligned terms, affine output indices, interpreter-only
semirings -- transparently fall back to ``PythonBackend``, so
``VectorBackend`` is safe as a drop-in default.
"""
from __future__ import annotations

import time
from collections import Counter
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import metrics as _obs_metrics
from repro.obs.spans import active_tracer

from .csf import CSF, _from_sorted_points
from .einsum import BinOp, Semiring, Take, TensorAccess
from .fibertree import FTensor
from .guards import check_conservation, check_finite
from .iteration import ExecutorBackend, PythonBackend
from .mapping import EinsumPlan
from .trace import Instrumentation, NullInstr
from .vplan import (DenseEnumerate, Drive, Intersect, LevelIR, Lookup,
                    UnionK, VectorPlan, _Unsupported, lower,
                    prepare_csf_inputs)

#: level-0 frontier slice size used to bound peak expansion memory when
#: the outermost loop rank is an output rank (slices are independent).
#: 512 measures ~15% faster than 1024 on 10k x 10k @ 1% SpMSpM: the
#: per-chunk working set stays closer to cache and large allocations
#: churn less
DEFAULT_CHUNK_ITEMS = 512

#: widest dense group-accumulator the fused leaf reduction will
#: allocate (slots; float64 sums + int64 counts ~= 16 B/slot)
DENSE_GROUP_CAP = 1 << 25

_I32_N = 1 << 31

#: pipeline-stage order used when synthesizing stage spans from the
#: accumulated profile timers (matches the stage_times key set)
STAGE_ORDER = ("materialize", "pair-merge", "lookup", "finalize",
               "reduce", "output-build")


# ---------------------------------------------------------------------- #
# batched helpers
# ---------------------------------------------------------------------- #
def _expand(lo: np.ndarray, hi: np.ndarray
            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Flatten per-item [lo, hi) ranges: (item_of, elem, counts, offs).

    ``item_of`` / ``elem`` come out int32 whenever they fit -- the
    expansion dominates peak bandwidth on the hot path, and every
    downstream consumer that multiplies them into packed int64 keys
    upcasts explicitly (NumPy 2 no longer value-promotes)."""
    counts = (hi - lo).astype(np.int64)
    total = int(counts.sum())
    idt = np.int32 if total < _I32_N and len(counts) < _I32_N else np.int64
    item_of = np.repeat(np.arange(len(counts), dtype=idt), counts)
    offs = np.zeros(len(counts) + 1, dtype=np.int64)
    np.cumsum(counts, out=offs[1:])
    elem = np.repeat((lo - offs[:-1]).astype(idt), counts)
    elem += np.arange(total, dtype=idt)
    return item_of, elem, counts, offs


class _Workspace:
    """Persistent per-backend scratch: named flat buffers grown
    geometrically and reused across chunks, levels, and Einsums of a
    batch, so the widest allocations of the hot loop stop cycling
    through the allocator."""

    __slots__ = ("_bufs",)

    def __init__(self):
        self._bufs: Dict[str, np.ndarray] = {}

    def buf(self, tag: str, n: int, dtype) -> np.ndarray:
        b = self._bufs.get(tag)
        if b is None or len(b) < n or b.dtype != np.dtype(dtype):
            cap = max(n, 1024, 0 if b is None else 2 * len(b))
            b = np.empty(cap, dtype=dtype)
            self._bufs[tag] = b
        return b[:n]

    def clear(self) -> None:
        self._bufs.clear()


class _CapacityExceeded(Exception):
    """Packed int64 sort keys would overflow for this data (frontier
    size x coordinate domain beyond 2^62).  The one data-dependent
    limit of the vector path: ``execute()`` falls back to the
    interpreter, which has no such bound."""


def _pack_factors(width: int, coord_arrays, n_groups: int
                  ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Shared coordinate-key packing: per-column domain sizes over all
    ``coord_arrays`` ([n, width] each), mixed-radix factors, and the
    per-group multiplier.  Packed keys
    (group * group_mult + coord . factors) must stay below 2^62."""
    mults = np.ones(width, dtype=np.int64)
    for c in coord_arrays:
        if len(c):
            mults = np.maximum(mults, c.max(axis=0).astype(np.int64) + 1)
    factors = np.ones(width, dtype=np.int64)
    for j in range(width - 2, -1, -1):
        factors[j] = factors[j + 1] * mults[j + 1]
    group_mult = int(factors[0] * mults[0])
    if max(n_groups, 1) * max(group_mult, 1) >= (1 << 62):
        raise _CapacityExceeded("coordinate key overflow")
    return mults, factors, group_mult


def _prefix_present(present: np.ndarray, offs: np.ndarray,
                    k: np.ndarray) -> np.ndarray:
    """Per item: how many of its first ``k`` stream elements satisfy
    ``present`` (consumption happens in stream order)."""
    cp = np.zeros(len(present) + 1, dtype=np.int64)
    np.cumsum(present, out=cp[1:])
    idx = np.minimum(offs[:-1] + k, offs[1:])
    return cp[idx] - cp[offs[:-1]]


def _gather_at(arr: np.ndarray, offs: np.ndarray, k: np.ndarray,
               mask: np.ndarray) -> np.ndarray:
    """arr[offs[i] + k[i] - 1] per masked item (0 elsewhere)."""
    out = np.zeros(len(k), dtype=np.int64)
    if mask.any() and len(arr):
        idx = np.minimum(offs[:-1] + np.maximum(k, 1) - 1, len(arr) - 1)
        vals = arr[idx]
        out[mask] = vals[mask]
    return out


class _Frontier:
    """Live iteration points: per-tensor element positions, captured
    output coordinate columns, and captured index-var value columns.
    ``pos`` semantics: >= 0 element index at the tensor's current
    depth, -1 absent (union miss / failed lookup), -2 not yet
    descended (root)."""

    __slots__ = ("n", "pos", "out_cols", "var_cols")

    def __init__(self, n: int, pos: Dict[str, np.ndarray],
                 out_cols: List[np.ndarray],
                 var_cols: Dict[str, np.ndarray]):
        self.n = n
        self.pos = pos
        self.out_cols = out_cols
        self.var_cols = var_cols

    def take(self, idx: np.ndarray, extra_col: Optional[np.ndarray] = None,
             skip_pos=()) -> "_Frontier":
        """Gather rows ``idx``; tensors in ``skip_pos`` get a dropped
        (unset) position -- callers that overwrite those entries from a
        stream right after skip the wasted full-frontier gather."""
        cols = [c[idx] for c in self.out_cols]
        if extra_col is not None:
            cols.append(extra_col)
        return _Frontier(len(idx),
                         {t: p[idx] for t, p in self.pos.items()
                          if t not in skip_pos},
                         cols, {v: c[idx] for v, c in self.var_cols.items()})

    def slice(self, i0: int, i1: int) -> "_Frontier":
        return _Frontier(i1 - i0,
                         {t: p[i0:i1] for t, p in self.pos.items()},
                         [c[i0:i1] for c in self.out_cols],
                         {v: c[i0:i1] for v, c in self.var_cols.items()})

    def filter(self, keep: np.ndarray) -> "_Frontier":
        idx = np.flatnonzero(keep)
        return self.take(idx)


class _Stream:
    """Per-item sorted element stream of one co-iteration node: keys
    embed the item index (``item * item_mult + packed coord``), so all
    per-item merges collapse into single sorted-array kernel calls.
    Keys are built lazily -- a level with a single driver never packs
    them (the hot single-tensor expansion stays int32)."""

    __slots__ = ("keys", "item_of", "counts", "offs", "coord", "pos")

    def __init__(self, keys, item_of, counts, offs, coord, pos):
        self.keys = keys                     # [n] int64 sorted (or None)
        self.item_of = item_of
        self.counts = counts
        self.offs = offs
        self.coord = coord                   # [n, width] int
        self.pos = pos                       # tensor -> element index / -1

    @property
    def n(self) -> int:
        return len(self.item_of)


# ---------------------------------------------------------------------- #
# runtime co-iteration nodes: materialized stream + exact lazy-pull
# accounting.  account(y, d) receives, per frontier item, how many
# elements the parent pulled from this node (y) and whether the parent
# drained it to completion (d); it emits this node's instrumentation
# counts and propagates consumption to its children.
# ---------------------------------------------------------------------- #
class _RtDrive:
    all_present = True

    def __init__(self, node: Drive, stream: _Stream):
        self.node = node
        self.stream = stream

    def account(self, counts: Counter, rank: str, y: np.ndarray,
                d: np.ndarray) -> None:
        n = int(y.sum())
        if n:
            counts[("touch", self.node.tensor, rank, "coord", "r")] += n


class _RtPair:
    """Two-finger pairwise intersection (the interpreter's
    ``_intersect2`` generator, vectorized with its exact pull
    accounting)."""

    all_present = True

    def __init__(self, left, right, stream: _Stream,
                 sel: np.ndarray, idx_sel: np.ndarray,
                 std_adv_l: np.ndarray, std_adv_r: np.ndarray):
        self.left = left
        self.right = right
        self.stream = stream
        self.sel = sel                       # match positions in left
        self.idx_sel = idx_sel               # match positions in right
        self.std_adv_l = std_adv_l
        self.std_adv_r = std_adv_r

    def account(self, counts, rank, y, d):
        counts[("isect_match", rank)] += int(y.sum())
        st = self.stream
        part = (~d) & (y > 0)
        any_part = bool(part.any())
        for side, within_src, std_adv in (
                (self.left, self.sel, self.std_adv_l),
                (self.right, self.idx_sel, self.std_adv_r)):
            ns = side.stream.counts
            if any_part:
                # match position within the item's side stream: only
                # needed when a parent paused mid-item (nested chains)
                within = within_src - side.stream.offs[st.item_of]
                w = _gather_at(within, st.offs, y, part)
            else:
                w = 0
            steps = np.where(d, std_adv, np.where(part, w, 0))
            ys = np.where(d, np.minimum(std_adv + 1, ns),
                          np.where(part, w + 1, 0))
            ds = d & (std_adv >= ns)
            _attr_steps(side, steps, counts, rank)
            side.account(counts, rank, ys, ds)


class _RtLF:
    """Leader-follower intersection of two Drive fibers: the leader
    enumerates, the follower is probed by coordinate (its non-matching
    elements are never touched)."""

    all_present = True

    def __init__(self, left, right, stream: _Stream,
                 sel: np.ndarray, idx_sel: np.ndarray,
                 lead_is_left: np.ndarray):
        self.left = left
        self.right = right
        self.stream = stream
        self.sel = sel
        self.idx_sel = idx_sel
        self.lead_is_left = lead_is_left         # per item

    def account(self, counts, rank, y, d):
        counts[("isect_match", rank)] += int(y.sum())
        st = self.stream
        part = (~d) & (y > 0)
        n_lead = np.where(self.lead_is_left, self.left.stream.counts,
                          self.right.stream.counts)
        if part.any():
            l_within = self.sel - self.left.stream.offs[st.item_of]
            r_within = self.idx_sel - self.right.stream.offs[st.item_of]
            lead_within = np.where(self.lead_is_left[st.item_of],
                                   l_within, r_within)
            w = _gather_at(lead_within, st.offs, y, part)
        else:
            w = 0
        pulls = np.where(d, n_lead, np.where(part, w + 1, 0))
        for is_left, lead, foll in ((True, self.left, self.right),
                                    (False, self.right, self.left)):
            m = self.lead_is_left == is_left
            p = np.where(m, pulls, 0)
            n = int(p.sum())
            if n:
                counts[("isect_step", rank, lead.node.tensor)] += n
                counts[("touch", foll.node.tensor, rank, "coord", "r")] += n
            lead.account(counts, rank, p, d & m)
        # the follower's own enumeration never runs: no leaf() touches


class _RtUnion:
    all_present = False

    def __init__(self, children, stream: _Stream, members):
        self.children = children
        self.stream = stream
        self.members = members                   # per child: bool [n]

    def account(self, counts, rank, y, d):
        st = self.stream
        some = y > 0
        for child, member in zip(self.children, self.members):
            nc = child.stream.counts
            # a suspended union has re-pulled the sources of its first
            # y-1 yields only (the y-th element's pull happens after
            # resume), plus the initial pull of every member stream
            c = _prefix_present(member, st.offs, np.maximum(y - 1, 0))
            pulls = np.where(d, nc,
                             np.where(some, np.minimum(c + 1, nc), 0))
            # a union cannot pull more from a source than it yielded
            check_conservation(int(nc.sum()), int(pulls.sum()),
                               f"union:{rank}")
            dc = d | (some & (c >= nc))
            child.account(counts, rank, pulls, dc)


def _attr_steps(child, k: np.ndarray, counts: Counter, rank: str) -> None:
    """Charge one ``isect_step`` per consumed child element to every
    tensor present in that element's payload (the interpreter's
    ``_isect_count``)."""
    total = int(k.sum())
    if total == 0:
        return
    st = child.stream
    if child.all_present:
        for t in st.pos:
            counts[("isect_step", rank, t)] += total
        return
    for t, p in st.pos.items():
        n = int(_prefix_present(p >= 0, st.offs, k).sum())
        if n:
            counts[("isect_step", rank, t)] += n


class VectorBackend(ExecutorBackend):
    name = "vector"

    def __init__(self, chunk_items: int = DEFAULT_CHUNK_ITEMS,
                 fallback: bool = True, kernel_backend=None,
                 profile: bool = False):
        self.chunk_items = chunk_items
        self.fallback = fallback
        self._oracle = PythonBackend()
        #: resolved kernel backend for the four seams: an instance, a
        #: registry name ('numpy' / 'jax-jit' / 'pallas-interpret' /
        #: 'pallas-tpu'), or None -> $REPRO_KERNEL_BACKEND / auto.
        #: Always wrapped in the guarded degradation chain: a failing
        #: backend downgrades per seam call (recorded as DowngradeEvents
        #: on last_downgrades) instead of poisoning the run.
        from repro.kernels.backends import resolve_guarded_kernels
        self.kernels = resolve_guarded_kernels(kernel_backend)
        #: 'vector' or 'fallback' for the most recent execute() call
        self.last_path: Optional[str] = None
        #: why the most recent execute() fell back (None on the fast path)
        self.last_fallback_reason: Optional[str] = None
        #: kernel-dispatch DowngradeEvents drained after the most recent
        #: execute() (guarded chain retries / downgrades / demotions)
        self.last_downgrades: List = []
        #: per-execution path of each request in the last execute_batch
        self.last_batch_paths: List[str] = []
        #: per-execution downgrade events for the last execute_batch
        self.last_batch_downgrades: List[List] = []
        #: per-execution stage_seconds for the last execute_batch
        #: (empty dicts unless profiling or tracing was active)
        self.last_batch_stage_seconds: List[Dict[str, float]] = []
        self._ws = _Workspace()
        #: when True, per-stage wall time accumulates in stage_times
        #: ('materialize' / 'pair-merge' / 'lookup' / 'finalize' /
        #: 'reduce' / 'output-build'), reset per execute()/execute_csf()
        self.profile = profile
        self.stage_times: Counter = Counter()

    # ------------------------------------------------------------------ #
    @property
    def stage_seconds(self) -> Dict[str, float]:
        """Per-stage wall seconds of the most recent execution -- the
        public accessor for the profile timers (``SimResult`` /
        ``Report`` surface the same dict as ``stage_seconds``)."""
        return {k: float(v) for k, v in self.stage_times.items()}

    @contextmanager
    def _einsum_telemetry(self, name: str):
        """``einsum:<name>`` span plus synthetic stage sub-spans
        around one execution; yields ``None`` (and does nothing) when
        no tracer is installed.

        While active it forces stage profiling on so the existing
        profile timers feed the trace, and tags the guarded kernel
        dispatch with the Einsum name so seam spans and
        ``DowngradeEvent``\\ s carry their attribution.  On exit the
        accumulated per-stage seconds become one ``stage:<stage>``
        span each, laid consecutively inside the einsum span's window
        (aggregates, not real intervals -- marked ``synthetic``) and
        added to the ``vector.stage_seconds/*`` counters.

        The Einsum tag on the kernel dispatch is set regardless of
        tracing (one attribute write): a ``DowngradeEvent`` recorded
        on an untraced run still names the Einsum it struck."""
        prev_einsum = getattr(self.kernels, "current_einsum", "")
        tag = hasattr(self.kernels, "current_einsum")
        if tag:
            self.kernels.current_einsum = name
        tr = active_tracer()
        if tr is None:
            try:
                yield None
            finally:
                if tag:
                    self.kernels.current_einsum = prev_einsum
            return
        prev_profile = self.profile
        self.profile = True
        snap = Counter(self.stage_times)
        sp = tr.span(f"einsum:{name}", cat="einsum",
                     args={"backend": self.name})
        try:
            with sp:
                yield sp
        finally:
            self.profile = prev_profile
            if tag:
                self.kernels.current_einsum = prev_einsum
            reg = _obs_metrics()
            cursor = sp._start_us
            for stage in STAGE_ORDER:
                secs = float(self.stage_times[stage]) - float(snap[stage])
                if secs <= 0.0:
                    continue
                reg.counter(f"vector.stage_seconds/{stage}").inc(secs)
                dur_us = secs * 1e6
                tr.add_span(f"stage:{stage}", "stage", cursor, dur_us,
                            {"einsum": name, "parent": f"einsum:{name}",
                             "synthetic": True})
                cursor += dur_us

    # ------------------------------------------------------------------ #
    def execute(self, plan, tensors, var_shapes, semiring=None, instr=None,
                out_initial=None, isect_strategy="two_finger",
                isect_leader=None) -> FTensor:
        instr = instr or NullInstr()
        semiring = semiring or Semiring.arithmetic()
        self.stage_times = Counter()
        with self._einsum_telemetry(plan.output) as sp:
            try:
                vp = lower(plan, var_shapes, semiring, out_initial,
                           isect_strategy, isect_leader)
                csf = {}
                for a in vp.accs:
                    v = tensors[a.tensor]
                    csf[a.tensor] = v if isinstance(v, CSF) else \
                        CSF.from_ftensor(v)
                init_csf = None
                if out_initial is not None:
                    init_csf = out_initial if isinstance(out_initial, CSF) \
                        else CSF.from_ftensor(out_initial)
                csf_out, _ = self._run(vp, plan, csf, instr,
                                       out_initial=init_csf)
                self.last_path = "vector"
                self.last_fallback_reason = None
                self.last_downgrades = self._drain_downgrades()
                if sp is not None:
                    sp.set("path", "vector")
                return csf_out.to_ftensor()
            except Exception as exc:
                if not (self.fallback and self._isolates(exc)):
                    self.last_downgrades = self._drain_downgrades()
                    raise
                # the vector pipeline is poisoned for this Einsum only
                # (inadmissible plan, exhausted kernel chain, violated
                # runtime invariant): fall back to the interpreter oracle.
                # _run emits instrumentation only on completion, so the
                # oracle's counts are the run's counts -- parity preserved.
                self.last_path = "fallback"
                self.last_fallback_reason = f"{type(exc).__name__}: {exc}" \
                    if not isinstance(exc,
                                      (_Unsupported, _CapacityExceeded)) \
                    else str(exc)
                self.last_downgrades = self._drain_downgrades()
                if sp is not None:
                    sp.set("path", "fallback")
                    sp.set("fallback", self.last_fallback_reason)
                ften = {t: (v.to_ftensor() if isinstance(v, CSF) else v)
                        for t, v in tensors.items()}
                return self._oracle.execute(
                    plan, ften, var_shapes, semiring=semiring, instr=instr,
                    out_initial=out_initial, isect_strategy=isect_strategy,
                    isect_leader=isect_leader)

    @staticmethod
    def _isolates(exc: BaseException) -> bool:
        """Faults the oracle fallback absorbs: plan inadmissibility (the
        historical pair), an exhausted kernel degradation chain, and
        strict-mode guard violations.  Anything else (a genuine bug, a
        bad input the oracle would also choke on) propagates."""
        if isinstance(exc, (_Unsupported, _CapacityExceeded)):
            return True
        from repro.core.guards import GuardViolation
        from repro.kernels.backends import KernelChainExhausted
        return isinstance(exc, (KernelChainExhausted, GuardViolation))

    def _drain_downgrades(self) -> List:
        pop = getattr(self.kernels, "pop_events", None)
        return pop() if pop is not None else []

    def execute_batch(self, requests) -> List[FTensor]:
        """Batched frontier execution across independent Einsums: the
        requests share this backend's resolved kernel dispatch and the
        persistent workspace, so scratch allocations amortize across
        the whole batch instead of cycling per Einsum.  Per-request
        outputs, counts, and fallback behavior are identical to the
        sequential loop (the grouping seam in ``generator.run`` only
        batches Einsums with no data dependencies between them)."""
        outs: List[FTensor] = []
        paths: List[str] = []
        reasons: List[Optional[str]] = []
        downgrades: List[List] = []
        stages: List[Dict[str, float]] = []
        for req in requests:
            try:
                outs.append(self.execute(**req))
                paths.append(self.last_path or "vector")
                reasons.append(self.last_fallback_reason)
            except Exception as exc:
                # per-Einsum isolation: a fault that escaped execute()'s
                # own fallback (or struck its oracle re-run) poisons
                # this Einsum only -- the rest of the batch proceeds on
                # the unaffected backend.  Never silent: the reason
                # lands on the batch record exactly like a planned
                # fallback, and the oracle replays instrumentation so
                # count parity holds for the isolated Einsum too.
                if not self.fallback:
                    self.last_batch_paths = paths
                    self.last_batch_fallbacks = reasons
                    self.last_batch_downgrades = downgrades
                    self.last_batch_stage_seconds = stages
                    raise
                outs.append(self._isolate_request(req, exc))
                paths.append("fallback")
                reasons.append(self.last_fallback_reason)
            downgrades.append(list(self.last_downgrades))
            # execute() resets stage_times on entry, so this snapshot
            # is this request's times alone (empty on fallback paths
            # that never reached the pipeline)
            stages.append(self.stage_seconds)
        self.last_batch_paths = paths
        self.last_batch_fallbacks = reasons
        self.last_batch_downgrades = downgrades
        self.last_batch_stage_seconds = stages
        return outs

    def _isolate_request(self, req, exc: BaseException) -> FTensor:
        """Oracle re-run of one poisoned batch request."""
        self.last_path = "fallback"
        self.last_fallback_reason = \
            f"einsum-isolated {type(exc).__name__}: {exc}"
        kw = dict(req)
        tensors = {t: (v.to_ftensor() if isinstance(v, CSF) else v)
                   for t, v in kw.pop("tensors").items()}
        plan = kw.pop("plan")
        var_shapes = kw.pop("var_shapes")
        return self._oracle.execute(plan, tensors, var_shapes, **kw)

    def execute_csf(self, plan, tensors, semiring=None, instr=None,
                    isect_strategy="two_finger",
                    var_shapes: Optional[Dict[str, int]] = None,
                    isect_leader=None) -> Tuple[CSF, Dict]:
        """Vector path only (no fallback): raw CSFs in, CSF out, never
        materializing per-element Python objects.  Runs the Section-3.2
        transform pre-pass (``vplan.prepare_csf_inputs``) so
        partitioned / flattened mappings work straight from storage
        form.  This is the large-scale entry point used by the
        throughput benchmark."""
        instr = instr or NullInstr()
        semiring = semiring or Semiring.arithmetic()
        self.stage_times = Counter()
        with self._einsum_telemetry(plan.output):
            shapes = dict(var_shapes or {})
            for c in tensors.values():
                for r, s in getattr(c, "rank_shapes", {}).items():
                    if isinstance(s, int):
                        v = r.lower()
                        shapes[v] = max(shapes.get(v, 0), s)
            vp = lower(plan, shapes, semiring, None, isect_strategy,
                       isect_leader)
            exec_csf = prepare_csf_inputs(plan, tensors)
            return self._run(vp, plan, exec_csf, instr)

    # ------------------------------------------------------------------ #
    # the vector loop nest
    # ------------------------------------------------------------------ #
    def _run(self, vp: VectorPlan, plan: EinsumPlan,
             csf: Dict[str, CSF], instr: Instrumentation,
             out_initial: Optional[CSF] = None) -> Tuple[CSF, Dict]:
        counts: Counter = Counter()
        name = vp.name
        red = vp.reduce

        # update-in-place: the existing output's leaf points seed the
        # reduction groups (they sort ahead of same-coordinate
        # contributions, so the sequential fold starts from them exactly
        # like the interpreter's lookup-then-add)
        init: Optional[Tuple[np.ndarray, np.ndarray]] = None
        if out_initial is not None and out_initial.nnz:
            ipaths = out_initial.point_matrix().astype(np.int64)
            if ipaths.shape[1] != sum(red.widths):
                raise _Unsupported(
                    "update-in-place output coordinate width mismatch")
            init = (ipaths, out_initial.values.astype(np.float64))

        frontier = _Frontier(1, {a.tensor: np.full(1, -2, dtype=np.int64)
                                 for a in vp.accs}, [], {})
        # constant-index descents that resolve before the first level
        if vp.pre_lookups:
            dead = np.zeros(frontier.n, dtype=bool)
            for lk in vp.pre_lookups:
                dead |= self._lookup(lk, csf, frontier, counts)
            if dead.any():
                frontier = frontier.filter(~dead)

        # level 0 first, then (optionally chunked) deeper levels; a
        # seeded reduction needs all contributions in one part, so
        # update-in-place disables chunking
        frontier = self._level(0, vp, csf, frontier, counts)
        chunked = (vp.levels[0].out_depth is not None
                   and frontier.n > self.chunk_items and len(vp.levels) > 1
                   and init is None)
        fuse = vp.leaf_fuse
        nz_cache: Dict = {}
        paths_parts: List[List[np.ndarray]] = []
        vals_parts: List[np.ndarray] = []
        n_levels = len(vp.levels)
        step = self.chunk_items if chunked else max(frontier.n, 1)
        for i0 in range(0, max(frontier.n, 1), step):
            part = frontier.slice(i0, min(i0 + step, frontier.n))
            inner = n_levels - 1 if fuse is not None else n_levels
            for li in range(1, inner):
                part = self._level(li, vp, csf, part, counts)
            tf = time.perf_counter() if self.profile else 0.0
            # other stage counters can also advance inside this window
            # (reduce always; a declined fuse re-enters _level, charging
            # materialize/pair-merge/lookup) -- net their deltas out so
            # the per-stage breakdown stays non-overlapping
            inner_keys = ("reduce", "materialize", "pair-merge", "lookup")
            s0 = sum(float(self.stage_times[k]) for k in inner_keys) \
                if self.profile else 0.0
            pv = None
            if fuse is not None:
                # batched innermost level: one wide expand-multiply-
                # accumulate pass over the whole chunk frontier; None
                # means the dense group domain was inadmissible here
                pv = self._finalize_fused(part, vp, csf, counts, nz_cache)
            if pv is None:
                if fuse is not None:
                    part = self._level(n_levels - 1, vp, csf, part, counts)
                pv = self._finalize(part, vp, csf, counts, init)
            if self.profile:
                s1 = sum(float(self.stage_times[k]) for k in inner_keys)
                self.stage_times["finalize"] += \
                    (time.perf_counter() - tf) - (s1 - s0)
            p, v = pv
            if len(v):
                paths_parts.append(p)
                vals_parts.append(v)

        tb = time.perf_counter() if self.profile else 0.0
        if vals_parts:
            cols = [np.concatenate([p[d] for p in paths_parts], axis=0)
                    for d in range(len(red.out_ranks))]
            vals = np.concatenate(vals_parts)
        else:
            cols = [np.zeros((0, w), dtype=np.int64) for w in red.widths]
            vals = np.zeros(0, dtype=np.float64)
        # arithmetic semirings promise finite leaf values (min-plus
        # legitimately folds infinities, so the scan gates on add)
        if vp.semiring.add_vec is np.add:
            check_finite(vals, f"vector-out:{name}")
        # every reduced group is a distinct output point, so the CSF
        # build can skip the leaf boundary scan (leaf_unique)
        out_csf = _from_sorted_points(
            name, red.out_ranks, cols, vals,
            {r: None for r in red.out_ranks}, 0, set(red.upper_ranks),
            leaf_unique=True)
        if self.profile:
            self.stage_times["output-build"] += time.perf_counter() - tb

        self._emit(instr, name, counts)
        stats = {"leaf_points": int(counts.get(("leaf",), 0)),
                 "muls": int(counts.get(("compute", "mul"), 0)),
                 "out_nnz": int(len(vals))}
        return out_csf, stats

    # ------------------------------------------------------------------ #
    # stream materialization (the kernel dispatch table lives here:
    # Drive -> segment expansion; Intersect -> kernels.ops.intersect_keys
    # (or the probe path for leader-follower); UnionK ->
    # kernels.ops.union_k_keys; Lookup -> kernels.ops.lookup_keys)
    # ------------------------------------------------------------------ #
    def _ranges(self, c: CSF, d: int, pos: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
        n = len(pos)
        if d == 0:
            n0 = len(c.coords[0])
            return (np.zeros(n, dtype=np.int64),
                    np.full(n, n0, dtype=np.int64))
        seg = c.segments[d]
        valid = pos >= 0
        # clamp also covers the all-absent / empty-tensor case, where
        # seg has a single entry and no position is valid
        safe = np.clip(pos, 0, max(len(seg) - 2, 0))
        lo = np.where(valid, seg[safe], 0)
        hi = np.where(valid, seg[np.minimum(safe + 1, len(seg) - 1)], 0)
        return lo, hi

    def _drive_raw(self, node: Drive, csf, fr, width: int):
        c = csf[node.tensor]
        lo, hi = self._ranges(c, node.depth, fr.pos[node.tensor])
        item_of, elem, cnts, offs = _expand(lo, hi)
        coord = c.coords[node.depth][elem]
        if coord.shape[1] != width:
            assert len(coord) == 0, \
                f"{node.tensor}: coordinate width {coord.shape[1]} != " \
                f"plan width {width}"
            coord = coord.reshape(0, width)
        return item_of, elem, cnts, offs, coord

    @staticmethod
    def _collect_drives(op, out: List[Drive]) -> None:
        if isinstance(op, Drive):
            out.append(op)
        else:
            for ch in getattr(op, "children", ()):
                VectorBackend._collect_drives(ch, out)

    def _materialize_level(self, lvl: LevelIR, csf, fr: _Frontier):
        """Build all Drive streams with a shared coordinate packing,
        then compose the op tree."""
        drives: List[Drive] = []
        self._collect_drives(lvl.op, drives)
        raw = {id(n): self._drive_raw(n, csf, fr, lvl.width)
               for n in drives}
        packing: List = []

        def ensure_keys(st: _Stream) -> np.ndarray:
            # lazy: only co-iterating nodes pack sort keys; a level with
            # a single driver never pays the domain scan at all
            if st.keys is None:
                if not packing:
                    packing.append(_pack_factors(
                        lvl.width, [r[4] for r in raw.values()], fr.n))
                _, factors, item_mult = packing[0]
                # item_of may be int32 (hot expansion): upcast before
                # the mult, NumPy 2 no longer value-promotes
                keys = st.item_of.astype(np.int64) * item_mult
                for j in range(st.coord.shape[1]):
                    keys = keys + st.coord[:, j].astype(np.int64) \
                        * factors[j]
                st.keys = keys
            return st.keys

        def item_mult_of() -> int:
            assert packing, "union children must have packed keys"
            return packing[0][2]

        def build(op):
            if isinstance(op, Drive):
                item_of, elem, cnts, offs, coord = raw[id(op)]
                return _RtDrive(op, _Stream(None, item_of, cnts, offs,
                                            coord, {op.tensor: elem}))
            if isinstance(op, Intersect):
                rt = build(op.children[0])
                for ch in op.children[1:]:
                    rt = self._pair(rt, build(ch), op, fr.n, ensure_keys)
                return rt
            assert isinstance(op, UnionK)
            return self._union([build(ch) for ch in op.children], fr.n,
                               item_mult_of, ensure_keys)
        return build(lvl.op)

    def _pair(self, left, right, op: Intersect, n_items: int, ensure_keys):
        kops = self.kernels
        ls, rs = left.stream, right.stream
        lkeys, rkeys = ensure_keys(ls), ensure_keys(rs)
        lf = (op.strategy == "leader_follower"
              and isinstance(left, _RtDrive) and isinstance(right, _RtDrive))
        if lf:
            if left.node.tensor == op.leader:
                lead_is_left = np.ones(n_items, dtype=bool)
            elif right.node.tensor == op.leader:
                lead_is_left = np.zeros(n_items, dtype=bool)
            else:
                # no explicit leader among the pair: lead with the
                # smaller fiber (the dynamic choice real units make)
                lead_is_left = ls.counts <= rs.counts
        tk = time.perf_counter() if self.profile else 0.0
        idx = kops.intersect_keys(lkeys, rkeys)
        if self.profile:
            self.stage_times["pair-merge"] += time.perf_counter() - tk
        hit = idx >= 0
        sel = np.flatnonzero(hit)
        item_of = ls.item_of[sel]
        cnts = np.bincount(item_of, minlength=n_items).astype(np.int64)
        offs = np.zeros(n_items + 1, dtype=np.int64)
        np.cumsum(cnts, out=offs[1:])
        pos = {t: p[sel] for t, p in ls.pos.items()}
        idx_sel = idx[sel]
        for t, p in rs.pos.items():
            pos[t] = p[idx_sel]
        st = _Stream(lkeys[sel], item_of, cnts, offs, ls.coord[sel], pos)
        if lf:
            return _RtLF(left, right, st, sel, idx_sel, lead_is_left)
        both = (ls.counts > 0) & (rs.counts > 0)
        lmax = lkeys[np.maximum(ls.offs[1:] - 1, 0)] if ls.n else \
            np.zeros(n_items, dtype=np.int64)
        rmax = rkeys[np.maximum(rs.offs[1:] - 1, 0)] if rs.n else \
            np.zeros(n_items, dtype=np.int64)
        adv_l = np.where(both, np.searchsorted(lkeys, rmax, side="right")
                         - ls.offs[:-1], 0)
        adv_r = np.where(both, np.searchsorted(rkeys, lmax, side="right")
                         - rs.offs[:-1], 0)
        return _RtPair(left, right, st, sel, idx_sel, adv_l, adv_r)

    def _union(self, children, n_items: int, item_mult_of, ensure_keys):
        kops = self.kernels
        streams = [c.stream for c in children]
        tk = time.perf_counter() if self.profile else 0.0
        u, pos_list = kops.union_k_keys([ensure_keys(s) for s in streams])
        if self.profile:
            self.stage_times["pair-merge"] += time.perf_counter() - tk
        item_of = u // max(item_mult_of(), 1)
        cnts = np.bincount(item_of, minlength=n_items).astype(np.int64)
        offs = np.zeros(n_items + 1, dtype=np.int64)
        np.cumsum(cnts, out=offs[1:])
        width = streams[0].coord.shape[1]
        coord = np.zeros((len(u), width), dtype=streams[0].coord.dtype)
        pos: Dict[str, np.ndarray] = {}
        members = []
        for s, cpos in zip(streams, pos_list):
            m = cpos >= 0
            members.append(m)
            if m.any():
                coord[m] = s.coord[cpos[m]]
            for t, p in s.pos.items():
                col = np.full(len(u), -1, dtype=np.int64)
                if m.any():
                    col[m] = p[cpos[m]]
                pos[t] = col
        st = _Stream(u, item_of, cnts, offs, coord, pos)
        return _RtUnion(children, st, members)

    # ------------------------------------------------------------------ #
    def _level(self, li: int, vp: VectorPlan, csf, fr: _Frontier,
               counts: Counter) -> _Frontier:
        tm = time.perf_counter() if self.profile else 0.0
        s0 = (float(self.stage_times["pair-merge"])
              + float(self.stage_times["lookup"])) if self.profile else 0.0
        lvl = vp.levels[li]
        rank = lvl.rank
        out_here = lvl.out_depth is not None

        if isinstance(lvl.op, DenseEnumerate):
            shape = lvl.op.shape
            n = fr.n * shape
            idt = np.int32 if n < _I32_N else np.int64
            item_of = np.repeat(np.arange(fr.n, dtype=idt), shape)
            coord = np.tile(np.arange(shape, dtype=idt), fr.n)[:, None]
            counts[("iterate", rank)] += n
            counts[("advance", rank)] += n
            nf = fr.take(item_of, coord if out_here else None)
        else:
            rt = self._materialize_level(lvl, csf, fr)
            st = rt.stream
            n = st.n
            counts[("iterate", rank)] += n
            counts[("advance", rank)] += n
            rt.account(counts, rank, st.counts.copy(),
                       np.ones(fr.n, dtype=bool))
            # matched elements descend: deepest levels touch payloads
            drives: List[Drive] = []
            self._collect_drives(lvl.op, drives)
            for node in drives:
                if node.leaf:
                    present = int((st.pos[node.tensor] >= 0).sum())
                    if present:
                        counts[("touch", node.tensor, rank,
                                "payload", "r")] += present
            coord = st.coord
            nf = fr.take(st.item_of, coord if out_here else None,
                         skip_pos=st.pos.keys())
            for t, p in st.pos.items():
                nf.pos[t] = p

        if lvl.binds:
            for v, (lv, col) in vp.capture_vars.items():
                if lv == li:
                    nf.var_cols[v] = coord[:, col].copy() if len(coord) \
                        else np.zeros(0, dtype=np.int64)

        if lvl.lookups:
            dead = np.zeros(nf.n, dtype=bool)
            for lk in lvl.lookups:
                dead |= self._lookup(lk, csf, nf, counts)
            if dead.any():
                nf = nf.filter(~dead)
        # stream conservation: a level cannot drain more frontier items
        # than its streams yielded (filters only ever shrink)
        check_conservation(n, nf.n, f"level:{vp.name}:{rank}")
        if self.profile:
            s1 = float(self.stage_times["pair-merge"]) \
                + float(self.stage_times["lookup"])
            self.stage_times["materialize"] += \
                (time.perf_counter() - tm) - (s1 - s0)
        return nf

    # ------------------------------------------------------------------ #
    def _lookup(self, lk: Lookup, csf, fr: _Frontier,
                counts: Counter) -> np.ndarray:
        """Catch-up descent of one tensor level by bound coordinate.
        Returns the per-item dead mask (essential misses)."""
        kops = self.kernels
        c = csf[lk.tensor]
        d = lk.depth
        n = fr.n
        if d == 0:
            parent = np.zeros(n, dtype=np.int64)
            pvalid = np.ones(n, dtype=bool)
        else:
            parent = fr.pos[lk.tensor]
            pvalid = parent >= 0
        level_coord = c.coords[d].astype(np.int64)
        neg: Optional[np.ndarray] = None
        if lk.index is not None:
            # affine / constant probe: const + sum(coeff * var column)
            # (im2col windowing for conv's I[b, c, p+r, q+s]).  Negative
            # coordinates are definite misses and must be masked before
            # key packing -- folded into an offset key they would alias
            # into the preceding fiber's range (kernels.ops has the same
            # guard in lookup_keys_shifted / intersect_keys_shifted).
            w = 1
            pb = np.full(n, int(lk.index.const), dtype=np.int64)
            for v, cf in lk.index.terms:
                pb = pb + int(cf) * fr.var_cols[v]
            neg = pb < 0
            probe = np.where(neg, 0, pb)[:, None] if n \
                else np.zeros((0, 1), dtype=np.int64)
        else:
            w = len(lk.vars)
            probe = np.stack([fr.var_cols[v] for v in lk.vars], axis=1) \
                if n else np.zeros((0, w), dtype=np.int64)
        if level_coord.shape[1] != w:
            assert len(level_coord) == 0
            level_coord = level_coord.reshape(0, w)
        par_of = c.expand_level(d)
        # probe coordinates can exceed the stored domain: the packing
        # must cover both, or a too-large probe would alias into the
        # next parent's key range
        _, factors, seg_mult = _pack_factors(
            w, [level_coord, probe], max(int(par_of.max(initial=0)) + 1, 1))
        hay = par_of * seg_mult + level_coord @ factors
        probe_keys = np.where(pvalid, parent, 0) * seg_mult \
            + (probe @ factors)

        if lk.partition_start:
            # position by range: largest coordinate <= target within the
            # parent fiber (missing -> absent, without a coordinate read)
            ins = np.searchsorted(hay, probe_keys, side="right") - 1
            safe = np.maximum(ins, 0)
            found = pvalid & (ins >= 0)
            if len(hay):
                found &= (hay[safe] // max(seg_mult, 1)) == \
                    np.where(pvalid, parent, 0)
            else:
                found[:] = False
            pos = np.where(found, safe, -1)
            n_touch = int(found.sum())
        else:
            tk = time.perf_counter() if self.profile else 0.0
            idx = kops.lookup_keys(hay, probe_keys)
            if self.profile:
                self.stage_times["lookup"] += time.perf_counter() - tk
            pos = np.where(pvalid, idx, -1)
            if neg is not None:
                # the clamped stand-in probe may have matched; a negative
                # coordinate is always a miss (still touched: the
                # interpreter reads the coordinate before missing)
                pos = np.where(neg, -1, pos)
            found = pos >= 0
            n_touch = int(pvalid.sum())
        if n_touch:
            counts[("touch", lk.tensor, lk.rank, "coord", "r")] += n_touch
        n_hit = int(found.sum())
        if lk.leaf and n_hit:
            counts[("touch", lk.tensor, lk.rank, "payload", "r")] += n_hit
        fr.pos[lk.tensor] = pos
        if lk.essential:
            return ~found
        return np.zeros(n, dtype=bool)

    # ------------------------------------------------------------------ #
    def _finalize(self, fr: _Frontier, vp: VectorPlan, csf,
                  counts: Counter,
                  init: Optional[Tuple[np.ndarray, np.ndarray]] = None
                  ) -> Tuple[List[np.ndarray], np.ndarray]:
        """Leaf evaluation + segmented in-order reduction (Reduce),
        both parameterized by the plan's semiring; ``init`` carries the
        update-in-place output's existing (paths, values)."""
        kops = self.kernels
        name = vp.name
        red = vp.reduce
        sr = vp.semiring
        counts[("leaf",)] += fr.n
        leafvals: Dict[str, np.ndarray] = {}
        for a in vp.accs:
            t = a.tensor
            c = csf[t]
            pos = fr.pos[t]
            present = pos >= 0
            if len(c.values) and c.values.dtype == np.float64 \
                    and present.all():
                # intersection-driven leaves: every point present, one
                # straight gather instead of zeros + masked scatter
                v = c.values[pos]
            else:
                v = np.zeros(fr.n, dtype=np.float64)
                if len(c.values):
                    v[present] = c.values[pos[present]]
            leafvals[t] = v

        def ev(e) -> np.ndarray:
            if isinstance(e, TensorAccess):
                return leafvals[e.tensor]
            if isinstance(e, Take):
                vals = [ev(a) for a in e.args]
                mask = np.ones(fr.n, dtype=bool)
                for v in vals:
                    mask &= v != 0
                return np.where(mask, vals[e.which], 0.0)
            assert isinstance(e, BinOp)
            lv, rv = ev(e.lhs), ev(e.rhs)
            if e.op == "*":
                # annihilator (empty payload) short-circuits without a
                # counted op, exactly like the interpreter's _eval
                mask = (lv != 0) & (rv != 0)
                counts[("compute", "mul")] += int(np.count_nonzero(mask))
                if sr.mul_vec is np.multiply:
                    # float product is exactly 0 whenever an operand is
                    # (up to sign, and the nz filter drops -0.0 too)
                    return lv * rv
                return np.where(mask, sr.mul_vec(lv, rv), 0.0)
            if e.op == "+":
                both = (lv != 0) & (rv != 0)
                counts[("compute", "add")] += int(both.sum())
                return np.where(lv == 0, rv,
                                np.where(rv == 0, lv, sr.add_vec(lv, rv)))
            counts[("compute", "add")] += lv.size
            return sr.sub_vec(lv, rv)

        vals = ev(vp.expr)
        # output coordinates as flat width-1 columns in exec-rank
        # order: the fused sort key is built straight from them, so the
        # full [n, ncol] path matrix is never materialized and only the
        # group-head rows are gathered after the sort -- on a 10k x 10k
        # SpMSpM chunk that drops three full-width matrix copies from
        # the hot loop
        flat: List[np.ndarray] = []
        lvl_cols = iter(fr.out_cols)
        for src, wdt in zip(red.sources, red.widths):
            if src[0] == "level":
                c = next(lvl_cols)
                flat.extend(c[:, j] for j in range(c.shape[1]))
            else:
                # native dtype (often int32 from CSF coords) flows
                # through to the output build's fast path
                flat.extend(np.asarray(fr.var_cols[v]) for v in src[1])
        widths = red.widths
        nzmask = vals != 0
        if nzmask.all():
            cols = list(flat)
        else:
            nz = np.flatnonzero(nzmask)
            vals = vals[nz]
            cols = [c[nz] for c in flat]

        # prepend the update-in-place seed points: placed first, the
        # stable sort keeps each seed at its group's head, so the
        # in-order fold starts from the existing value
        n_init = 0
        if init is not None:
            ipaths, ivals = init
            n_init = len(ivals)
            cols = [np.concatenate([ipaths[:, j], c])
                    for j, c in enumerate(cols)]
            vals = np.concatenate([ivals, vals])

        def assemble(rows: List[np.ndarray]) -> List[np.ndarray]:
            n_rows = len(rows[0]) if rows else 0
            out, j = [], 0
            for w in widths:
                if w == 1:               # reshape view, no copy
                    out.append(rows[j].reshape(-1, 1))
                elif w:
                    out.append(np.stack(rows[j:j + w], axis=1))
                else:
                    out.append(np.zeros((n_rows, 0), dtype=np.int64))
                j += w
            return out

        if len(vals) == 0:
            return [np.zeros((0, w), dtype=np.int64) for w in widths], vals
        # one fused-key stable sort beats a column-wise lexsort; fall
        # back to lexsort when the packed coordinate domain overflows
        mults = [int(c.max()) + 1 for c in cols]
        total_mult = 1.0
        for m in mults:
            total_mult *= m
        boundary = np.ones(len(vals), dtype=bool)
        if total_mult < float(1 << 62):
            # int32 keys when the packed domain fits: numpy's stable
            # argsort is measurably faster and every key gather moves
            # half the bytes
            kdt = np.int32 if total_mult < float(1 << 31) else np.int64
            key = np.zeros(len(vals), dtype=kdt)
            for c, m in zip(cols, mults):
                key *= m
                key += c
            order = np.argsort(key, kind="stable")
            key = key[order]
            if len(vals) > 1:
                boundary[1:] = key[1:] != key[:-1]
        else:
            order = np.lexsort(tuple(cols[::-1]))
            if len(vals) > 1:
                boundary[1:] = False
                for c in cols:
                    cs = c[order]
                    boundary[1:] |= cs[1:] != cs[:-1]
        vals = vals[order]
        starts = np.flatnonzero(boundary)
        gids = np.cumsum(boundary, dtype=np.int64)
        np.subtract(gids, 1, out=gids)
        # accumulate strictly in iteration order (matches the
        # interpreter's sequential semiring.add, bit for bit; arith
        # rides one bincount pass, min-plus ufunc.reduceat, see
        # kernels.ops.segmented_reduce)
        tr = time.perf_counter() if self.profile else 0.0
        sums = kops.segmented_reduce(vals, starts, sr, group_ids=gids)
        if self.profile:
            self.stage_times["reduce"] += time.perf_counter() - tr
        head = order[starts]             # pre-sort row of each group head
        out_rank = red.out_ranks[-1]
        # accounting: the first contribution of a group inserts (w);
        # every later one reads the accumulator, adds, and writes back.
        # A group headed by an update-in-place seed point already has an
        # accumulator, so all its contributions read+add+write; a group
        # holding only its seed costs nothing (untouched existing value).
        n_contrib = len(vals) - n_init
        n_plain = int((head >= n_init).sum()) if n_init else len(starts)
        counts[("touch", name, out_rank, "payload", "w")] += n_contrib
        counts[("touch", name, out_rank, "payload", "r")] += \
            n_contrib - n_plain
        counts[("compute", "add")] += n_contrib - n_plain
        return assemble([c[head] for c in cols]), sums

    # ------------------------------------------------------------------ #
    def _finalize_fused(self, fr: _Frontier, vp: VectorPlan, csf,
                        counts: Counter, cache: Dict
                        ) -> Optional[Tuple[List[np.ndarray], np.ndarray]]:
        """Batched innermost level: expand every frontier item's leaf
        fiber of the driven factor, multiply by the co-factor's leaf
        value, and reduce into a dense per-group accumulator in one
        ``bincount`` pass -- replacing stream build + sort + segmented
        fold for the dominant two-factor contraction shape
        (``vplan.LeafFuse``).  Bit-exact with the generic path: groups
        come out in the same lexicographic order, and the weighted
        bincount accumulates contributions in input order, which is
        exactly the order the stable sort presents them to the
        sequential fold.  Returns None when the dense group domain is
        inadmissible here (caller runs the generic innermost level)."""
        red = vp.reduce
        fz = vp.leaf_fuse
        last = len(vp.levels) - 1
        rank = vp.levels[last].rank
        c = csf[fz.driven]
        oc = csf[fz.other]
        dd = vp.leaf_depth[fz.driven]
        if fr.n == 0:
            return ([np.zeros((0, w), dtype=np.int64) for w in red.widths],
                    np.zeros(0, dtype=np.float64))
        opos = fr.pos.get(fz.other)
        dpos = fr.pos.get(fz.driven)
        if (opos is None or (opos < 0).any()
                or (dd > 0 and (dpos is None or (dpos < 0).any()))
                or oc.values.dtype != np.float64
                or c.values.dtype != np.float64):
            return None
        lo, hi = self._ranges(c, dd, dpos if dpos is not None
                              else np.full(fr.n, -2, dtype=np.int64))
        total = int((hi - lo).sum())
        lc = c.coords[dd]
        if total == 0 or len(lc) == 0:
            return ([np.zeros((0, w), dtype=np.int64) for w in red.widths],
                    np.zeros(0, dtype=np.float64))

        # flat output columns in exec-rank order, tagged by where the
        # value lives: 'p' sorted-prefix item column, 'i' other per-item
        # column, 'e' leaf coordinate column (index into lc)
        flat: List[Tuple[str, object]] = []
        n_prefix_cols = 0
        lvl_cols = iter(fr.out_cols)
        for si, (src, wdt) in enumerate(zip(red.sources, red.widths)):
            if src[0] == "level":
                if src[1] == last:
                    flat.extend(("e", j) for j in range(wdt))
                else:
                    cc = next(lvl_cols)
                    kind = "p" if si < red.prefix_sources else "i"
                    flat.extend((kind, cc[:, j])
                                for j in range(cc.shape[1]))
                    if kind == "p":
                        n_prefix_cols += cc.shape[1]
            else:
                for v in src[1]:
                    lv, colj = vp.capture_vars[v]
                    if lv == last:
                        flat.append(("e", colj))
                    else:
                        flat.append(("i", np.asarray(fr.var_cols[v])))

        mults = []
        for kind, x in flat:
            if kind == "e":
                mults.append(int(lc[:, x].max()) + 1)
            else:
                mults.append(int(x.max()) + 1)

        # the frontier is lexicographically sorted by level coords, so
        # the leading prefix columns group with one boundary scan
        if n_prefix_cols:
            b = np.zeros(fr.n, dtype=bool)
            b[0] = True
            for _, x in flat[:n_prefix_cols]:
                b[1:] |= x[1:] != x[:-1]
            head_items = np.flatnonzero(b)
            gid = np.cumsum(b, dtype=np.int64) - 1
            n_local = len(head_items)
        else:
            head_items = np.zeros(1, dtype=np.int64)
            gid = np.zeros(fr.n, dtype=np.int64)
            n_local = 1

        rest = flat[n_prefix_cols:]
        rest_factors = [0] * len(rest)
        rm = 1
        for j in range(len(rest) - 1, -1, -1):
            rest_factors[j] = rm
            rm *= mults[n_prefix_cols + j]
        size = n_local * rm
        # three admissibility gates: bounded footprint, bounded
        # oversubscription (slots vs contributions), and a cache-sized
        # per-prefix-group span -- the scatter sweeps forward through
        # prefix groups, so rm bounds its working set; without the
        # bound (e.g. the flattened mapping, whose frontier is ordered
        # by position, not output coordinate) the dense accumulate
        # loses to the generic sort
        if size > DENSE_GROUP_CAP or size > max(8 * total, 1 << 16) \
                or rm > (1 << 20):
            return None

        # ---- commit point: counts may be mutated from here on ----
        counts[("iterate", rank)] += total
        counts[("advance", rank)] += total
        counts[("touch", fz.driven, rank, "coord", "r")] += total
        counts[("touch", fz.driven, rank, "payload", "r")] += total
        counts[("leaf",)] += total

        # per-item slot base and per-leaf-element slot offset (both fit
        # int32: size <= DENSE_GROUP_CAP)
        ik = gid * rm
        for (kind, x), f in zip(rest, rest_factors):
            if kind != "e":
                ik = ik + x.astype(np.int64) * f
        item_key = ik.astype(np.int32)
        ecols = [(x, f) for (kind, x), f in zip(rest, rest_factors)
                 if kind == "e"]
        ekey = ("ep", id(c)) + tuple(ecols)
        epart = cache.get(ekey)
        if epart is None and ecols:
            ep = np.zeros(len(lc), dtype=np.int64)
            for x, f in ecols:
                ep += lc[:, x].astype(np.int64) * f
            epart = ep.astype(np.int32)
            cache[ekey] = epart

        ws = self._ws
        item_of, elem, _, _ = _expand(lo, hi)
        key = ws.buf("fk1", total, np.int32)
        np.take(item_key, item_of, out=key)
        if epart is not None:
            ek = ws.buf("fk2", total, np.int32)
            np.take(epart, elem, out=ek)
            key += ek
        v_o = oc.values[opos]
        vals = ws.buf("fv1", total, np.float64)
        np.take(v_o, item_of, out=vals)
        v2 = ws.buf("fv2", total, np.float64)
        np.take(c.values, elem, out=v2)
        np.multiply(vals, v2, out=vals)

        # multiplies counted on operand nonzeros (the annihilator
        # short-circuit), exactly like the generic leaf eval
        nzd = cache.get(("nz", id(c)))
        if nzd is None:
            nzd = c.values != 0
            cache[("nz", id(c))] = nzd
        m1 = ws.buf("fm1", total, np.bool_)
        np.take(v_o != 0, item_of, out=m1)
        m2 = ws.buf("fm2", total, np.bool_)
        np.take(nzd, elem, out=m2)
        m1 &= m2
        counts[("compute", "mul")] += int(np.count_nonzero(m1))

        # dense accumulate: weighted bincount == sequential in-order
        # fold, bit for bit (stable sort preserves input order within a
        # group, and a 0.0-seeded sum of its nonzero contributions
        # reproduces the fold exactly); group existence comes from the
        # nonzero-contribution count, matching the generic nz filter
        nzv = ws.buf("fm3", total, np.bool_)
        np.not_equal(vals, 0.0, out=nzv)
        all_nz = bool(nzv.all())
        tr = time.perf_counter() if self.profile else 0.0
        sums = np.bincount(key, weights=vals, minlength=size)
        exists = np.zeros(size, dtype=bool)
        exists[key if all_nz else key[nzv]] = True
        if self.profile:
            self.stage_times["reduce"] += time.perf_counter() - tr
        idx = np.flatnonzero(exists)
        n_groups = len(idx)
        n_contrib = total if all_nz else int(np.count_nonzero(nzv))
        out_rank = red.out_ranks[-1]
        counts[("touch", vp.name, out_rank, "payload", "w")] += n_contrib
        counts[("touch", vp.name, out_rank, "payload", "r")] += \
            n_contrib - n_groups
        counts[("compute", "add")] += n_contrib - n_groups
        if n_groups == 0:
            return ([np.zeros((0, w), dtype=np.int64) for w in red.widths],
                    np.zeros(0, dtype=np.float64))
        gvals = sums[idx]

        # decode slot -> output columns (ascending slot order is the
        # generic path's lexicographic group order)
        g_head = idx // rm
        rem = idx - g_head * rm
        out_flat: List[np.ndarray] = []
        ri = 0
        for j, (kind, x) in enumerate(flat):
            if j < n_prefix_cols:
                heads = np.asarray(x)[head_items]
                out_flat.append(heads[g_head])
            else:
                f = rest_factors[ri]
                ri += 1
                q = rem // f
                rem = rem - q * f
                out_flat.append(q.astype(np.int32))

        out, j = [], 0
        for w in red.widths:
            if w == 1:
                out.append(out_flat[j].reshape(-1, 1))
            elif w:
                out.append(np.stack(out_flat[j:j + w], axis=1))
            else:
                out.append(np.zeros((n_groups, 0), dtype=np.int64))
            j += w
        return out, gvals

    # ------------------------------------------------------------------ #
    def _emit(self, instr: Instrumentation, name: str,
              counts: Counter) -> None:
        instr.begin_einsum(name)
        for key in sorted(counts, key=repr):
            n = int(counts[key])
            if n <= 0 or key == ("leaf",):
                continue
            tag = key[0]
            if tag == "touch":
                _, tensor, rank, kindk, rw = key
                instr.touch(name, tensor, rank, (), kindk, rw, n=n)
            elif tag == "iterate":
                instr.iterate(name, key[1], n=n)
            elif tag == "advance":
                instr.advance(name, key[1], n=n)
            elif tag == "compute":
                instr.compute(name, key[1], n=n)
            elif tag == "isect_step":
                instr.isect_step(name, key[1], key[2], n=n)
            elif tag == "isect_match":
                instr.isect_match(name, key[1], n=n)
        instr.end_einsum(name)
