"""VectorBackend: columnar per-rank co-iteration over CSF arrays.

Executes the same mapped loop nests as the Python interpreter
(``EinsumExecutor``) but one *rank* at a time instead of one *element*
at a time: the set of live iteration points at each loop level (the
frontier) is a struct-of-arrays, and advancing one loop level is a
handful of batched array ops -- segment expansion, offset-keyed sorted
intersection / union (``repro.kernels.ops``: the Pallas skip-ahead
intersection kernel on TPU, its ``searchsorted`` lowering on CPU), and
segmented reduction into the output.

Instrumentation counts are emitted in aggregate (one ``n``-weighted
call per action kind) and match the interpreter's per-element counts
exactly; output fibertrees are bit-identical, including float
accumulation order (contributions to one output coordinate are summed
in loop-iteration order).  Plans outside the supported class -- affine
or constant indices, take(), partitioned / flattened ranks, driverless
(dense) loop ranks, >2 co-iterated tensors per rank, non-arithmetic
semirings, leader-follower intersection -- transparently fall back to
``PythonBackend``, so ``VectorBackend`` is safe as a drop-in default.
See DESIGN.md for the architecture and the exact count semantics.
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .csf import CSF, _from_sorted_points
from .einsum import BinOp, Semiring, TensorAccess
from .fibertree import FTensor
from .iteration import EinsumExecutor, ExecutorBackend, PythonBackend
from .mapping import EinsumPlan
from .trace import Instrumentation, NullInstr

#: level-0 frontier slice size used to bound peak expansion memory when
#: the outermost loop rank is an output rank (slices are independent)
DEFAULT_CHUNK_ITEMS = 1024


class _Unsupported(Exception):
    """Plan shape the vector path does not cover (-> fallback)."""


# ---------------------------------------------------------------------- #
# expression analysis
# ---------------------------------------------------------------------- #
def _product_accesses(expr) -> Optional[List[TensorAccess]]:
    """Accesses of a pure multiplicative chain, in evaluation order."""
    out: List[TensorAccess] = []

    def rec(e) -> bool:
        if isinstance(e, TensorAccess):
            out.append(e)
            return True
        if isinstance(e, BinOp) and e.op == "*":
            return rec(e.lhs) and rec(e.rhs)
        return False

    return out if rec(expr) else None


def _classify_expr(expr) -> Tuple[str, List[TensorAccess]]:
    """('product', accesses) or ('sum', [lhs, rhs]); raises otherwise."""
    accs = _product_accesses(expr)
    if accs is not None:
        return "product", accs
    if (isinstance(expr, BinOp) and expr.op in "+-"
            and isinstance(expr.lhs, TensorAccess)
            and isinstance(expr.rhs, TensorAccess)):
        return "sum", [expr.lhs, expr.rhs]
    raise _Unsupported(f"expression shape {expr}")


# ---------------------------------------------------------------------- #
# batched helpers
# ---------------------------------------------------------------------- #
def _expand(lo: np.ndarray, hi: np.ndarray
            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Flatten per-item [lo, hi) ranges: (item_of, elem, counts, offs)."""
    counts = (hi - lo).astype(np.int64)
    total = int(counts.sum())
    item_of = np.repeat(np.arange(len(counts), dtype=np.int64), counts)
    offs = np.zeros(len(counts) + 1, dtype=np.int64)
    np.cumsum(counts, out=offs[1:])
    within = np.arange(total, dtype=np.int64) - offs[item_of]
    elem = lo[item_of] + within
    return item_of, elem, counts, offs


def _seg_last(coords: np.ndarray, offs: np.ndarray, counts: np.ndarray
              ) -> np.ndarray:
    """Last coordinate of each segment (0 for empty segments); safe
    when the whole expanded array is empty."""
    out = np.zeros(len(counts), dtype=np.int64)
    if len(coords):
        out = np.where(counts > 0,
                       coords[np.maximum(offs[1:] - 1, 0)], 0)
    return out


class _Frontier:
    """Live iteration points: per-tensor element positions + captured
    output coordinate columns.  ``pos`` semantics: >= 0 element index at
    the tensor's current depth, -1 absent (union), -2 not yet descended
    (root)."""

    __slots__ = ("n", "pos", "out_cols")

    def __init__(self, n: int, pos: Dict[str, np.ndarray],
                 out_cols: List[np.ndarray]):
        self.n = n
        self.pos = pos
        self.out_cols = out_cols

    def take(self, idx: np.ndarray, extra_col: Optional[np.ndarray] = None
             ) -> "_Frontier":
        cols = [c[idx] for c in self.out_cols]
        if extra_col is not None:
            cols.append(extra_col)
        return _Frontier(len(idx), {t: p[idx] for t, p in self.pos.items()},
                         cols)

    def slice(self, i0: int, i1: int) -> "_Frontier":
        return _Frontier(i1 - i0,
                         {t: p[i0:i1] for t, p in self.pos.items()},
                         [c[i0:i1] for c in self.out_cols])


class VectorBackend(ExecutorBackend):
    name = "vector"

    def __init__(self, chunk_items: int = DEFAULT_CHUNK_ITEMS,
                 fallback: bool = True):
        self.chunk_items = chunk_items
        self.fallback = fallback
        self._oracle = PythonBackend()
        #: 'vector' or 'fallback' for the most recent execute() call
        self.last_path: Optional[str] = None
        #: why the most recent execute() fell back (None on the fast path)
        self.last_fallback_reason: Optional[str] = None

    # ------------------------------------------------------------------ #
    def execute(self, plan, tensors, var_shapes, semiring=None, instr=None,
                out_initial=None, isect_strategy="two_finger",
                isect_leader=None) -> FTensor:
        instr = instr or NullInstr()
        semiring = semiring or Semiring.arithmetic()
        try:
            csf_out, _ = self._run_vectorized(
                plan, tensors, semiring, instr, out_initial, isect_strategy)
            self.last_path = "vector"
            self.last_fallback_reason = None
            return csf_out.to_ftensor()
        except _Unsupported as exc:
            if not self.fallback:
                raise
            self.last_path = "fallback"
            self.last_fallback_reason = str(exc)
            ften = {t: (v.to_ftensor() if isinstance(v, CSF) else v)
                    for t, v in tensors.items()}
            return self._oracle.execute(
                plan, ften, var_shapes, semiring=semiring, instr=instr,
                out_initial=out_initial, isect_strategy=isect_strategy,
                isect_leader=isect_leader)

    def execute_csf(self, plan, tensors, semiring=None, instr=None,
                    isect_strategy="two_finger") -> Tuple[CSF, Dict]:
        """Vector path only (no fallback): returns the output as a CSF
        plus run stats, never materializing per-element Python objects.
        This is the large-scale entry point used by the throughput
        benchmark."""
        instr = instr or NullInstr()
        semiring = semiring or Semiring.arithmetic()
        return self._run_vectorized(plan, tensors, semiring, instr,
                                    None, isect_strategy)

    # ------------------------------------------------------------------ #
    # supported-plan analysis
    # ------------------------------------------------------------------ #
    def _analyze(self, ex: EinsumExecutor, semiring: Semiring,
                 out_initial, isect_strategy: str):
        if out_initial is not None:
            raise _Unsupported("update-in-place output")
        if semiring.name != "arith":
            raise _Unsupported(f"semiring {semiring.name}")
        einsum = ex.einsum
        if not einsum.output.indices:
            raise _Unsupported("bare copy")
        if any(not ix.is_bare for ix in einsum.output.indices):
            raise _Unsupported("non-bare output indices")
        kind, accs = _classify_expr(einsum.expr)
        for a in accs:
            if any(not ix.is_bare for ix in a.indices):
                raise _Unsupported(f"non-bare access {a}")
        if ex.unmatched_out:
            raise _Unsupported("output ranks bound at the leaf")
        plan = ex.plan
        if any(ri.flattened for ri in plan.loop_order):
            raise _Unsupported("flattened loop ranks")
        order = [a.tensor for a in accs]
        for t in order:
            if len(ex.drive[t]) != len(plan.tensors[t].exec_order):
                raise _Unsupported(f"{t}: lookup (non-driving) levels")
        # per-level driver lists in expression order
        levels: List[Tuple[str, List[Tuple[str, int]]]] = []
        for li, ri in enumerate(plan.loop_order):
            drv = [(t, ex.drive[t][li]) for t in order if li in ex.drive[t]]
            if len(drv) == 0:
                raise _Unsupported(f"driverless (dense) rank {ri.name}")
            if len(drv) > 2:
                raise _Unsupported(f">2 drivers at rank {ri.name}")
            if (kind == "product" and len(drv) == 2
                    and isect_strategy != "two_finger"):
                raise _Unsupported(f"{isect_strategy} intersection")
            levels.append((ri.name, drv))
        if kind == "sum":
            keys = {t: frozenset(ex.drive[t]) for t in order}
            all_levels = frozenset(range(len(plan.loop_order)))
            if any(k != all_levels for k in keys.values()):
                raise _Unsupported("summands with unaligned ranks")
        return kind, accs, levels

    # ------------------------------------------------------------------ #
    # the vector loop nest
    # ------------------------------------------------------------------ #
    def _run_vectorized(self, plan: EinsumPlan, tensors: Dict[str, Any],
                        semiring: Semiring, instr: Instrumentation,
                        out_initial, isect_strategy: str
                        ) -> Tuple[CSF, Dict]:
        ex = EinsumExecutor(plan, tensors, {}, semiring=semiring,
                            instr=NullInstr(),
                            isect_strategy=isect_strategy)
        kind, accs, levels = self._analyze(ex, semiring, out_initial,
                                           isect_strategy)
        name = plan.output
        csf: Dict[str, CSF] = {}
        for a in accs:
            v = tensors[a.tensor]
            c = v if isinstance(v, CSF) else CSF.from_ftensor(v)
            if any(c.level_width(d) != 1 for d in range(c.ndim)):
                raise _Unsupported(f"{a.tensor}: tuple coordinates")
            csf[a.tensor] = c

        counts: Counter = Counter()
        leaf_depth = {t: len(plan.tensors[t].exec_order) - 1
                      for t in csf}
        out_ranks = plan.tensors[name].exec_order

        frontier = _Frontier(1, {t: np.full(1, -2, dtype=np.int64)
                                 for t in csf}, [])

        # level 0 first, then (optionally chunked) deeper levels
        frontier = self._level(0, levels, ex, csf, frontier, counts, kind)
        chunked = (0 in ex.out_descend and frontier.n > self.chunk_items
                   and len(levels) > 1)
        paths_parts: List[np.ndarray] = []
        vals_parts: List[np.ndarray] = []
        step = self.chunk_items if chunked else max(frontier.n, 1)
        for i0 in range(0, max(frontier.n, 1), step):
            part = frontier.slice(i0, min(i0 + step, frontier.n))
            for li in range(1, len(levels)):
                part = self._level(li, levels, ex, csf, part, counts, kind)
            p, v = self._finalize(part, ex, csf, counts)
            if len(v):
                paths_parts.append(p)
                vals_parts.append(v)

        if paths_parts:
            paths = np.concatenate(paths_parts, axis=0)
            vals = np.concatenate(vals_parts)
        else:
            paths = np.zeros((0, len(out_ranks)), dtype=np.int64)
            vals = np.zeros(0, dtype=np.float64)
        out_csf = _from_sorted_points(
            name, out_ranks, [paths[:, d:d + 1] for d in range(paths.shape[1])],
            vals, {r: None for r in out_ranks}, 0,
            {r for r in out_ranks
             if plan.created_ranks.get(r) == "upper"})

        self._emit(instr, name, counts)
        stats = {"leaf_points": int(counts.get(("leaf",), 0)),
                 "muls": int(counts.get(("compute", "mul"), 0)),
                 "out_nnz": int(len(vals))}
        return out_csf, stats

    # ------------------------------------------------------------------ #
    def _ranges(self, c: CSF, d: int, pos: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
        n = len(pos)
        if d == 0:
            n0 = len(c.coords[0])
            return (np.zeros(n, dtype=np.int64),
                    np.full(n, n0, dtype=np.int64))
        seg = c.segments[d]
        valid = pos >= 0
        # clamp also covers the all-absent / empty-tensor case, where
        # seg has a single entry and no position is valid
        safe = np.clip(pos, 0, max(len(seg) - 2, 0))
        lo = np.where(valid, seg[safe], 0)
        hi = np.where(valid, seg[np.minimum(safe + 1, len(seg) - 1)], 0)
        return lo, hi

    def _level(self, li: int, levels, ex: EinsumExecutor,
               csf: Dict[str, CSF], fr: _Frontier, counts: Counter,
               kind: str) -> _Frontier:
        rank, drv = levels[li]
        name = ex.name
        out_here = li in ex.out_descend

        if len(drv) == 1:
            t, d = drv[0]
            lo, hi = self._ranges(csf[t], d, fr.pos[t])
            item_of, elem, _, _ = _expand(lo, hi)
            coord = csf[t].coords[d][elem, 0]
            n = len(elem)
            counts[("touch", t, rank, "coord", "r")] += n
            counts[("iterate", rank)] += n
            counts[("advance", rank)] += n
            if d == self._leaf_depth(ex, t):
                counts[("touch", t, rank, "payload", "r")] += n
            nf = fr.take(item_of, coord if out_here else None)
            nf.pos[t] = elem
            return nf

        (ta, da), (tb, db) = drv
        ca, cb = csf[ta], csf[tb]
        lo_a, hi_a = self._ranges(ca, da, fr.pos[ta])
        lo_b, hi_b = self._ranges(cb, db, fr.pos[tb])
        ia, ea, na, offs_a = _expand(lo_a, hi_a)
        ib, eb, nb, offs_b = _expand(lo_b, hi_b)
        coord_a = ca.coords[da][ea, 0].astype(np.int64)
        coord_b = cb.coords[db][eb, 0].astype(np.int64)
        mult = int(max(coord_a.max(initial=0), coord_b.max(initial=0))) + 1
        akeys = ia * mult + coord_a
        bkeys = ib * mult + coord_b

        if kind == "product":
            from repro.kernels import ops as kops
            idx = kops.intersect_keys(akeys, bkeys)
            hit = idx >= 0
            n_match = int(hit.sum())
            # two-finger pointer advances: elements <= the other side's
            # last coordinate (within each item's fiber pair)
            items = np.arange(fr.n, dtype=np.int64)
            both = (na > 0) & (nb > 0)
            bmax = _seg_last(coord_b, offs_b, nb)
            amax = _seg_last(coord_a, offs_a, na)
            adv_a = np.where(both, np.searchsorted(
                akeys, items * mult + bmax, side="right") - offs_a[:-1], 0)
            adv_b = np.where(both, np.searchsorted(
                bkeys, items * mult + amax, side="right") - offs_b[:-1], 0)
            touched_a = np.minimum(adv_a + 1, na)
            touched_b = np.minimum(adv_b + 1, nb)
            counts[("touch", ta, rank, "coord", "r")] += int(touched_a.sum())
            counts[("touch", tb, rank, "coord", "r")] += int(touched_b.sum())
            counts[("isect_step", rank, ta)] += int(adv_a.sum())
            counts[("isect_step", rank, tb)] += int(adv_b.sum())
            counts[("isect_match", rank)] += n_match
            counts[("iterate", rank)] += n_match
            counts[("advance", rank)] += n_match
            if da == self._leaf_depth(ex, ta):
                counts[("touch", ta, rank, "payload", "r")] += n_match
            if db == self._leaf_depth(ex, tb):
                counts[("touch", tb, rank, "payload", "r")] += n_match
            sel = np.flatnonzero(hit)
            nf = fr.take(ia[sel], coord_a[sel] if out_here else None)
            nf.pos[ta] = ea[sel]
            nf.pos[tb] = eb[idx[sel]]
            return nf

        # union (additive expression)
        from repro.kernels import ops as kops
        ukeys, pa, pb = kops.union_keys(akeys, bkeys)
        n_u = len(ukeys)
        item_u = ukeys // mult
        coord_u = ukeys % mult
        counts[("touch", ta, rank, "coord", "r")] += int(len(akeys))
        counts[("touch", tb, rank, "coord", "r")] += int(len(bkeys))
        counts[("iterate", rank)] += n_u
        counts[("advance", rank)] += n_u
        present_a = pa >= 0
        present_b = pb >= 0
        if da == self._leaf_depth(ex, ta):
            counts[("touch", ta, rank, "payload", "r")] += int(present_a.sum())
        if db == self._leaf_depth(ex, tb):
            counts[("touch", tb, rank, "payload", "r")] += int(present_b.sum())
        nf = fr.take(item_u, coord_u if out_here else None)
        pos_a = np.full(n_u, -1, dtype=np.int64)
        pos_b = np.full(n_u, -1, dtype=np.int64)
        if len(ea):
            pos_a[present_a] = ea[pa[present_a]]
        if len(eb):
            pos_b[present_b] = eb[pb[present_b]]
        nf.pos[ta] = pos_a
        nf.pos[tb] = pos_b
        return nf

    @staticmethod
    def _leaf_depth(ex: EinsumExecutor, t: str) -> int:
        return len(ex.plan.tensors[t].exec_order) - 1

    # ------------------------------------------------------------------ #
    def _finalize(self, fr: _Frontier, ex: EinsumExecutor,
                  csf: Dict[str, CSF], counts: Counter
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """Leaf evaluation + segmented in-order reduction."""
        name = ex.name
        counts[("leaf",)] += fr.n
        leafvals: Dict[str, np.ndarray] = {}
        for t, c in csf.items():
            pos = fr.pos[t]
            v = np.zeros(fr.n, dtype=np.float64)
            present = pos >= 0
            if len(c.values):
                v[present] = c.values[pos[present]]
            leafvals[t] = v

        def ev(e) -> np.ndarray:
            if isinstance(e, TensorAccess):
                return leafvals[e.tensor]
            assert isinstance(e, BinOp)
            lv, rv = ev(e.lhs), ev(e.rhs)
            if e.op == "*":
                mask = (lv != 0) & (rv != 0)
                counts[("compute", "mul")] += int(mask.sum())
                return np.where(mask, lv * rv, 0.0)
            if e.op == "+":
                both = (lv != 0) & (rv != 0)
                counts[("compute", "add")] += int(both.sum())
                return np.where(lv == 0, rv, np.where(rv == 0, lv, lv + rv))
            counts[("compute", "add")] += lv.size
            return lv - rv

        vals = ev(ex.einsum.expr)
        if fr.out_cols:
            paths = np.stack(fr.out_cols, axis=1)
        else:
            paths = np.zeros((fr.n, 0), dtype=np.int64)
        nz = np.flatnonzero(vals != 0)
        paths, vals = paths[nz], vals[nz]
        if len(vals) == 0:
            return paths, vals
        ncol = paths.shape[1]
        order = np.lexsort(tuple(paths[:, c] for c in range(ncol - 1, -1, -1)))
        paths, vals = paths[order], vals[order]
        boundary = np.ones(len(vals), dtype=bool)
        if len(vals) > 1:
            boundary[1:] = np.any(paths[1:] != paths[:-1], axis=1)
        starts = np.flatnonzero(boundary)
        group_counts = np.diff(np.append(starts, len(vals)))
        sums = vals[starts].copy()
        # accumulate strictly in iteration order (matches the
        # interpreter's sequential semiring.add, bit for bit)
        step = 1
        while True:
            act = np.flatnonzero(group_counts > step)
            if len(act) == 0:
                break
            sums[act] = sums[act] + vals[starts[act] + step]
            step += 1
        out_rank = ex.plan.tensors[name].exec_order[-1]
        n_contrib = len(vals)
        n_out = len(starts)
        counts[("touch", name, out_rank, "payload", "w")] += n_contrib
        counts[("touch", name, out_rank, "payload", "r")] += n_contrib - n_out
        counts[("compute", "add")] += n_contrib - n_out
        return paths[starts], sums

    # ------------------------------------------------------------------ #
    def _emit(self, instr: Instrumentation, name: str,
              counts: Counter) -> None:
        instr.begin_einsum(name)
        for key in sorted(counts, key=repr):
            n = int(counts[key])
            if n <= 0 or key == ("leaf",):
                continue
            tag = key[0]
            if tag == "touch":
                _, tensor, rank, kindk, rw = key
                instr.touch(name, tensor, rank, (), kindk, rw, n=n)
            elif tag == "iterate":
                instr.iterate(name, key[1], n=n)
            elif tag == "advance":
                instr.advance(name, key[1], n=n)
            elif tag == "compute":
                instr.compute(name, key[1], n=n)
            elif tag == "isect_step":
                instr.isect_step(name, key[1], key[2], n=n)
            elif tag == "isect_match":
                instr.isect_match(name, key[1], n=n)
        instr.end_einsum(name)
