"""Hardware component action-count models (TeAAL Sec. 4.1.2 / Table 3).

The ``PerformanceModel`` is an Instrumentation sink: the executing loop
nest streams data-access / iteration / compute events into it, and each
event is routed to the hardware component bound to it (Sec. 4.1.3).
Storage components simulate residency online (buffets with explicit
evict-on epochs, caches with LRU), so DRAM traffic is derived from real
misses on real data rather than an analytic distribution -- the fidelity
claim of the paper.

Components and their attributes (Table 3):
  DRAM          bandwidth (GB/s)
  Buffer        type (buffet | cache), width (bytes/line), depth (lines),
                bandwidth (GB/s, optional)
  Intersection  type (two_finger | leader_follower | skip_ahead), leader
  Merger        inputs, comparator_radix, outputs, order, reduce
  Sequencer     num_ranks
  Compute       type (mul | add)

Cycle attribution honors spatial work scheduling: events are keyed by
the coordinates of the mapping's ``space`` ranks, and a spatially
fanned-out component's cycle count is the *maximum* over its spatial
instances (real load imbalance, not an average).
"""
from __future__ import annotations

import math
import threading
from collections import Counter, OrderedDict, defaultdict
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from .density import stat_misses
from .fibertree import Fiber, FTensor
from .formats import fiber_header_bytes, subtree_bytes, touch_bytes
from .mapping import EinsumPlan
from .spec import (AcceleratorSpec, Component, EinsumBinding, RankFormat,
                   StorageBinding, TensorFormat)
from .trace import Instrumentation

SpatialKey = Tuple

# ---------------------------------------------------------------------- #
# point-axis vectorized statistical residency (DSE batched replay)
# ---------------------------------------------------------------------- #
#: thread-local feed of pre-vectorized ``stat_misses`` values.  The DSE
#: engine computes the capacity-dependent miss closed form for a whole
#: group of design points in one numpy pass (``density.
#: batched_stat_misses`` over the point axis) and replays the recorded
#: event stream per point under ``stat_miss_feed`` -- each touch_stat
#: then consumes its precomputed value instead of recomputing it.  A
#: feed entry that does not match the live call (routing drift) makes
#: the feed stand down and the scalar closed form take over, so feeding
#: is an optimization that can never change results.
_STAT_FEED = threading.local()


@contextmanager
def stat_miss_feed(feed):
    prev = getattr(_STAT_FEED, "feed", None)
    _STAT_FEED.feed = feed
    try:
        yield
    finally:
        _STAT_FEED.feed = prev



# ---------------------------------------------------------------------- #
# storage levels
# ---------------------------------------------------------------------- #
class DRAM:
    """Backing store: accumulates bytes; time = bytes / bandwidth."""

    def __init__(self, name: str, bandwidth_gbs: float):
        self.name = name
        self.bandwidth_gbs = bandwidth_gbs
        self.read_bytes = 0.0
        self.write_bytes = 0.0

    def access(self, nbytes: float, rw: str, key: Any = None) -> None:
        if rw == "r":
            self.read_bytes += nbytes
        else:
            self.write_bytes += nbytes

    @property
    def total_bytes(self) -> float:
        return self.read_bytes + self.write_bytes

    def seconds(self) -> float:
        return self.total_bytes / (self.bandwidth_gbs * 1e9)


class StorageLevel:
    """One buffer level for one binding.  ``buffet`` has an explicit
    fill/drain policy (evict_on rank epochs); ``cache`` is LRU.  Capacity
    is tracked in *bytes* (width x depth), so occupancy-sized residency
    granules (eager subtrees) displace proportionally."""

    def __init__(self, comp: Component, binding: StorageBinding,
                 instances: int, backing: "StorageLevel | DRAM"):
        self.comp = comp
        self.binding = binding
        self.instances = instances
        self.backing = backing
        self.kind = comp.attrs.get("type", "buffet")
        self.width = float(comp.attrs.get("width", 8))      # bytes / line
        self.depth = int(comp.attrs.get("depth", 1 << 30))  # lines
        self.capacity_bytes = self.width * self.depth * instances
        self.bandwidth_gbs = comp.attrs.get("bandwidth")
        # residency state: key -> [bytes, dirty]
        self.resident: "OrderedDict[Any, list]" = OrderedDict()
        self.resident_bytes = 0.0
        # stats
        self.reads = 0
        self.writes = 0
        self.fills = 0
        self.drains = 0
        self.fill_bytes = 0.0
        self.drain_bytes = 0.0
        self.access_bytes = 0.0

    # -------------------------------------------------------------- #
    def touch(self, key: Any, nbytes: float, rw: str,
              fill_bytes: Optional[float] = None, n: int = 1) -> None:
        """``n`` accesses of ``nbytes`` each; ``fill_bytes`` is the
        transfer size on a miss (subtree for eager bindings, line for
        caches).  Aggregate touches (n > 1, from the vector backend)
        count every access but model residency as a single fill."""
        self.access_bytes += nbytes * n
        if rw == "r":
            self.reads += n
        else:
            self.writes += n
        got = self.resident.get(key)
        if got is not None:
            self.resident.move_to_end(key)
            if rw == "w":
                got[1] = True
            return
        # miss -> fill from backing (outputs fill empty: no read for 'w')
        size = fill_bytes if fill_bytes is not None else \
            (self.width if self.kind == "cache" else max(nbytes, 1e-9))
        self.fills += 1
        self.fill_bytes += size
        if rw == "r":
            self._backing_access(size, "r", key)
        self.resident[key] = [size, rw == "w"]
        self.resident_bytes += size
        while self.resident_bytes > self.capacity_bytes \
                and len(self.resident) > 1:
            old_key, (osize, dirty) = self.resident.popitem(last=False)
            self.resident_bytes -= osize
            if dirty:
                self._drain_one(osize, old_key)

    def touch_stat(self, key: Any, nbytes: float, rw: str, n: int,
                   unique: int) -> None:
        """Statistical residency for aggregate touches that carry a
        distinct-element hint (the analytic backend): ``unique`` cold
        misses, plus capacity misses on the reuse accesses when the
        touched footprint exceeds this level's capacity -- the
        Sparseloop-style closed-form a path-exact LRU cannot provide
        from aggregate events.  ``unique == 0`` means the data is
        produced on chip (read-modify of freshly written output): pure
        bandwidth accesses, no backing traffic."""
        self.access_bytes += nbytes * n
        if rw == "r":
            self.reads += n
        else:
            self.writes += n
        unique = max(0, min(int(unique), n))
        if unique == 0 or nbytes <= 0:
            return
        footprint = unique * nbytes
        misses = None
        feed = getattr(_STAT_FEED, "feed", None)
        if feed is not None:
            misses = feed.take(self, nbytes, n, unique)
        if misses is None:
            misses = stat_misses(n, unique, nbytes, self.capacity_bytes)
        self.fills += int(round(misses))
        self.fill_bytes += misses * nbytes
        if rw == "r":
            self._backing_access(misses * nbytes, "r", key)
        else:
            # written data eventually drains through the backing store
            self.drains += unique
            self.drain_bytes += footprint
            self._backing_access(footprint, "w", key)

    def access(self, nbytes: float, rw: str, key: Any = None) -> None:
        """Entry point when a *child* level fills/drains through us."""
        self.touch(key if key is not None else object(), nbytes, rw,
                   fill_bytes=nbytes)

    def _backing_access(self, nbytes: float, rw: str, key: Any) -> None:
        self.backing.access(nbytes, rw, key)

    def _drain_one(self, size: float, key: Any) -> None:
        self.drains += 1
        self.drain_bytes += size
        self._backing_access(size, "w", key)

    def evict_all(self, size_fn=None) -> None:
        """Buffet drain at an evict-on epoch boundary."""
        for key, (size, dirty) in self.resident.items():
            if dirty:
                self._drain_one(size, key)
        self.resident.clear()
        self.resident_bytes = 0.0

    def seconds(self, clock_ghz: float) -> float:
        if self.bandwidth_gbs:
            return self.access_bytes / (self.bandwidth_gbs * 1e9)
        # default: one access per cycle per instance
        return (self.reads + self.writes) / self.instances / (clock_ghz * 1e9)


# ---------------------------------------------------------------------- #
# functional units
# ---------------------------------------------------------------------- #
@dataclass
class FunctionalUnit:
    comp: Component
    instances: int
    # per-spatial-instance counts (load imbalance!)
    per_key: Counter = field(default_factory=Counter)
    total: float = 0.0

    def add(self, key: SpatialKey, n: float = 1.0) -> None:
        self.per_key[key] += n
        self.total += n

    def cycles(self) -> float:
        if not self.per_key:
            return 0.0
        if len(self.per_key) <= 1:
            # no spatial attribution: spread over instances
            return self.total / self.instances
        # each spatial slot is one hardware instance; the slowest wins.
        # if there are more slots than instances, slots time-multiplex.
        mx = max(self.per_key.values())
        waves = math.ceil(len(self.per_key) / self.instances)
        return max(mx * waves, self.total / self.instances)


class Merger:
    """Hardware merger: rank-swizzles E elements arriving as L sorted
    runs.  A radix-R comparator tree needs ceil(log_R L) passes over the
    data; ``outputs`` elements emerge per cycle."""

    def __init__(self, comp: Component, instances: int):
        self.comp = comp
        self.instances = instances
        self.radix = int(comp.attrs.get("comparator_radix", 64))
        self.outputs = int(comp.attrs.get("outputs", 1))
        self.elements = 0.0
        self.events = 0
        self._cycles = 0.0

    def merge(self, elements: int, lists: int) -> None:
        self.events += 1
        self.elements += elements
        if lists <= 1:
            return
        passes = max(1, math.ceil(math.log(max(lists, 2), self.radix)))
        self._cycles += elements * passes / self.outputs

    def cycles(self) -> float:
        return self._cycles / self.instances


class Intersector:
    """Intersection unit (two_finger | leader_follower | skip_ahead)."""

    def __init__(self, comp: Component, instances: int):
        self.comp = comp
        self.instances = instances
        self.kind = comp.attrs.get("type", "two_finger")
        self.leader = comp.attrs.get("leader")
        self.steps: Counter = Counter()          # tensor -> pointer advances
        self.matches = 0
        self.per_key: Counter = Counter()

    def step(self, tensor: str, key: SpatialKey, n: int = 1) -> None:
        self.steps[tensor] += n
        self.per_key[key] += n

    def match(self, key: SpatialKey, n: int = 1) -> None:
        self.matches += n

    def cycles(self) -> float:
        total_steps = sum(self.steps.values())
        if self.kind == "two_finger":
            total = total_steps                  # one finger moves per cycle
        elif self.kind == "leader_follower":
            total = self.steps.get(self.leader, 0) or total_steps / 2
        else:                                    # skip_ahead (ExTensor)
            # matched coordinates cost a cycle; skipped runs are jumped in
            # ~one cycle each: approximate skips by the smaller side's
            # non-matching steps.
            smaller = min(self.steps.values()) if self.steps else 0
            total = self.matches + max(smaller - self.matches, 0)
        if len(self.per_key) > 1:
            frac = max(self.per_key.values()) / max(sum(self.per_key.values()),
                                                    1)
            waves = math.ceil(len(self.per_key) / self.instances)
            return max(total * frac * waves, total / self.instances)
        return total / self.instances


# ---------------------------------------------------------------------- #
# the per-Einsum performance model
# ---------------------------------------------------------------------- #
class EinsumModel:
    """Routes one Einsum's event stream into bound components."""

    def __init__(self, spec: AcceleratorSpec, plan: EinsumPlan,
                 binding: EinsumBinding, dram: DRAM,
                 shared: Dict[str, Any]):
        self.spec = spec
        self.plan = plan
        self.binding = binding
        self.dram = dram
        self.name = plan.output
        topo = binding.topology if binding.topology in spec.arch.topologies \
            else next(iter(spec.arch.topologies), None)
        self.topology = topo

        # ---- storage chains: (tensor, kind) -> [innermost..outermost]
        self.chains: Dict[Tuple[str, str], List[StorageLevel]] = {}
        self.eager_depth: Dict[int, int] = {}
        self.evict_map: Dict[str, List[StorageLevel]] = defaultdict(list)
        by_key: Dict[Tuple[str, str], List[StorageBinding]] = defaultdict(list)
        for sb in binding.storage:
            kinds = ("coord", "payload") if sb.type == "elem" else (sb.type,)
            for k in kinds:
                by_key[(sb.tensor, k)].append(sb)
        # (component, tensor, kind) -> StorageLevel, SHARED across the
        # whole cascade so on-chip intermediates persist between Einsums
        self._levels: Dict[Tuple[str, str, str], StorageLevel] = shared
        for key, sbs in by_key.items():
            chain: List[StorageLevel] = []
            # order: binding list order = innermost first
            backing: Any = self.dram
            for sb in reversed(sbs):
                comp, inst = self._find(sb.component)
                lvl_key = (sb.component, sb.tensor, key[1])
                lvl = self._levels.get(lvl_key)
                if lvl is None:
                    lvl = StorageLevel(comp, sb, inst, backing)
                    self._levels[lvl_key] = lvl
                if sb.evict_on:
                    if lvl not in self.evict_map[sb.evict_on]:
                        self.evict_map[sb.evict_on].append(lvl)
                chain.append(lvl)
                backing = lvl
            chain.reverse()
            self.chains[key] = chain

        # ---- functional units
        self.units: Dict[str, FunctionalUnit] = {}
        self.compute_map: Dict[str, FunctionalUnit] = {}
        for cb in binding.compute:
            comp, inst = self._find(cb.component)
            fu = self.units.setdefault(cb.component,
                                       FunctionalUnit(comp, inst))
            self.compute_map[cb.op] = fu

        self.isect: Optional[Intersector] = None
        self.merger: Optional[Merger] = None
        self.seq: Optional[FunctionalUnit] = None
        for comp, inst in self._all_components():
            if comp.klass == "Intersection" and self.isect is None:
                self.isect = Intersector(comp, inst)
            elif comp.klass == "Merger" and self.merger is None:
                self.merger = Merger(comp, inst)
            elif comp.klass == "Sequencer" and self.seq is None:
                self.seq = FunctionalUnit(comp, inst)

        # spatial context
        self.space_ranks = plan.space_ranks
        self._space_ctx: Dict[str, Any] = {}
        # exec-form tensors for subtree footprints (set by the generator)
        self.tensors: Dict[str, FTensor] = {}
        self._subtree_cache: Dict[Tuple[str, Tuple], float] = {}
        # fused intermediates (set by PerformanceModel)
        self.stream_tensors: Set[str] = set()
        # concrete-layout position caches for line-granular cache keys
        self._offset_cache: Dict[Tuple[str, int], Dict] = {}
        self._dyn_pos: Dict[Tuple, Dict] = {}

    # -------------------------------------------------------------- #
    def _find(self, comp_name: str) -> Tuple[Component, int]:
        if self.topology is None:
            return Component(comp_name, "Compute"), 1
        return self.spec.arch.find(self.topology, comp_name)

    def _all_components(self) -> List[Tuple[Component, int]]:
        if self.topology is None:
            return []
        return self.spec.arch.topologies[self.topology].all_components()

    def _fmt(self, tensor: str, config: str = "default") -> TensorFormat:
        cfgs = self.spec.format.tensors.get(tensor)
        if cfgs and config in cfgs:
            return cfgs[config]
        return self.spec.format.default(tensor)

    def spatial_key(self) -> SpatialKey:
        return tuple(self._space_ctx.get(r) for r in self.space_ranks)

    # -------------------------------------------------------------- #
    # event entry points (called by PerformanceModel)
    # -------------------------------------------------------------- #
    def on_iterate(self, rank: str, coord: Any, n: int = 1) -> None:
        if rank in self.space_ranks:
            self._space_ctx[rank] = coord
        if self.seq is not None:
            self.seq.add(self.spatial_key(), n)

    def on_touch(self, tensor: str, rank: str, path: Tuple, kind: str,
                 rw: str, n: int = 1,
                 unique: Optional[int] = None) -> None:
        fmt = self._fmt(tensor)
        nbytes = touch_bytes(fmt, rank, kind)
        chain = self.chains.get((tensor, kind))
        if not chain:
            # fused intermediates stream on-chip between the Einsums of
            # one fusion block (Gamma's T through the merger, Sec. 4.3)
            # and never touch DRAM; everything else unbound streams
            # to/from DRAM.
            if tensor in self.stream_tensors:
                return
            if nbytes:
                self.dram.access(nbytes * n, rw)
            return
        lvl = chain[0]
        sb = lvl.binding
        if n > 1 or not path:
            # aggregate touch: no per-element path.  With a
            # distinct-element hint (analytic backend) residency is
            # estimated statistically; without one (vector backend)
            # it degrades to (rank, kind)-granular keys -- counts are
            # exact, locality is approximate either way.
            if unique is not None:
                lvl.touch_stat((tensor, rank, kind), nbytes, rw, n, unique)
            else:
                lvl.touch((tensor, rank, kind), nbytes, rw,
                          fill_bytes=nbytes, n=n)
            return
        if sb.style == "eager":
            # residency granule: the subtree under the binding rank
            ft = self.tensors.get(tensor)
            depth = self._rank_depth(tensor, sb.rank)
            key = path[:depth + 1]
            fill = self._subtree_fill(tensor, key, depth, fmt)
            lvl.touch(key, nbytes, rw, fill_bytes=fill)
        elif lvl.kind == "cache":
            # line-granular residency: a compressed (C-format) tensor is
            # laid out POSITIONALLY -- one contiguous array per rank in
            # lexicographic fiber order (CSR-style), and partitioning /
            # flattening preserve that order (Sec. 3.2.1: the concrete
            # representation may remain unchanged).  Keying lines by the
            # element's GLOBAL position credits spatial locality across
            # fiber boundaries; keying by element or coordinate would
            # charge a full line per element and inflate traffic by
            # width/elem_bytes.
            epl = max(1, int(lvl.width // max(nbytes, 1.0)))
            pos, proj = self._line_position(tensor, path)
            key = (rank, kind) + proj + (pos // epl,)
            lvl.touch(key, nbytes, rw, fill_bytes=lvl.width)
        else:
            lvl.touch((rank,) + tuple(path), nbytes, rw,
                      fill_bytes=nbytes)

    def _project_prefix(self, tensor: str, path: Tuple) -> Tuple:
        """Path prefix with partition-upper coords dropped (the stored
        layout addresses content coordinates only)."""
        tp = self.plan.tensors.get(tensor)
        ranks = tp.exec_order if tp is not None else \
            (self.tensors[tensor].ranks if tensor in self.tensors else [])
        if len(ranks) < len(path):
            return tuple(path[:-1])
        out = []
        for r, c in zip(ranks[:len(path) - 1], path[:-1]):
            if self.plan.created_ranks.get(r) == "upper":
                continue
            out.append(c)
        return tuple(out)

    def _line_position(self, tensor: str, path: Tuple
                       ) -> Tuple[int, Tuple]:
        """(global positional index of path[-1] in its rank's concrete
        array, projected key prefix)."""
        import bisect
        if not path:
            return 0, ()
        ft = self.tensors.get(tensor)
        if ft is not None:
            d = len(path) - 1
            ck = (tensor, d)
            offs = self._offset_cache.get(ck)
            if offs is None:
                offs = {}
                total = 0

                def rec(fiber: Fiber, depth: int, prefix: Tuple) -> int:
                    nonlocal total
                    if depth == d:
                        offs[prefix] = (total, fiber)
                        total += len(fiber)
                        return 0
                    for c, p in fiber:
                        if isinstance(p, Fiber):
                            rec(p, depth + 1, prefix + (c,))
                    return 0

                rec(ft.root, 0, ())
                self._offset_cache[ck] = offs
            got = offs.get(tuple(path[:-1]))
            if got is not None:
                start, fiber = got
                return (start + bisect.bisect_left(fiber.coords,
                                                   path[-1]), ())
        # dynamic (output) tensors: first-touch order approximates the
        # concordant build order of the concrete array
        proj = self._project_prefix(tensor, path)
        dp = self._dyn_pos.setdefault((tensor, proj), {})
        pos = dp.get(path[-1])
        if pos is None:
            pos = len(dp)
            dp[path[-1]] = pos
        return pos, proj

    def _rank_depth(self, tensor: str, rank: str) -> int:
        tp = self.plan.tensors.get(tensor)
        if tp and rank in tp.exec_order:
            return tp.exec_order.index(rank)
        ft = self.tensors.get(tensor)
        if ft and rank in ft.ranks:
            return ft.ranks.index(rank)
        return 0

    def _subtree_fill(self, tensor: str, key: Tuple, depth: int,
                      fmt: TensorFormat) -> float:
        ck = (tensor, key)
        got = self._subtree_cache.get(ck)
        if got is not None:
            return got
        ft = self.tensors.get(tensor)
        size = 8.0
        if ft is not None:
            node: Any = ft.root
            ok = True
            for c in key:
                if not isinstance(node, Fiber):
                    ok = False
                    break
                node = node.lookup(c)
                if node is None:
                    ok = False
                    break
            if ok:
                size = subtree_bytes(ft, fmt, node, min(depth + 1,
                                                        len(ft.ranks) - 1)) \
                    if isinstance(node, Fiber) else \
                    touch_bytes(fmt, ft.ranks[-1], "payload")
        self._subtree_cache[ck] = size
        return size

    def on_advance(self, rank: str) -> None:
        for lvl in self.evict_map.get(rank, ()):
            lvl.evict_all()

    def on_compute(self, op: str, n: int = 1) -> None:
        fu = self.compute_map.get(op)
        if fu is None:
            fu = self.compute_map.get("mul") or self.compute_map.get("add")
        if fu is not None:
            fu.add(self.spatial_key(), n)

    def on_isect_step(self, rank: str, tensor: str, n: int = 1) -> None:
        if self.isect is not None:
            self.isect.step(tensor, self.spatial_key(), n)

    def on_isect_match(self, rank: str, n: int = 1) -> None:
        if self.isect is not None:
            self.isect.match(self.spatial_key(), n)

    def on_merge(self, tensor: str, elements: int, lists: int) -> None:
        if self.merger is not None:
            self.merger.merge(elements, lists)

    def finish(self) -> None:
        """Einsum end: buffet epochs close (caches persist on-chip)."""
        for lvls in self.evict_map.values():
            for lvl in lvls:
                lvl.evict_all()

    # -------------------------------------------------------------- #
    def component_seconds(self, clock_ghz: float) -> Dict[str, float]:
        """Per-component busy time for this Einsum (excl. DRAM)."""
        out: Dict[str, float] = {}
        hz = clock_ghz * 1e9
        seen = set()
        for chain in self.chains.values():
            for lvl in chain:
                if id(lvl) in seen:
                    continue
                seen.add(id(lvl))
                cname = lvl.comp.name
                out[cname] = out.get(cname, 0.0) + lvl.seconds(clock_ghz)
        for name, fu in self.units.items():
            out[name] = out.get(name, 0.0) + fu.cycles() / hz
        if self.isect is not None:
            out[self.isect.comp.name] = self.isect.cycles() / hz
        if self.merger is not None:
            out[self.merger.comp.name] = self.merger.cycles() / hz
        if self.seq is not None:
            out[self.seq.comp.name] = self.seq.cycles() / hz
        return out

    def action_counts(self) -> Dict[str, float]:
        """Flat action counts for the energy model."""
        acts: Dict[str, float] = Counter()
        seen = set()
        for chain in self.chains.values():
            for lvl in chain:
                if id(lvl) in seen:
                    continue
                seen.add(id(lvl))
                acts["sram_read"] += lvl.reads
                acts["sram_write"] += lvl.writes
                acts["sram_fill_bytes"] += lvl.fill_bytes
                acts["sram_drain_bytes"] += lvl.drain_bytes
        for op, fu in self.compute_map.items():
            acts[op] += fu.per_key.total() if hasattr(fu.per_key, "total") \
                else sum(fu.per_key.values())
        if self.isect is not None:
            acts["isect_step"] += sum(self.isect.steps.values())
        if self.merger is not None:
            acts["merge_elem"] += self.merger.elements
        return dict(acts)


class PerformanceModel(Instrumentation):
    """Top-level sink: demuxes events to per-Einsum models, owns DRAM."""

    def __init__(self, spec: AcceleratorSpec,
                 plans: Dict[str, EinsumPlan],
                 dram_bandwidth_gbs: float = 68.256):
        self.spec = spec
        # one DRAM per design
        dname, bw = "DRAM", dram_bandwidth_gbs
        for topo in spec.arch.topologies.values():
            for comp, _ in topo.all_components():
                if comp.klass == "DRAM":
                    dname = comp.name
                    bw = float(comp.attrs.get("bandwidth", bw))
        self.dram = DRAM(dname, bw)
        shared: Dict[Tuple[str, str, str], StorageLevel] = {}
        self.shared_levels = shared
        self.models: Dict[str, EinsumModel] = {
            name: EinsumModel(spec, plan, spec.binding.get(name), self.dram,
                              shared)
            for name, plan in plans.items()
        }
        # intermediates produced AND consumed inside one fusion block
        # stream on-chip (Sec. 4.3): no DRAM traffic for them
        from .cascade import CascadeDAG, fusion_blocks
        dag = CascadeDAG.from_spec(spec)
        fused: Set[str] = set()
        for block in fusion_blocks(spec, plans):
            names = set(block)
            if len(names) < 2:
                continue
            for name in block:
                e = spec.einsum.einsum_for(name)
                for t in e.input_names:
                    if t in names and dag.is_intermediate(t):
                        fused.add(t)
        for m in self.models.values():
            m.stream_tensors = fused
        self._cur: Optional[EinsumModel] = None
        # DRAM bytes attributed per einsum (for fusion-block accounting)
        self.dram_bytes_per_einsum: Counter = Counter()
        self._dram_mark = 0.0

    # ------------------------------------------------------------------ #
    def begin_einsum(self, einsum: str) -> None:
        self._cur = self.models.get(einsum)
        self._dram_mark = self.dram.total_bytes

    def end_einsum(self, einsum: str) -> None:
        if self._cur is not None:
            self._cur.finish()
        self.dram_bytes_per_einsum[einsum] += \
            self.dram.total_bytes - self._dram_mark
        self._cur = None

    def touch(self, einsum, tensor, rank, path, kind, rw, n=1, unique=None):
        if self._cur is not None:
            self._cur.on_touch(tensor, rank, path, kind, rw, n, unique)

    def advance(self, einsum, rank, n=1):
        # n > 1 (aggregate) epochs with no interleaved touches collapse
        # to one effective eviction; evict_all is idempotent
        if self._cur is not None:
            self._cur.on_advance(rank)

    def iterate(self, einsum, rank, n=1, coord=None):
        if self._cur is not None:
            self._cur.on_iterate(rank, coord, n)

    def compute(self, einsum, op, n=1):
        if self._cur is not None:
            self._cur.on_compute(op, n)

    def isect_step(self, einsum, rank, tensor, n=1):
        if self._cur is not None:
            self._cur.on_isect_step(rank, tensor, n)

    def isect_match(self, einsum, rank, n=1):
        if self._cur is not None:
            self._cur.on_isect_match(rank, n)

    def merge(self, einsum, tensor, elements, lists):
        m = self.models.get(einsum)
        if m is not None:
            m.on_merge(tensor, elements, lists)

    # ------------------------------------------------------------------ #
    def register_exec_tensors(self, einsum: str,
                              tensors: Dict[str, FTensor]) -> None:
        m = self.models.get(einsum)
        if m is not None:
            m.tensors.update(tensors)

    def finalize(self) -> None:
        """End of cascade: write back all dirty on-chip state."""
        if getattr(self, "_finalized", False):
            return
        self._finalized = True
        mark = self.dram.total_bytes
        for lvl in self.shared_levels.values():
            lvl.evict_all()
        # attribute final drains to the last einsum
        if self.models:
            last = list(self.models)[-1]
            self.dram_bytes_per_einsum[last] += self.dram.total_bytes - mark
