"""TeAAL core: the paper's declarative language + simulator generator.

Public API:
    load_spec          -- YAML-shaped dict -> AcceleratorSpec
    CascadeSimulator   -- spec + real tensors -> outputs + Report
    FTensor / Fiber    -- the fibertree abstraction
    Semiring           -- redefinable (+, *) for graph algorithms
"""
from .einsum import Einsum, Semiring, dense_reference, parse_einsum
from .fibertree import Fiber, FTensor
from .generator import CascadeSimulator, SimResult, check_against_dense
from .mapping import MappingResolver
from .metrics import ENERGY_TABLE_PJ, Report, RooflineTerms, roofline
from .spec import AcceleratorSpec, load_spec

__all__ = [
    "Einsum", "Semiring", "dense_reference", "parse_einsum",
    "Fiber", "FTensor", "CascadeSimulator", "SimResult",
    "check_against_dense", "MappingResolver", "ENERGY_TABLE_PJ",
    "Report", "RooflineTerms", "roofline", "AcceleratorSpec", "load_spec",
]
