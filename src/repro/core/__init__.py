"""TeAAL core: the paper's declarative language + simulator generator.

Public API:
    load_spec          -- YAML-shaped dict -> AcceleratorSpec
    CascadeSimulator   -- spec + real tensors -> outputs + Report
    FTensor / Fiber    -- the fibertree abstraction
    CSF                -- columnar compressed-sparse-fiber arrays
    ExecutorBackend    -- pluggable execution engines
                          (python | vector | analytic)
    TensorDensity      -- per-rank occupancy models (analytic engine)
    Semiring           -- redefinable (+, *) for graph algorithms
"""
from .analytic import AnalyticBackend
from .csf import CSF
from .density import TensorDensity
from .einsum import Einsum, Semiring, dense_reference, parse_einsum
from .fibertree import Fiber, FTensor
from .generator import CascadeSimulator, SimResult, check_against_dense
from .iteration import ExecutorBackend, PythonBackend, get_backend
from .mapping import MappingResolver
from .metrics import ENERGY_TABLE_PJ, Report, RooflineTerms, roofline
from .spec import AcceleratorSpec, load_spec
from .vectorized import VectorBackend
from .vplan import VectorPlan, lower as lower_vector_plan

__all__ = [
    "Einsum", "Semiring", "dense_reference", "parse_einsum",
    "Fiber", "FTensor", "CSF", "CascadeSimulator", "SimResult",
    "check_against_dense", "MappingResolver", "ENERGY_TABLE_PJ",
    "Report", "RooflineTerms", "roofline", "AcceleratorSpec", "load_spec",
    "ExecutorBackend", "PythonBackend", "VectorBackend",
    "AnalyticBackend", "TensorDensity", "get_backend",
    "VectorPlan", "lower_vector_plan",
]
