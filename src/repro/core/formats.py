"""Concrete tensor formats (TeAAL Section 4.1.1).

Lowers fibertrees onto concrete per-rank representations described by a
``TensorFormat`` (format type U/C/B, layout SoA/AoS, data widths for
coordinates / payloads / fiber headers).  Provides:

  * byte accounting per touched element (the storage models consume this),
  * whole-tensor / subtree footprints (eager fills, buffer occupancy),
  * reference lowerings to familiar formats (CSR, CSC, COO, bitmap,
    OuterSPACE's array-of-linked-lists) for tests and demos,
  * the algorithmic-minimum traffic used to normalize Figure 9.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .fibertree import Fiber, FTensor
from .spec import FormatSpec, RankFormat, TensorFormat


# ---------------------------------------------------------------------- #
# byte accounting
# ---------------------------------------------------------------------- #
def touch_bytes(fmt: TensorFormat, rank: str, kind: str) -> float:
    """Bytes moved by touching one coordinate/payload at ``rank``."""
    rf = fmt.ranks.get(rank, RankFormat())

    def coord_cost() -> float:
        if rf.format == "U":
            return 0.0                      # positional; nothing stored
        if rf.format == "B":
            return 1.0 / 8.0                # bitmap: one bit per position
        return rf.cbits / 8.0

    if kind == "coord":
        return coord_cost()
    if kind == "payload":
        return rf.pbits / 8.0
    if kind == "elem":
        return coord_cost() + rf.pbits / 8.0
    raise ValueError(kind)


def fiber_header_bytes(fmt: TensorFormat, rank: str) -> float:
    rf = fmt.ranks.get(rank, RankFormat())
    return rf.fhbits / 8.0


def subtree_bytes(ft: FTensor, fmt: TensorFormat, node: Any,
                  depth: int) -> float:
    """Footprint of the subtree rooted at ``node`` (a Fiber at level
    ``depth`` of ``ft``, or a leaf payload)."""
    if not isinstance(node, Fiber):
        return touch_bytes(fmt, ft.ranks[-1], "payload")
    rank = ft.ranks[depth]
    rf = fmt.ranks.get(rank, RankFormat())
    total = rf.fhbits / 8.0
    occupancy = len(node)
    if rf.format == "U":
        shape = ft.rank_shapes.get(rank) or occupancy
        if isinstance(shape, tuple):
            shape = int(np.prod([s or 1 for s in shape]))
        n_pay = shape
        n_coord = 0
    elif rf.format == "B":
        shape = ft.rank_shapes.get(rank) or occupancy
        if isinstance(shape, tuple):
            shape = int(np.prod([s or 1 for s in shape]))
        n_pay = occupancy
        n_coord = 0
        total += shape / 8.0                # bitmap: one bit per position
    else:                                    # C
        n_pay = occupancy
        n_coord = occupancy
    total += n_coord * rf.cbits / 8.0
    if depth == len(ft.ranks) - 1:
        total += n_pay * rf.pbits / 8.0
    else:
        # payloads are fiber references (pbits wide) + children footprints
        total += n_pay * rf.pbits / 8.0
        for _, child in node:
            total += subtree_bytes(ft, fmt, child, depth + 1)
    return total


def tensor_bytes(ft: FTensor, fmt: TensorFormat) -> float:
    return subtree_bytes(ft, fmt, ft.root, 0)


# ---------------------------------------------------------------------- #
# reference lowerings (tests / demos)
# ---------------------------------------------------------------------- #
@dataclass
class CSR:
    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])


def to_csr(ft: FTensor) -> CSR:
    """Lower a 2-rank fibertree (row rank outer) to CSR arrays."""
    assert len(ft.ranks) == 2
    nrows = ft._int_shape(ft.ranks[0])
    indptr = np.zeros(nrows + 1, dtype=np.int64)
    cols: List[int] = []
    vals: List[float] = []
    for r, fiber in ft.root:
        indptr[r + 1] = len(fiber)
        cols.extend(fiber.coords)
        vals.extend(fiber.payloads)
    indptr = np.cumsum(indptr)
    return CSR(indptr, np.asarray(cols, dtype=np.int64),
               np.asarray(vals, dtype=np.float64))


def to_csc(ft: FTensor) -> CSR:
    """CSC = CSR of the rank-swizzled tensor."""
    return to_csr(ft.swizzle(list(reversed(ft.ranks))))


def to_coo(ft: FTensor) -> Tuple[np.ndarray, np.ndarray]:
    """(coords [nnz, ndim], values [nnz]) in rank order."""
    pts, vals = [], []
    for path, v in ft.iter_leaves():
        flat = []
        for c in path:
            flat.extend(c) if isinstance(c, tuple) else flat.append(c)
        pts.append(flat)
        vals.append(v)
    if not pts:
        return (np.zeros((0, len(ft.ranks)), dtype=np.int64),
                np.zeros((0,), dtype=np.float64))
    return np.asarray(pts, dtype=np.int64), np.asarray(vals, dtype=np.float64)


def to_bitmap(ft: FTensor) -> Tuple[np.ndarray, np.ndarray]:
    """SIGMA-style bitmap + packed nonzero values for a 2-rank tensor."""
    dense = ft.to_dense()
    mask = dense != 0
    return mask, dense[mask]


@dataclass
class LinkedLists:
    """OuterSPACE's array-of-linked-lists (Fig. 5c): one list head per
    upper-rank coordinate; each node is a (coord, value, next) record."""
    heads: np.ndarray            # [shape_upper] -> node index or -1
    nodes: List[Tuple[int, float, int]]

    @property
    def nnz(self) -> int:
        return len(self.nodes)


def to_linked_lists(ft: FTensor) -> LinkedLists:
    assert len(ft.ranks) == 2
    n_upper = ft._int_shape(ft.ranks[0])
    heads = np.full(n_upper, -1, dtype=np.int64)
    nodes: List[Tuple[int, float, int]] = []
    for r, fiber in ft.root:
        prev = -1
        for c, v in fiber:
            nodes.append((int(c), float(v), -1))
            idx = len(nodes) - 1
            if prev == -1:
                heads[r] = idx
            else:
                pc, pv, _ = nodes[prev]
                nodes[prev] = (pc, pv, idx)
            prev = idx
    return LinkedLists(heads, nodes)


# ---------------------------------------------------------------------- #
# algorithmic minimum traffic (Fig. 9 normalization)
# ---------------------------------------------------------------------- #
def algorithmic_min_traffic(inputs: Dict[str, FTensor],
                            output: FTensor,
                            fmt: Optional[FormatSpec] = None) -> float:
    """Bytes if every input were read exactly once and the final output
    written exactly once, in the default format of each tensor."""
    fmt = fmt or FormatSpec()
    total = 0.0
    for name, ft in inputs.items():
        total += tensor_bytes(ft, fmt.default(name))
    total += tensor_bytes(output, fmt.default(output.name))
    return total
