"""Instrumentation interface: the simulator generator emits access/compute
events; performance-model components consume them online (TeAAL Sec. 4.3
"trace generation" / "trace consumption" -- we stream rather than
materialize giant trace files, with an optional collector for tests).
"""
from __future__ import annotations

from collections import Counter, defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


class Instrumentation:
    """Event sink. All methods are no-ops; subclasses override.

    Every count-like method takes ``n`` so a vectorized backend can
    report the same actions in aggregate (one call for n events) that
    the Python interpreter reports element-by-element; per-element
    ``path`` context is then unavailable (empty tuple).
    """

    def begin_einsum(self, einsum: str) -> None: ...

    def end_einsum(self, einsum: str) -> None: ...

    # storage: element touch. path = coords root->here, kind 'coord'|'payload'
    # ``unique`` (aggregate emitters only) hints how many *distinct*
    # elements underlie the n accesses, so storage models can estimate
    # residency statistically: None = unknown (legacy aggregate
    # handling), 0 = data already on chip (no cold fills)
    def touch(self, einsum: str, tensor: str, rank: str,
              path: Tuple, kind: str, rw: str, n: int = 1,
              unique: "int | None" = None) -> None: ...

    # loop rank advanced to a new coordinate (epoch marker for buffets)
    def advance(self, einsum: str, rank: str, n: int = 1) -> None: ...

    # sequencer: one coordinate enumerated at this loop rank
    def iterate(self, einsum: str, rank: str, n: int = 1,
                coord=None) -> None: ...

    # compute op executed ('mul'|'add')
    def compute(self, einsum: str, op: str, n: int = 1) -> None: ...

    # intersection: one pointer advance on `tensor` at `rank`
    def isect_step(self, einsum: str, rank: str, tensor: str,
                   n: int = 1) -> None: ...

    def isect_match(self, einsum: str, rank: str, n: int = 1) -> None: ...

    # online rank swizzle: merge `elements` leaves from `lists` sorted runs
    def merge(self, einsum: str, tensor: str, elements: int,
              lists: int) -> None: ...


class NullInstr(Instrumentation):
    pass


@dataclass
class CollectingInstr(Instrumentation):
    """Counts everything; optionally records full touch traces."""
    record_touches: bool = False
    touches: List[Tuple] = field(default_factory=list)
    touch_counts: Counter = field(default_factory=Counter)
    iter_counts: Counter = field(default_factory=Counter)
    compute_counts: Counter = field(default_factory=Counter)
    isect_steps: Counter = field(default_factory=Counter)
    isect_matches: Counter = field(default_factory=Counter)
    advances: Counter = field(default_factory=Counter)
    merges: List[Tuple[str, str, int, int]] = field(default_factory=list)

    def touch(self, einsum, tensor, rank, path, kind, rw, n=1, unique=None):
        self.touch_counts[(einsum, tensor, rank, kind, rw)] += n
        if self.record_touches:
            self.touches.append((einsum, tensor, rank, path, kind, rw))

    def advance(self, einsum, rank, n=1):
        self.advances[(einsum, rank)] += n

    def iterate(self, einsum, rank, n=1, coord=None):
        self.iter_counts[(einsum, rank)] += n

    def compute(self, einsum, op, n=1):
        self.compute_counts[(einsum, op)] += n

    def isect_step(self, einsum, rank, tensor, n=1):
        self.isect_steps[(einsum, rank, tensor)] += n

    def isect_match(self, einsum, rank, n=1):
        self.isect_matches[(einsum, rank)] += n

    def merge(self, einsum, tensor, elements, lists):
        self.merges.append((einsum, tensor, elements, lists))


class RecordingInstr(Instrumentation):
    """Records the event stream verbatim for later replay.

    The basis of the DSE engine's batched evaluation: for design points
    that share a mapping signature (and intersection config), the
    backend's instrumentation stream is a pure function of the workload
    and the lowered plans -- architecture attributes (capacities,
    bandwidths, radices) enter only when the stream is *consumed* by a
    ``PerformanceModel``.  Recording the stream once and replaying it
    into each point's own model therefore reproduces per-point results
    bit-identically while paying the backend walk once per group.

    ``max_events`` bounds memory: past it the recorder stops appending
    and flags ``overflowed`` -- callers must then fall back to
    per-point evaluation (per-element streams from the Python oracle
    can be arbitrarily long; aggregate analytic streams are tiny).
    """

    def __init__(self, max_events: int = 250_000):
        self.max_events = max_events
        self.events: List[Tuple] = []
        self.overflowed = False

    def _rec(self, method: str, *args) -> None:
        if len(self.events) >= self.max_events:
            self.overflowed = True
            return
        self.events.append((method, args))

    def begin_einsum(self, einsum):
        self._rec("begin_einsum", einsum)

    def end_einsum(self, einsum):
        self._rec("end_einsum", einsum)

    def touch(self, einsum, tensor, rank, path, kind, rw, n=1, unique=None):
        self._rec("touch", einsum, tensor, rank, path, kind, rw, n, unique)

    def advance(self, einsum, rank, n=1):
        self._rec("advance", einsum, rank, n)

    def iterate(self, einsum, rank, n=1, coord=None):
        self._rec("iterate", einsum, rank, n, coord)

    def compute(self, einsum, op, n=1):
        self._rec("compute", einsum, op, n)

    def isect_step(self, einsum, rank, tensor, n=1):
        self._rec("isect_step", einsum, rank, tensor, n)

    def isect_match(self, einsum, rank, n=1):
        self._rec("isect_match", einsum, rank, n)

    def merge(self, einsum, tensor, elements, lists):
        self._rec("merge", einsum, tensor, elements, lists)

    def __len__(self) -> int:
        return len(self.events)

    def replay(self, sink: Instrumentation) -> None:
        """Re-emit the recorded stream, in order, into ``sink``."""
        for method, args in self.events:
            getattr(sink, method)(*args)


class TeeInstr(Instrumentation):
    """Fan out events to several sinks."""

    def __init__(self, *sinks: Instrumentation):
        self.sinks = [s for s in sinks if s is not None]

    def begin_einsum(self, einsum):
        for s in self.sinks:
            s.begin_einsum(einsum)

    def end_einsum(self, einsum):
        for s in self.sinks:
            s.end_einsum(einsum)

    def touch(self, *a, **k):
        for s in self.sinks:
            s.touch(*a, **k)

    def advance(self, *a, **k):
        for s in self.sinks:
            s.advance(*a, **k)

    def iterate(self, *a, **k):
        for s in self.sinks:
            s.iterate(*a, **k)

    def compute(self, *a, **k):
        for s in self.sinks:
            s.compute(*a, **k)

    def isect_step(self, *a, **k):
        for s in self.sinks:
            s.isect_step(*a, **k)

    def isect_match(self, *a, **k):
        for s in self.sinks:
            s.isect_match(*a, **k)

    def merge(self, *a):
        for s in self.sinks:
            s.merge(*a)
