"""Einsum-cascade DAG and fusion-block inference (TeAAL Sec. 3.1 / 4.3).

A cascade is a DAG of Einsums connected through intermediate tensors.
Fusion blocks group Einsums that execute as one pipelined phase; TeAAL
infers fusion when (Sec. 4.3):

  1. the Einsums use the same accelerator topology,
  2. the temporal ranks in all loop orders *before the first spatial
     rank* are the same, and
  3. disjoint subsets of the non-storage components are each exclusively
     used by only one Einsum.

Blocks are formed greedily from the first Einsum.  The block structure
feeds the bottleneck analysis in ``metrics``: block time = max over
components; cascade time = sum over blocks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .mapping import EinsumPlan
from .spec import AcceleratorSpec


@dataclass
class CascadeDAG:
    """Producer/consumer structure of the cascade."""
    order: List[str]                          # einsum outputs, program order
    produces: Dict[str, str]                  # tensor -> producing einsum
    consumers: Dict[str, List[str]]           # tensor -> consuming einsums
    intermediates: Set[str]                   # tensors produced & consumed

    @staticmethod
    def from_spec(spec: AcceleratorSpec) -> "CascadeDAG":
        order = [e.output.tensor for e in spec.einsum.expressions]
        produces = {t: t for t in order}
        consumers: Dict[str, List[str]] = {}
        for e in spec.einsum.expressions:
            for t in e.input_names:
                consumers.setdefault(t, []).append(e.output.tensor)
        inter = {t for t in order if t in consumers}
        return CascadeDAG(order, produces, consumers, inter)

    def is_intermediate(self, tensor: str) -> bool:
        return tensor in self.intermediates


def mapping_signature(spec: AcceleratorSpec,
                      params: Optional[Dict[str, int]] = None) -> str:
    """Canonical signature of everything that determines the lowered
    plans and exec-form tensor structure: the einsum cascade, rank
    orders, and per-Einsum mapping directives (with partition sizes),
    plus any symbolic-size params.

    Format / architecture / binding sections are deliberately excluded:
    sweeping them (FiberCache capacity, merger radix as a pure arch
    attribute, DRAM bandwidth, ...) must share plan memoization and
    density-calibration cache entries in the DSE engine.
    """
    parts: List[str] = []
    parts.append("decl:" + repr(sorted(
        (t, tuple(r)) for t, r in spec.einsum.declaration.items())))
    parts.append("expr:" + repr([str(e) for e in spec.einsum.expressions]))
    parts.append("sr:" + spec.einsum.semiring.name)
    parts.append("order:" + repr(sorted(
        (t, tuple(r)) for t, r in spec.mapping.rank_order.items())))
    for name in sorted(spec.mapping.per_einsum):
        em = spec.mapping.per_einsum[name]
        st = em.spacetime
        parts.append(f"{name}:loop={em.loop_order!r}"
                     f":space={st.space if st else None!r}"
                     f":time={st.time if st else None!r}"
                     f":part={sorted((repr(k), [str(d) for d in v]) for k, v in em.partitioning.items())!r}")
    parts.append("params:" + repr(sorted((params or {}).items())))
    return "|".join(parts)


def _temporal_prefix(plan: EinsumPlan) -> Tuple[str, ...]:
    """Loop ranks before the first spatial rank."""
    prefix: List[str] = []
    space = set(plan.space_ranks)
    for ri in plan.loop_order:
        if ri.name in space:
            break
        prefix.append(ri.name)
    return tuple(prefix)


def _nonstorage_components(spec: AcceleratorSpec, name: str) -> Set[str]:
    """Components (other than buffers/DRAM) bound to einsum ``name``."""
    b = spec.binding.get(name)
    used: Set[str] = {cb.component for cb in b.compute}
    return used


def fusion_blocks(spec: AcceleratorSpec,
                  plans: Dict[str, EinsumPlan]) -> List[List[str]]:
    """Greedy block formation per the three criteria."""
    order = [e.output.tensor for e in spec.einsum.expressions]
    blocks: List[List[str]] = []
    cur: List[str] = []

    def fusable(a: str, b: str) -> bool:
        ba, bb = spec.binding.get(a), spec.binding.get(b)
        if ba.topology != bb.topology:
            return False                                   # criterion 1
        if _temporal_prefix(plans[a]) != _temporal_prefix(plans[b]):
            return False                                   # criterion 2
        if _nonstorage_components(spec, a) & _nonstorage_components(spec, b):
            return False                                   # criterion 3
        return True

    for name in order:
        if not cur:
            cur = [name]
            continue
        if all(fusable(prev, name) for prev in cur):
            cur.append(name)
        else:
            blocks.append(cur)
            cur = [name]
    if cur:
        blocks.append(cur)
    return blocks
