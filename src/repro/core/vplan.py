"""VectorPlan: the typed per-rank IR the columnar ``VectorBackend`` runs.

``lower(plan, ...)`` turns an ``EinsumPlan`` into a ``VectorPlan`` -- a
per-loop-rank list of typed co-iteration ops plus a ``Reduce`` describing
output construction:

  * ``Drive``           enumerate one tensor level's fibers
  * ``Intersect``       co-iterate factors of a product / ``take()``
                        (two-finger or leader-follower, any arity,
                        left-nested pairwise exactly like the
                        interpreter's ``_intersect_many``)
  * ``UnionK``          k-ary sorted merge across additive terms
  * ``DenseEnumerate``  driverless (dense) rank: iterate the index
                        var's full coordinate range
  * ``Lookup``          catch-up descent of a non-driving tensor level
                        by bound coordinate (exact match, or
                        partition-upper range positioning)
  * ``Reduce``          leaf evaluation + segmented reduction into the
                        output, with per-rank coordinate sources
                        (loop-captured or recovered from index-var
                        bindings for leaf-bound output ranks)

``_Unsupported`` is raised **only here**, never mid-execution: if
``lower`` returns, the vector path can run the plan.  Affine and
constant index maps lower onto ``Lookup`` (coordinate translation on
the probe stream), any semiring with vectorized forms parameterizes
``Reduce`` and leaf compute, and update-in-place outputs seed the
reduction from the existing tensor's points.  What remains outside the
IR -- bare copies, sums of non-atomic or rank-unaligned terms, affine
*output* indices, interpreter-only semirings -- falls back to the
interpreter per Einsum.

``prepare_csf_inputs`` is the pre-pass for the columnar entry point
(``VectorBackend.execute_csf``): it applies the Einsum's Section-3.2
transform recipe (swizzle / flatten / uniform partitioning, recorded on
``EinsumPlan.transform_recipe``) directly on CSF arrays, so
SIGMA-style flattened and OuterSPACE-style partitioned workloads run
at scale without ever materializing per-element fibertrees.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .einsum import AffineIndex, BinOp, Semiring, Take, TensorAccess
from .iteration import EinsumExecutor
from .mapping import EinsumPlan
from .trace import NullInstr


class _Unsupported(Exception):
    """Plan shape the vector path does not cover (-> fallback).

    ``einsum`` (when known) names the output tensor whose plan failed
    to lower, so batched runs and sweep errors can say *which* Einsum
    forced the oracle rather than just why."""

    def __init__(self, reason: str, einsum: Optional[str] = None):
        self.reason = reason
        self.einsum = einsum
        super().__init__(
            f"{einsum}: {reason}" if einsum else reason)


# ---------------------------------------------------------------------- #
# IR node types
# ---------------------------------------------------------------------- #
@dataclass(frozen=True)
class Drive:
    """Enumerate the fibers of one tensor level."""
    tensor: str
    depth: int
    leaf: bool                       # deepest level: matches touch payloads


@dataclass(frozen=True)
class Intersect:
    """Product / take() co-iteration; executed as a left-nested chain of
    pairwise merges (``((c0 ^ c1) ^ c2) ...``), mirroring the
    interpreter.  ``leader_follower`` applies to Drive/Drive pairs only
    (deeper pairs two-finger), again mirroring the interpreter."""
    children: Tuple = ()
    strategy: str = "two_finger"
    leader: Optional[str] = None


@dataclass(frozen=True)
class UnionK:
    """k-ary sorted union across additive terms."""
    children: Tuple = ()


@dataclass(frozen=True)
class DenseEnumerate:
    """Driverless rank: iterate ``range(shape)`` of the index var."""
    var: str
    shape: int


@dataclass(frozen=True)
class Lookup:
    """Catch-up descent of one non-driving tensor level, probed by the
    coordinate computed from index-var bindings.

    ``index`` carries the affine map (coordinate shift/scale) for
    non-bare accesses -- the probe is ``const + sum(coeff * var_col)``
    over captured frontier columns (im2col-style windowing for conv's
    ``I[b, c, p+r, q+s]``).  ``index is None`` means a bare/derived
    probe built by stacking the level's var columns."""
    tensor: str
    depth: int
    rank: str
    vars: Tuple[str, ...]
    partition_start: bool            # position-by-range (upper partition)
    leaf: bool
    essential: bool                  # miss kills the branch
    index: Optional[AffineIndex] = None


@dataclass
class LevelIR:
    """One loop rank: its co-iteration op, binding info, the output
    descend depth (if an output rank sits here), and the catch-up
    lookups scheduled right after its bindings land."""
    rank: str
    width: int
    binds: bool
    vars: Tuple[str, ...]
    out_depth: Optional[int]
    op: object                       # Drive | Intersect | UnionK | DenseEnumerate
    lookups: List[Lookup] = field(default_factory=list)


@dataclass
class Reduce:
    """Output construction: per exec-order output rank, where its
    coordinates come from -- ("level", li) for loop-matched ranks,
    ("vars", vars) for leaf-bound ranks recovered from bindings.

    The segmented reduction over the fused-key sort folds contributions
    with ``semiring.add`` (sequential order, bit-exact against the
    interpreter); ``has_initial`` seeds the groups from the existing
    output tensor's points (update-in-place)."""
    out_ranks: List[str]
    sources: List[Tuple]
    widths: List[int]
    upper_ranks: Set[str]
    semiring: Semiring = field(default_factory=Semiring.arithmetic)
    has_initial: bool = False
    #: leading sources that are loop levels 0, 1, 2, ... in order (and
    #: all above the innermost level).  The frontier is lexicographically
    #: sorted by level coordinates, so these columns arrive
    #: non-decreasing and batched execution can group them with one
    #: boundary scan instead of a sort (``vectorized._finalize_fused``).
    prefix_sources: int = 0


@dataclass(frozen=True)
class LeafFuse:
    """Innermost-level fusion descriptor: the last loop level is a
    single-tensor ``Drive`` of ``driven``'s leaf with no lookups, the
    expression is a two-factor arithmetic product, and ``other``'s leaf
    value is already positioned on the frontier.  Execution can then
    batch the whole frontier x leaf-fiber expansion into one wide
    gather-multiply-bincount pass (``vectorized._finalize_fused``)
    instead of materializing the innermost frontier and sorting it --
    runtime still falls back to the generic path when the dense group
    domain is inadmissible for the chunk at hand."""
    driven: str                      # tensor enumerated at the last level
    other: str                       # the co-factor, at its leaf already


@dataclass
class VectorPlan:
    name: str
    expr: object
    accs: List[TensorAccess]
    levels: List[LevelIR]
    reduce: Reduce
    essential: Set[str]
    leaf_depth: Dict[str, int]
    #: index vars whose bound values must be captured as frontier
    #: columns (lookup probes + leaf-bound output coordinates):
    #: var -> (loop level, coordinate column at that level)
    capture_vars: Dict[str, Tuple[int, int]]
    semiring: Semiring = field(default_factory=Semiring.arithmetic)
    #: constant-index descents resolvable before the first loop level
    #: (e.g. the FFT cascade's P[0, k0, ...] root coordinate)
    pre_lookups: List[Lookup] = field(default_factory=list)
    #: set when the innermost level admits batched leaf fusion
    leaf_fuse: Optional[LeafFuse] = None


# ---------------------------------------------------------------------- #
# expression shape validation
# ---------------------------------------------------------------------- #
def _walk_expr(expr, accs: List[TensorAccess], has_sum: List[bool]) -> None:
    if isinstance(expr, TensorAccess):
        # affine / constant indices lower onto Lookup probes; nothing to
        # reject here (unschedulable maps raise during lookup placement)
        accs.append(expr)
        return
    if isinstance(expr, Take):
        for a in expr.args:
            _walk_expr(a, accs, has_sum)
        return
    if isinstance(expr, BinOp):
        if expr.op in "+-":
            has_sum[0] = True
        elif expr.op != "*":
            raise _Unsupported(f"operator {expr.op!r}")
        _walk_expr(expr.lhs, accs, has_sum)
        _walk_expr(expr.rhs, accs, has_sum)
        return
    raise _Unsupported(f"expression node {expr!r}")


def _sum_terms(expr) -> List:
    """Flatten an additive expression into its terms (each term must be
    a plain access for the vector path)."""
    if isinstance(expr, BinOp) and expr.op in "+-":
        return _sum_terms(expr.lhs) + _sum_terms(expr.rhs)
    return [expr]


# ---------------------------------------------------------------------- #
# lowering
# ---------------------------------------------------------------------- #
def _build_op(expr, active: Set[str], leaf_depth: Dict[str, int],
              depth_at: Dict[str, int], essential: Set[str],
              strategy: str, leader: Optional[str]):
    """Co-iteration op tree for one level, mirroring the interpreter's
    ``_build_coiter``: intersection across product/take factors, union
    across additive terms; inactive operands drop out."""
    if isinstance(expr, TensorAccess):
        t = expr.tensor
        if t not in active:
            return None
        d = depth_at[t]
        return Drive(t, d, d == leaf_depth[t])
    if isinstance(expr, Take):
        children = [_build_op(a, active, leaf_depth, depth_at, essential,
                              strategy, leader) for a in expr.args]
        children = [c for c in children if c is not None]
        return _isect_many(children, essential, strategy, leader)
    if isinstance(expr, BinOp):
        lhs = _build_op(expr.lhs, active, leaf_depth, depth_at, essential,
                        strategy, leader)
        rhs = _build_op(expr.rhs, active, leaf_depth, depth_at, essential,
                        strategy, leader)
        if expr.op == "*":
            children = [c for c in (lhs, rhs) if c is not None]
            return _isect_many(children, essential, strategy, leader)
        if lhs is None:
            return rhs
        if rhs is None:
            return lhs
        lparts = lhs.children if isinstance(lhs, UnionK) else (lhs,)
        rparts = rhs.children if isinstance(rhs, UnionK) else (rhs,)
        return UnionK(lparts + rparts)
    return None


def _op_tensors(op) -> Set[str]:
    if isinstance(op, Drive):
        return {op.tensor}
    out: Set[str] = set()
    for c in getattr(op, "children", ()):
        out |= _op_tensors(c)
    return out


def _isect_many(children: List, essential: Set[str], strategy: str,
                leader: Optional[str]):
    if not children:
        return None
    if len(children) == 1:
        return children[0]
    # an absent operand under an intersection would degrade it to the
    # remaining factors (interpreter semantics); that cannot happen when
    # every factor annihilates the expression (essential), which the
    # plain product / take() cascades all satisfy
    for c in children:
        if not _op_tensors(c) <= essential:
            raise _Unsupported("intersection over possibly-absent operands")
    return Intersect(tuple(children), strategy, leader)


def lower(plan: EinsumPlan, var_shapes: Dict[str, int],
          semiring: Optional[Semiring] = None,
          out_initial=None, isect_strategy: str = "two_finger",
          isect_leader: Optional[str] = None) -> VectorPlan:
    """EinsumPlan -> VectorPlan, or raise ``_Unsupported`` (tagged with
    the Einsum's output name, so multi-Einsum runs report which plan
    declined the vector path)."""
    try:
        return _lower(plan, var_shapes, semiring, out_initial,
                      isect_strategy, isect_leader)
    except _Unsupported as exc:
        if exc.einsum is None:
            raise _Unsupported(exc.reason, plan.output) from None
        raise


def _lower(plan: EinsumPlan, var_shapes: Dict[str, int],
           semiring: Optional[Semiring] = None,
           out_initial=None, isect_strategy: str = "two_finger",
           isect_leader: Optional[str] = None) -> VectorPlan:
    semiring = semiring or Semiring.arithmetic()
    if not semiring.has_vector_forms:
        raise _Unsupported(
            f"semiring {semiring.name} has no vectorized forms")
    einsum = plan.einsum
    if not einsum.output.indices:
        raise _Unsupported("bare copy")
    # constant output indices (E[0, k0]) ride the loop-rank name match
    # exactly like the interpreter; true affine output maps do not
    if any(ix.terms and not ix.is_bare for ix in einsum.output.indices):
        raise _Unsupported("affine output indices")

    accs: List[TensorAccess] = []
    has_sum = [False]
    _walk_expr(einsum.expr, accs, has_sum)
    if not accs:
        raise _Unsupported("no tensor operands")
    if has_sum[0]:
        for term in _sum_terms(einsum.expr):
            if not isinstance(term, TensorAccess):
                raise _Unsupported("sum of non-atomic terms")

    # the interpreter's own analysis is the single source of truth for
    # drive/lookup level assignment and output descent
    try:
        ex = EinsumExecutor(plan, {}, var_shapes, semiring=semiring,
                            instr=NullInstr(),
                            isect_strategy=isect_strategy,
                            isect_leader=isect_leader)
    except (ValueError, AssertionError) as e:
        raise _Unsupported(str(e))

    loop = plan.loop_order
    leaf_depth = {a.tensor: len(plan.tensors[a.tensor].exec_order) - 1
                  for a in accs}
    order = [a.tensor for a in accs]

    if has_sum[0]:
        all_levels = frozenset(range(len(loop)))
        for t in order:
            if frozenset(ex.drive[t]) != all_levels:
                raise _Unsupported("summands with unaligned ranks")

    # loop level at which each var binds
    var_bound_at: Dict[str, int] = {}
    for li, ri in enumerate(loop):
        if ri.binds:
            for v in ri.vars:
                var_bound_at[v] = li

    # ---- per-level ops
    levels: List[LevelIR] = []
    for li, ri in enumerate(loop):
        active = {t for t in order if li in ex.drive[t]}
        depth_at = {t: ex.drive[t][li] for t in active}
        op = _build_op(einsum.expr, active, leaf_depth, depth_at,
                       ex._essential, isect_strategy, isect_leader)
        if op is None:
            if ri.flattened:
                raise _Unsupported(f"driverless flattened rank {ri.name}")
            var = ri.vars[0]
            shape = var_shapes.get(var)
            if shape is None:
                raise _Unsupported(f"unknown shape for dense rank {ri.name}")
            op = DenseEnumerate(var, int(shape))
        levels.append(LevelIR(rank=ri.name, width=len(ri.vars),
                              binds=ri.binds, vars=ri.vars,
                              out_depth=ex.out_descend.get(li), op=op))

    # ---- catch-up lookups: schedule every non-driving tensor level at
    # the first binding loop level where its coordinate is computable
    # and its parent level has been descended.  Affine/constant access
    # indices carry their map onto the Lookup (probe translation);
    # constant-only levels whose parents are all pre-descended resolve
    # before the loop entirely (pre_lookups).
    acc_of = {a.tensor: a for a in accs}
    pre_lookups: List[Lookup] = []
    for t in order:
        tp = plan.tensors[t]
        drive = ex.drive[t]
        depth_level: Dict[int, int] = {}     # depth -> loop level available
        drive_depths = set(drive.values())
        next_drive_after = sorted(drive.items())
        for d in range(len(tp.exec_order)):
            if d in drive_depths:
                lv = next(l for l, dd in drive.items() if dd == d)
                depth_level[d] = lv
                continue
            rank = tp.exec_order[d]
            idx = ex._level_index(acc_of[t], tp, d)
            if idx is not None and not idx.is_bare:
                vars_ = idx.vars
            else:
                idx = None             # bare/derived level: stack var cols
                vars_ = ex._level_vars(None, tp, d, rank)
                if not vars_:
                    raise _Unsupported(
                        f"{t}: lookup level {rank} binds no vars")
            if any(v not in var_bound_at for v in vars_):
                raise _Unsupported(f"{t}: unbound lookup level {rank}")
            need = max((var_bound_at[v] for v in vars_), default=-1)
            prior = depth_level.get(d - 1, -1) if d > 0 else -1
            lv = max(need, prior)
            # catch-up runs only after binding levels (lv == -1: all
            # probe inputs constant, descend before the first level)
            while 0 <= lv < len(loop) and not loop[lv].binds:
                lv += 1
            if lv >= len(loop):
                raise _Unsupported(f"{t}: no binding level for {rank}")
            nxt = next((l for l, dd in next_drive_after if dd > d), None)
            if nxt is not None and lv >= nxt:
                raise _Unsupported(
                    f"{t}: lookup level {rank} resolves after its next "
                    f"driving level")
            depth_level[d] = lv
            # partition-created upper levels position by range; the
            # plan's created_ranks map is authoritative (a *declared*
            # rank whose name happens to end in a digit is exact-match)
            part = plan.created_ranks.get(rank) == "upper"
            if part and idx is not None:
                raise _Unsupported(
                    f"{t}: affine index on partition rank {rank}")
            lk = Lookup(
                tensor=t, depth=d, rank=rank, vars=tuple(vars_),
                partition_start=part, leaf=(d == leaf_depth[t]),
                essential=(t in ex._essential), index=idx)
            if lv < 0:
                pre_lookups.append(lk)
            else:
                levels[lv].lookups.append(lk)

    # every lookup var and leaf-bound output var must be capturable
    out_ranks = list(plan.tensors[plan.output].exec_order)
    matched = {}
    for li, lvl in enumerate(levels):
        if lvl.out_depth is not None:
            matched[lvl.out_depth] = li
    sources: List[Tuple] = []
    widths: List[int] = []
    needed_vars: Set[str] = set()
    for d, r in enumerate(out_ranks):
        if d in matched:
            sources.append(("level", matched[d]))
            widths.append(levels[matched[d]].width)
        else:
            vars_ = ex._rank_vars(r)
            sources.append(("vars", tuple(vars_)))
            widths.append(len(vars_))
            needed_vars.update(vars_)
    for lvl in levels:
        for lk in lvl.lookups:
            needed_vars.update(lk.vars)

    capture_vars: Dict[str, Tuple[int, int]] = {}
    for li, ri in enumerate(loop):
        if ri.binds:
            for col, v in enumerate(ri.vars):
                if v in needed_vars and v not in capture_vars:
                    capture_vars[v] = (li, col)
    missing = needed_vars - set(capture_vars)
    if missing:
        raise _Unsupported(f"uncapturable index vars {sorted(missing)}")

    if out_initial is not None and list(out_initial.ranks) != out_ranks:
        raise _Unsupported(
            f"update-in-place output not in execution form "
            f"({list(out_initial.ranks)} vs {out_ranks})")

    # sorted-prefix run length: leading output sources that are loop
    # levels 0, 1, 2, ... in order arrive lexicographically sorted on
    # the frontier (levels above the innermost one only -- the
    # innermost level's columns are per-element, not per-item)
    last_li = len(levels) - 1
    prefix_sources = 0
    for src in sources:
        if src[0] == "level" and src[1] == prefix_sources \
                and src[1] < last_li:
            prefix_sources += 1
        else:
            break

    # innermost-level fusion: a lone leaf Drive under a two-factor
    # arithmetic product lets execution batch the frontier x leaf-fiber
    # expansion into one wide gather-multiply-bincount pass
    leaf_fuse = None
    lvl_last = levels[-1]
    if (len(levels) >= 2 and isinstance(lvl_last.op, Drive)
            and lvl_last.op.leaf and not lvl_last.lookups
            and semiring.mul_vec is np.multiply
            and semiring.add_vec is np.add
            and out_initial is None
            and isinstance(einsum.expr, BinOp) and einsum.expr.op == "*"
            and isinstance(einsum.expr.lhs, TensorAccess)
            and isinstance(einsum.expr.rhs, TensorAccess)):
        factors = {einsum.expr.lhs.tensor, einsum.expr.rhs.tensor}
        drv = lvl_last.op.tensor
        if drv in factors and len(factors) == 2:
            leaf_fuse = LeafFuse(driven=drv, other=(factors - {drv}).pop())

    red = Reduce(out_ranks=out_ranks, sources=sources, widths=widths,
                 upper_ranks={r for r in out_ranks
                              if plan.created_ranks.get(r) == "upper"},
                 semiring=semiring,
                 has_initial=out_initial is not None,
                 prefix_sources=prefix_sources)
    return VectorPlan(name=plan.output, expr=einsum.expr, accs=accs,
                      levels=levels, reduce=red, essential=set(ex._essential),
                      leaf_depth=leaf_depth, capture_vars=capture_vars,
                      semiring=semiring, pre_lookups=pre_lookups,
                      leaf_fuse=leaf_fuse)


# ---------------------------------------------------------------------- #
# pre-pass: Section-3.2 transforms on CSF arrays
# ---------------------------------------------------------------------- #
def prepare_csf_inputs(plan: EinsumPlan, tensors: Dict) -> Dict:
    """Apply the Einsum's recorded transform recipe (flatten / uniform
    partitioning / concordant swizzle) to raw CSF inputs, returning
    execution-form CSFs.  Mirrors ``MappingResolver.transform_tensor``
    but stays columnar end-to-end; leader-follower occupancy adoption
    (dynamic per-fiber boundaries) is not expressible on arrays and
    raises ``_Unsupported``."""
    out: Dict = {}
    for name, cur in tensors.items():
        tp = plan.tensors.get(name)
        if tp is None:
            out[name] = cur
            continue
        for step in plan.transform_recipe.get(name, ()):
            if step[0] == "flatten":
                key = step[1]
                if not all(r in cur.ranks for r in key):
                    continue
                others = [r for r in cur.ranks if r not in key]
                idx = min(cur.ranks.index(r) for r in key)
                new_order = others[:idx] + list(key) + others[idx:]
                if new_order != cur.ranks:
                    cur = cur.swizzle(new_order)
                acc = key[0]
                for r in key[1:]:
                    cur = cur.flatten_ranks(acc, r)
                    acc = acc + r
            else:
                _, key, dirs = step
                if key not in cur.ranks:
                    continue
                seg = key
                produced: List[str] = []
                for kind, size, leader in dirs:
                    if kind == "occupancy" and leader not in (None, name):
                        raise _Unsupported(
                            f"{name}: leader-follower occupancy adoption "
                            f"(leader {leader}) needs the fibertree path")
                    cur = (cur.partition_uniform_shape(seg, size)
                           if kind == "shape"
                           else cur.partition_uniform_occupancy(seg, size))
                    produced.append(seg + "1")
                    seg = seg + "0"
                final = [f"{key}{i}" for i in range(len(dirs), 0, -1)] \
                    + [f"{key}0"]
                cur = cur.rename_ranks(dict(zip(produced + [seg], final)))
        if list(cur.ranks) != list(tp.exec_order):
            cur = cur.swizzle(tp.exec_order)
        out[name] = cur
    return out
