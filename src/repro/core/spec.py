"""TeAAL declarative specification (Sections 3-4).

Five sub-specifications:
  * einsum      -- declaration (tensor ranks) + expressions (the cascade)
  * mapping     -- rank-order, partitioning, loop-order, spacetime
  * format      -- per-tensor, per-config concrete fiber formats (Sec. 4.1.1)
  * architecture-- topology tree of hardware components (Sec. 4.1.2)
  * binding     -- data/compute placement onto components (Sec. 4.1.3)

Specs are plain dataclasses, loadable from YAML-shaped dicts that mirror
the paper's Figures 3, 5 and 8 syntax.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .einsum import Einsum, Semiring, parse_einsum


class SpecError(ValueError):
    """A malformed or inconsistent accelerator spec.

    Carries the offending ``accelerator`` name, spec ``section``
    (einsum / mapping / format / architecture / binding), ``field``
    (the rank, tensor, component, or einsum the error anchors to) and,
    for parse failures, the raw ``directive`` text -- so a zoo-wide
    sweep reports *which* spec broke, not just that one did."""

    def __init__(self, message: str, *,
                 accelerator: Optional[str] = None,
                 section: Optional[str] = None,
                 field: Optional[str] = None,
                 directive: Optional[str] = None):
        self.accelerator = accelerator
        self.section = section
        self.field = field
        self.directive = directive
        ctx = [p for p in (accelerator, section, field) if p]
        super().__init__(
            f"[{'/'.join(ctx)}] {message}" if ctx else message)

    def with_accelerator(self, name: str) -> "SpecError":
        return SpecError(self.args[0].split("] ", 1)[-1],
                         accelerator=name, section=self.section,
                         field=self.field, directive=self.directive)


# ---------------------------------------------------------------------- #
# partitioning directives
# ---------------------------------------------------------------------- #


@dataclass(frozen=True)
class UniformShape:
    size: Union[int, str]          # int or symbolic (e.g. 'K0' in ExTensor)

    def __str__(self) -> str:
        return f"uniform_shape({self.size})"


@dataclass(frozen=True)
class UniformOccupancy:
    leader: str                    # leader tensor name
    size: int

    def __str__(self) -> str:
        return f"uniform_occupancy({self.leader}.{self.size})"


@dataclass(frozen=True)
class Flatten:
    def __str__(self) -> str:
        return "flatten()"


Directive = Union[UniformShape, UniformOccupancy, Flatten]

_DIR_RE = re.compile(
    r"(?:uniform_shape\((?P<shape>[A-Za-z_0-9]+)\)"
    r"|uniform_occupancy\((?P<lead>[A-Za-z_0-9]+)\.(?P<occ>\d+)\)"
    r"|(?P<flat>flatten\(\)))")


def parse_directive(text: str, *, field: Optional[str] = None,
                    accelerator: Optional[str] = None) -> Directive:
    m = _DIR_RE.fullmatch(text.strip())
    if not m:
        raise SpecError(f"bad partitioning directive: {text!r}",
                        accelerator=accelerator, section="mapping",
                        field=field, directive=text)
    if m.group("flat"):
        return Flatten()
    if m.group("shape") is not None:
        s = m.group("shape")
        return UniformShape(int(s) if s.isdigit() else s)
    return UniformOccupancy(m.group("lead"), int(m.group("occ")))


# ---------------------------------------------------------------------- #
# mapping spec
# ---------------------------------------------------------------------- #
@dataclass
class SpaceTime:
    space: List[str] = field(default_factory=list)
    time: List[str] = field(default_factory=list)


@dataclass
class EinsumMapping:
    """Mapping attributes of a single Einsum in the cascade."""
    loop_order: Optional[List[str]] = None
    spacetime: Optional[SpaceTime] = None
    # rank -> directive list, applied top-down.  Keys may be tuples of
    # ranks, e.g. ('K', 'M') for flatten, or partitioned names ('KM').
    partitioning: Dict[Union[str, Tuple[str, ...]], List[Directive]] = \
        field(default_factory=dict)


@dataclass
class MappingSpec:
    rank_order: Dict[str, List[str]] = field(default_factory=dict)
    per_einsum: Dict[str, EinsumMapping] = field(default_factory=dict)

    def einsum_mapping(self, out_name: str) -> EinsumMapping:
        return self.per_einsum.get(out_name, EinsumMapping())


# ---------------------------------------------------------------------- #
# einsum spec
# ---------------------------------------------------------------------- #
@dataclass
class EinsumSpec:
    declaration: Dict[str, List[str]]
    expressions: List[Einsum]
    semiring: Semiring = field(default_factory=Semiring.arithmetic)

    @property
    def cascade_outputs(self) -> List[str]:
        return [e.output.tensor for e in self.expressions]

    def einsum_for(self, out_name: str) -> Einsum:
        for e in self.expressions:
            if e.output.tensor == out_name:
                return e
        raise SpecError(
            f"no Einsum produces {out_name!r} "
            f"(cascade outputs: {self.cascade_outputs})",
            section="einsum", field=out_name)


# ---------------------------------------------------------------------- #
# format spec (Sec. 4.1.1)
# ---------------------------------------------------------------------- #
@dataclass
class RankFormat:
    """U (uncompressed), C (compressed), or B (coords U / payloads C)."""
    format: str = "C"                # 'U' | 'C' | 'B'
    layout: str = "separate"         # 'separate' (SoA) | 'interleaved' (AoS)
    cbits: int = 32
    pbits: int = 32
    fhbits: int = 0                  # fiber-header bits (e.g. list pointers)

    def coord_bytes(self) -> float:
        return self.cbits / 8.0

    def payload_bytes(self) -> float:
        return self.pbits / 8.0


@dataclass
class TensorFormat:
    """One named concrete configuration of a tensor (e.g. 'LinkedLists')."""
    config: str
    ranks: Dict[str, RankFormat]

    def fiber_bytes(self, rank: str, occupancy: int, shape: int) -> float:
        """Footprint of one fiber at ``rank``."""
        f = self.ranks[rank]
        n_coords = 0 if f.format == "U" else occupancy
        n_pay = shape if f.format in ("U", "B") else occupancy
        if f.format == "B":
            n_coords = 0
        return (n_coords * f.cbits + n_pay * f.pbits + f.fhbits) / 8.0


@dataclass
class FormatSpec:
    # tensor -> config name -> TensorFormat
    tensors: Dict[str, Dict[str, TensorFormat]] = field(default_factory=dict)

    def get(self, tensor: str, config: str) -> TensorFormat:
        return self.tensors[tensor][config]

    def default(self, tensor: str) -> TensorFormat:
        cfgs = self.tensors.get(tensor)
        if not cfgs:
            return TensorFormat("default", {})
        return next(iter(cfgs.values()))


# ---------------------------------------------------------------------- #
# architecture spec (Sec. 4.1.2, Table 3)
# ---------------------------------------------------------------------- #
@dataclass
class Component:
    name: str
    klass: str                      # DRAM | Buffer | Intersection | Merger
    #                               | Sequencer | Compute
    attrs: Dict[str, Any] = field(default_factory=dict)


@dataclass
class ArchLevel:
    name: str
    num: int = 1                    # instances of this level (spatial fanout)
    local: List[Component] = field(default_factory=list)
    subtree: List["ArchLevel"] = field(default_factory=list)

    def find(self, comp_name: str, multiplier: int = 1
             ) -> Optional[Tuple[Component, int]]:
        """Return (component, total instance count across the fanout)."""
        m = multiplier * self.num
        for c in self.local:
            if c.name == comp_name:
                return c, m
        for sub in self.subtree:
            r = sub.find(comp_name, m)
            if r:
                return r
        return None

    def all_components(self, multiplier: int = 1
                       ) -> List[Tuple[Component, int]]:
        m = multiplier * self.num
        out = [(c, m) for c in self.local]
        for sub in self.subtree:
            out.extend(sub.all_components(m))
        return out


@dataclass
class ArchSpec:
    # topology name -> root level; designs can reconfigure per Einsum
    topologies: Dict[str, ArchLevel] = field(default_factory=dict)
    clock_ghz: float = 1.0

    def find(self, topology: str, comp: str) -> Tuple[Component, int]:
        root = self.topologies.get(topology)
        if root is None:
            raise SpecError(
                f"unknown topology {topology!r} "
                f"(have: {sorted(self.topologies)})",
                section="architecture", field=topology)
        r = root.find(comp)
        if not r:
            raise SpecError(
                f"component {comp!r} not in topology {topology!r}",
                section="architecture", field=comp)
        return r


# ---------------------------------------------------------------------- #
# binding spec (Sec. 4.1.3)
# ---------------------------------------------------------------------- #
@dataclass
class StorageBinding:
    component: str
    tensor: str
    rank: str
    type: str = "elem"              # 'coord' | 'payload' | 'elem'
    config: str = "default"
    style: str = "lazy"             # 'lazy' | 'eager' (whole subtree)
    evict_on: Optional[str] = None  # required for buffets


@dataclass
class ComputeBinding:
    component: str
    op: str                          # 'mul' | 'add'


@dataclass
class EinsumBinding:
    topology: str = "main"
    storage: List[StorageBinding] = field(default_factory=list)
    compute: List[ComputeBinding] = field(default_factory=list)


@dataclass
class BindingSpec:
    per_einsum: Dict[str, EinsumBinding] = field(default_factory=dict)

    def get(self, out_name: str) -> EinsumBinding:
        return self.per_einsum.get(out_name, EinsumBinding())


# ---------------------------------------------------------------------- #
# the full accelerator spec
# ---------------------------------------------------------------------- #
@dataclass
class AcceleratorSpec:
    name: str
    einsum: EinsumSpec
    mapping: MappingSpec
    format: FormatSpec = field(default_factory=FormatSpec)
    arch: ArchSpec = field(default_factory=ArchSpec)
    binding: BindingSpec = field(default_factory=BindingSpec)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "AcceleratorSpec":
        return load_spec(d)


# ---------------------------------------------------------------------- #
# YAML-shaped dict loader (mirrors the paper's Figure 3 syntax)
# ---------------------------------------------------------------------- #
def _parse_partitioning(d: Dict[str, Any]
                        ) -> Dict[Union[str, Tuple[str, ...]], List[Directive]]:
    out: Dict[Union[str, Tuple[str, ...]], List[Directive]] = {}
    for key, dirs in (d or {}).items():
        if isinstance(key, str) and key.startswith("("):
            ranks = tuple(r.strip() for r in key.strip("()").split(","))
            key2: Union[str, Tuple[str, ...]] = ranks
        elif isinstance(key, tuple):
            key2 = key
        else:
            key2 = key
        out[key2] = [parse_directive(t, field=str(key))
                     if isinstance(t, str) else t
                     for t in dirs]
    return out


def load_spec(d: Dict[str, Any], name: str = "design") -> AcceleratorSpec:
    """Build an AcceleratorSpec from a dict shaped like the paper's
    YAML.  Spec errors surface as :class:`SpecError` tagged with the
    accelerator's name."""
    try:
        return _load_spec(d, name)
    except SpecError as exc:
        if exc.accelerator is None:
            raise exc.with_accelerator(d.get("name", name)) from None
        raise


def _load_spec(d: Dict[str, Any], name: str) -> AcceleratorSpec:
    es = d["einsum"]
    einsum_spec = EinsumSpec(
        declaration={t: list(r) for t, r in es["declaration"].items()},
        expressions=[parse_einsum(x) for x in es["expressions"]],
        semiring=es.get("semiring", Semiring.arithmetic()),
    )

    mp = d.get("mapping", {})
    per_einsum: Dict[str, EinsumMapping] = {}
    names = set(einsum_spec.cascade_outputs)
    part = mp.get("partitioning", {}) or {}
    loops = mp.get("loop-order", {}) or {}
    st = mp.get("spacetime", {}) or {}
    for out_name in names:
        em = EinsumMapping()
        if out_name in loops:
            em.loop_order = list(loops[out_name])
        if out_name in st:
            em.spacetime = SpaceTime(space=list(st[out_name].get("space", [])),
                                     time=list(st[out_name].get("time", [])))
        p = part.get(out_name)
        if p is None and len(names) == 1:
            p = part if any(not isinstance(v, dict) for v in part.values()) \
                else None
        if p:
            em.partitioning = _parse_partitioning(p)
        per_einsum[out_name] = em
    # top-level partitioning applying to every einsum (single-einsum style)
    if part and not (set(part) & names):
        shared = _parse_partitioning(part)
        for em in per_einsum.values():
            if not em.partitioning:
                em.partitioning = dict(shared)

    mapping = MappingSpec(
        rank_order={t: list(r) for t, r in (mp.get("rank-order") or {}).items()},
        per_einsum=per_einsum,
    )

    fmt = FormatSpec()
    for tensor, cfgs in (d.get("format") or {}).items():
        fmt.tensors[tensor] = {}
        for cfg_name, ranks in cfgs.items():
            fmt.tensors[tensor][cfg_name] = TensorFormat(
                cfg_name,
                {r: RankFormat(**attrs) for r, attrs in ranks.items()})

    arch = ArchSpec()
    ad = d.get("architecture") or {}
    arch.clock_ghz = ad.get("clock_ghz", 1.0)

    def _level(ld: Dict[str, Any]) -> ArchLevel:
        return ArchLevel(
            name=ld["name"], num=ld.get("num", 1),
            local=[Component(c["name"], c["class"],
                             {k: v for k, v in c.items()
                              if k not in ("name", "class")})
                   for c in ld.get("local", [])],
            subtree=[_level(s) for s in ld.get("subtree", [])])

    for topo_name, root in (ad.get("topologies") or {}).items():
        arch.topologies[topo_name] = _level(root)

    binding = BindingSpec()
    for out_name, bd in (d.get("binding") or {}).items():
        eb = EinsumBinding(topology=bd.get("topology", "main"))
        for sb in bd.get("storage", []):
            eb.storage.append(StorageBinding(
                component=sb["component"], tensor=sb["tensor"],
                rank=sb["rank"], type=sb.get("type", "elem"),
                config=sb.get("config", "default"),
                style=sb.get("style", "lazy"),
                evict_on=sb.get("evict-on", sb.get("evict_on"))))
        for cb in bd.get("compute", []):
            eb.compute.append(ComputeBinding(component=cb["component"],
                                             op=cb["op"]))
        binding.per_einsum[out_name] = eb

    return AcceleratorSpec(name=d.get("name", name), einsum=einsum_spec,
                           mapping=mapping, format=fmt, arch=arch,
                           binding=binding)
