"""Loop-nest interpreter: executes one mapped Einsum on fibertrees.

This is the imperative-style IR the TeAAL simulator generator produces
(Section 4.3): a loop nest whose levels follow the mapping's loop order,
with per-rank fiber co-iteration (intersection for products / take,
union for sums), catch-up descents for tensors accessed by lookup
(affine indices, partially-bound flattened ranks), and reduction into
the output fibertree.  Every data access and compute op is reported to
an Instrumentation sink, from which the performance model derives
per-component action counts.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .einsum import (AffineIndex, BinOp, Einsum, Literal, Semiring, Take,
                     TensorAccess, expr_accesses)
from .fibertree import Fiber, FTensor
from .mapping import EinsumPlan, RankInfo
from .trace import Instrumentation, NullInstr

ABSENT = None


@dataclass
class _Cursor:
    """Traversal state of one tensor."""
    tensor: FTensor
    access: TensorAccess
    depth: int = 0                       # levels descended
    stack: Tuple = ()                    # fibers root->current
    path: Tuple = ()                     # coords root->current
    payload: Any = ABSENT                # scalar once fully descended
    absent: bool = False

    def current_fiber(self) -> Optional[Fiber]:
        if self.absent:
            return None
        return self.stack[-1] if self.stack else self.tensor.root


class _LeafIter:
    """A driving fiber's iterator, tagged with its tensor and fiber so
    intersection strategies can probe instead of enumerate."""

    __slots__ = ("tensor", "fiber", "path", "_it")

    def __init__(self, tensor, fiber, path, it):
        self.tensor = tensor
        self.fiber = fiber
        self.path = path
        self._it = it

    def __iter__(self):
        return self._it

    def __next__(self):
        return next(self._it)


class EinsumExecutor:
    """Executes one Einsum per its plan; returns the output FTensor in
    loop-concordant rank order (the generator swizzles it back)."""

    def __init__(self, plan: EinsumPlan, tensors: Dict[str, FTensor],
                 var_shapes: Dict[str, int],
                 semiring: Optional[Semiring] = None,
                 instr: Optional[Instrumentation] = None,
                 out_initial: Optional[FTensor] = None,
                 isect_strategy: str = "two_finger",
                 isect_leader: Optional[str] = None):
        self.plan = plan
        self.isect_strategy = isect_strategy
        self.isect_leader = isect_leader
        self.einsum = plan.einsum
        self.name = plan.output
        self.semiring = semiring or Semiring.arithmetic()
        self.instr = instr or NullInstr()
        self.var_shapes = var_shapes
        self.tensors = tensors

        self.accesses: List[TensorAccess] = []
        seen: Set[str] = set()
        for a in self.einsum.inputs:
            assert a.tensor not in seen, \
                f"tensor {a.tensor} accessed twice in one Einsum"
            seen.add(a.tensor)
            self.accesses.append(a)

        # output execution-form fibertree (loop-order-concordant)
        out_plan = plan.tensors[self.name]
        out_ranks = out_plan.exec_order
        self.out = FTensor(self.name, out_ranks,
                           rank_shapes={r: None for r in out_ranks},
                           upper_ranks={r for r in out_ranks
                                        if plan.created_ranks.get(r) == "upper"})
        self.out_initial = out_initial

        # per-level driver assignment
        self._assign_drive_levels()
        self._essential = self._essential_tensors(self.einsum.expr)

        # output descent schedule: loop level -> (out depth)
        self.out_descend: Dict[int, int] = {}
        depth = 0
        for li, ri in enumerate(plan.loop_order):
            if depth < len(out_ranks) and out_ranks[depth] == ri.name:
                self.out_descend[li] = depth
                depth += 1
        # output ranks not reached by loop-name matching: their coordinates
        # are computed from index-var bindings at the leaf (e.g. SIGMA's Z
        # has rank M whose var m binds at the flattened MK00 loop rank).
        self.n_matched = depth
        self.unmatched_out: List[str] = list(out_ranks[depth:])
        for r in self.unmatched_out:
            for ri in plan.loop_order:
                if set(self._rank_vars(r)) <= set(v for v in ri.vars):
                    break
            else:
                raise ValueError(
                    f"output rank {r} of {self.name} binds no loop rank")

    # ------------------------------------------------------------------ #
    def _assign_drive_levels(self) -> None:
        """For each input tensor level, decide the loop level at which it
        co-iterates (drives), or None => catch-up lookup."""
        loop = self.plan.loop_order
        # loop level at which each index var becomes bound
        var_bound_at: Dict[str, int] = {}
        for lj, rj in enumerate(loop):
            if rj.binds:
                for v in rj.vars:
                    var_bound_at[v] = lj
        self.drive: Dict[str, Dict[int, int]] = {}   # tensor -> {loop: depth}
        for acc in self.accesses:
            t = acc.tensor
            tp = self.plan.tensors[t]
            ranks = tp.exec_order
            mapping: Dict[int, int] = {}
            li = 0
            for d, r in enumerate(ranks):
                # access index for this level (original rank position)
                idx = self._level_index(acc, tp, d)
                bare = idx is None or idx.is_bare
                assigned = None
                for lj in range(li, len(loop)):
                    rj = loop[lj]
                    if rj.name == r and bare:
                        assigned = lj
                        break
                    # vars-exact match at a binding rank (e.g. tensor rank K
                    # co-iterating at loop rank K0)
                    if (bare and rj.binds and
                            tuple(sorted(rj.vars)) ==
                            tuple(sorted(self._level_vars(acc, tp, d, r)))):
                        assigned = lj
                        break
                if assigned is None:
                    # lookup level: coordinate computed from bindings during
                    # catch-up.  Deeper levels may still drive, but only at
                    # loop levels after this level's vars are all bound.
                    vars_ = (idx.vars if idx is not None
                             else self._level_vars(acc, tp, d, r))
                    # constant index (e.g. P[0, k0]): resolvable immediately
                    lv = max((var_bound_at.get(v, len(loop)) for v in vars_),
                             default=-1)
                    li = max(li, lv + 1)
                    continue
                mapping[assigned] = d
                li = assigned + 1
            self.drive[t] = mapping

    def _rank_vars(self, rank: str) -> Tuple[str, ...]:
        """Index vars spanned by a rank name (loop registry or fallback)."""
        for ri in self.plan.loop_order:
            if ri.name == rank:
                return ri.vars
        vm = self.plan.var_map.get(rank)
        if vm:
            return vm
        base = rank.rstrip("0123456789")
        return (base.lower(),) if len(base) == 1 \
            else tuple(ch.lower() for ch in base)

    def _level_vars(self, acc: TensorAccess, tp, depth: int, rank: str
                    ) -> Tuple[str, ...]:
        # vars spanned by this tensor level: from the rank-name registry
        # implied by the plan (rank names carry vars via loop RankInfos)
        for ri in self.plan.loop_order:
            if ri.name == rank:
                return ri.vars
        # fallback: strip partition suffix, lowercase
        base = rank.rstrip("0123456789")
        if len(base) > 1 and not base.isupper():
            return (base.lower(),)
        return tuple(ch.lower() for ch in base) if len(base) > 1 \
            else (base.lower(),)

    def _level_index(self, acc: TensorAccess, tp, depth: int
                     ) -> Optional[AffineIndex]:
        """The access AffineIndex corresponding to tensor level `depth`,
        or None when not recoverable (partitioned/flattened levels: bare)."""
        # map exec rank at this depth to a declared rank if it is one
        rank = tp.exec_order[depth]
        decl = list(acc.indices)
        # declared ranks of the access follow the tensor's declaration order
        from_decl = self.tensors.get(acc.tensor)
        decl_ranks = tp.declared_order
        if rank in decl_ranks and len(decl) == len(decl_ranks):
            return decl[decl_ranks.index(rank)]
        return None                     # partitioned/flattened: treat bare

    @staticmethod
    def _essential_tensors(expr) -> Set[str]:
        """Tensors appearing as a factor in *every* additive term: their
        absence annihilates the whole expression."""
        def terms(e) -> List[Set[str]]:
            if isinstance(e, BinOp) and e.op in "+-":
                return terms(e.lhs) + terms(e.rhs)
            return [ {a.tensor for a in expr_accesses(e)} ]
        ts = terms(expr)
        if not ts:
            return set()
        out = set(ts[0])
        for t in ts[1:]:
            out &= t
        return out

    # ------------------------------------------------------------------ #
    def run(self) -> FTensor:
        self.instr.begin_einsum(self.name)
        if not self.einsum.output.indices and isinstance(self.einsum.expr,
                                                         TensorAccess):
            # bare copy: P1 = P0
            src = self.tensors[self.einsum.expr.tensor]
            self.out = src.copy(self.name)
            for path, _ in self.out.iter_leaves():
                self.instr.touch(self.name, src.name, src.ranks[-1], path,
                                 "payload", "r")
                self.instr.touch(self.name, self.name, src.ranks[-1], path,
                                 "payload", "w")
            self.instr.end_einsum(self.name)
            return self.out

        cursors = {a.tensor: _Cursor(self.tensors[a.tensor], a)
                   for a in self.accesses}
        if self.out_initial is not None:
            # update-in-place semantics (e.g. GraphDynS filtered writes)
            self.out = self.out_initial.copy(self.name)
        bindings: Dict[str, int] = {}
        for c in cursors.values():
            self._catch_up(c, bindings, 0)
        self._loop(0, cursors, bindings, [self.out.root], ())
        self.instr.end_einsum(self.name)
        return self.out

    # ------------------------------------------------------------------ #
    def _catch_up(self, cur: _Cursor, bindings: Dict[str, int],
                  next_loop_level: int) -> None:
        """Descend `cur` through levels whose coordinates are computable
        from current bindings and that are not scheduled to drive at a
        later loop level."""
        if cur.absent:
            return
        tp = self.plan.tensors[cur.access.tensor]
        ranks = tp.exec_order
        drive = self.drive[cur.access.tensor]
        future_drive_depths = {d for l, d in drive.items()
                               if l >= next_loop_level}
        while cur.depth < len(ranks):
            d = cur.depth
            if d in future_drive_depths:
                return
            idx = self._level_index(cur.access, tp, d)
            rank = ranks[d]
            if idx is not None:
                if not all(v in bindings for v in idx.vars):
                    return
                coord = idx.evaluate(bindings)
            else:
                # partitioned/flattened level: coordinate derived from vars
                vars_ = self._level_vars(cur.access, tp, d, rank)
                if not all(v in bindings for v in vars_):
                    return
                vals = tuple(bindings[v] for v in vars_)
                coord = vals if len(vals) > 1 else vals[0]
                if self.plan.created_ranks.get(rank) == "upper":
                    # upper partition level: position by range (bisect)
                    coord = self._partition_start(cur, coord)
                    if coord is None:
                        self._mark_absent(cur)
                        return
            fiber = cur.current_fiber()
            self.instr.touch(self.name, cur.access.tensor, rank,
                             cur.path + (coord,), "coord", "r")
            payload = fiber.lookup(coord) if fiber is not None else None
            if payload is None:
                self._mark_absent(cur)
                return
            self._descend(cur, rank, coord, payload)

    def _partition_start(self, cur: _Cursor, coord) -> Optional[Any]:
        fiber = cur.current_fiber()
        if fiber is None or not fiber.coords:
            return None
        i = bisect.bisect_right(fiber.coords, coord) - 1
        if i < 0:
            return None
        return fiber.coords[i]

    def _mark_absent(self, cur: _Cursor) -> None:
        cur.absent = True
        cur.payload = ABSENT

    def _descend(self, cur: _Cursor, rank: str, coord, payload) -> None:
        if isinstance(payload, Fiber):
            cur.stack = cur.stack + (payload,)
            cur.payload = ABSENT
        else:
            cur.stack = cur.stack + (payload,)
            cur.payload = payload
            self.instr.touch(self.name, cur.access.tensor, rank,
                             cur.path + (coord,), "payload", "r")
        cur.path = cur.path + (coord,)
        cur.depth += 1

    # ------------------------------------------------------------------ #
    def _loop(self, level: int, cursors: Dict[str, _Cursor],
              bindings: Dict[str, int], out_stack: List,
              out_path: Tuple = ()) -> None:
        loop = self.plan.loop_order
        if level == len(loop):
            self._leaf(cursors, bindings, out_stack, out_path)
            return
        ri = loop[level]
        drivers = [t for t, m in self.drive.items() if level in m
                   and not cursors[t].absent]
        out_depth = self.out_descend.get(level)

        def body(coord, payloads: Dict[str, Any]):
            self.instr.iterate(self.name, ri.name, coord=coord)
            new_bind = bindings
            if ri.binds:
                new_bind = dict(bindings)
                vals = coord if isinstance(coord, tuple) else (coord,)
                for v, val in zip(ri.vars, vals):
                    new_bind[v] = val
            # clone cursors, descend drivers
            new_cursors: Dict[str, _Cursor] = {}
            for t, c in cursors.items():
                if t in payloads and not c.absent:
                    nc = _Cursor(c.tensor, c.access, c.depth, c.stack,
                                 c.path, c.payload, c.absent)
                    self._descend(nc, ri.name, coord, payloads[t])
                    new_cursors[t] = nc
                elif t in self._essential and t in drivers:
                    return            # unreachable (intersection semantics)
                else:
                    nc = _Cursor(c.tensor, c.access, c.depth, c.stack,
                                 c.path, c.payload, c.absent)
                    if t in drivers and t not in payloads:
                        # union semantics: this driver lacks the coordinate
                        nc.absent = True
                    new_cursors[t] = nc
            new_out = out_stack
            new_out_path = out_path
            if out_depth is not None:
                parent = out_stack[-1]
                is_insertion = (not self.unmatched_out
                                and out_depth == len(self.out.ranks) - 1)
                if is_insertion:
                    new_out = out_stack + [(parent, coord)]
                else:
                    new_out = out_stack + [parent.get_or_create(coord, Fiber)]
                new_out_path = out_path + (coord,)
            if ri.binds:
                for nc in new_cursors.values():
                    self._catch_up(nc, new_bind, level + 1)
                # essential tensor turned absent -> dead branch
                for t in self._essential:
                    if t in new_cursors and new_cursors[t].absent:
                        self.instr.advance(self.name, ri.name)
                        return
            self._loop(level + 1, new_cursors, new_bind, new_out, new_out_path)
            self.instr.advance(self.name, ri.name)

        if drivers:
            for coord, payloads in self._coiterate(self.einsum.expr, drivers,
                                                   cursors, ri):
                body(coord, payloads)
        else:
            # dense range over the rank's vars (e.g. conv output rank)
            assert not ri.flattened, \
                f"no driver for flattened rank {ri.name}"
            var = ri.vars[0]
            shape = self.var_shapes.get(var)
            assert shape is not None, f"unknown shape for var {var!r}"
            for coord in range(shape):
                body(coord, {})

    # ------------------------------------------------------------------ #
    def _coiterate(self, expr, drivers: List[str],
                   cursors: Dict[str, _Cursor], ri: RankInfo):
        """Iterator of (coord, {tensor: payload}) per the expression
        structure: intersection across product/take factors, union across
        additive terms."""
        it = self._build_coiter(expr, set(drivers), cursors, ri)
        if it is None:
            return iter(())
        return it

    def _build_coiter(self, expr, active: Set[str],
                      cursors: Dict[str, _Cursor], ri: RankInfo):
        if isinstance(expr, TensorAccess):
            if expr.tensor not in active:
                return None
            fiber = cursors[expr.tensor].current_fiber()
            if fiber is None:
                return None
            t = expr.tensor

            def leaf():
                for c, p in fiber:
                    self.instr.touch(self.name, t, ri.name,
                                     cursors[t].path + (c,), "coord", "r")
                    yield c, {t: p}
            return _LeafIter(t, fiber, cursors[t].path, leaf())
        if isinstance(expr, Take):
            children = [self._build_coiter(a, active, cursors, ri)
                        for a in expr.args]
            children = [c for c in children if c is not None]
            return self._intersect_many(children, ri)
        if isinstance(expr, BinOp):
            lhs = self._build_coiter(expr.lhs, active, cursors, ri)
            rhs = self._build_coiter(expr.rhs, active, cursors, ri)
            if expr.op == "*":
                children = [c for c in (lhs, rhs) if c is not None]
                return self._intersect_many(children, ri)
            return self._union2(lhs, rhs, ri)
        return None

    def _intersect_many(self, children: List, ri: RankInfo):
        if not children:
            return None
        if len(children) == 1:
            return children[0]
        it = children[0]
        for other in children[1:]:
            it = self._intersect2(it, other, ri)
        return it

    def _intersect2(self, a, b, ri: RankInfo):
        # leader-follower hardware (Gamma, vertex-centric apply): the
        # leader enumerates; the follower is *probed* by coordinate, so
        # its non-matching elements are never touched.
        if (self.isect_strategy == "leader_follower"
                and isinstance(a, _LeafIter) and isinstance(b, _LeafIter)):
            lead, foll = None, None
            if a.tensor == self.isect_leader:
                lead, foll = a, b
            elif b.tensor == self.isect_leader:
                lead, foll = b, a
            else:
                # no explicit leader among the pair: lead with the
                # smaller fiber (the dynamic choice real units make)
                lead, foll = (a, b) if len(a.fiber) <= len(b.fiber) \
                    else (b, a)
            return self._intersect_lookup(lead, foll, ri)

        def gen():
            ai = iter(a)
            bi = iter(b)
            av = next(ai, None)
            bv = next(bi, None)
            while av is not None and bv is not None:
                ca, pa = av
                cb, pb = bv
                for t in pa:
                    pass
                if ca == cb:
                    self.instr.isect_match(self.name, ri.name)
                    merged = dict(pa)
                    merged.update(pb)
                    yield ca, merged
                    av = next(ai, None)
                    bv = next(bi, None)
                    self._isect_count(pa, ri)
                    self._isect_count(pb, ri)
                elif ca < cb:
                    self._isect_count(pa, ri)
                    av = next(ai, None)
                else:
                    self._isect_count(pb, ri)
                    bv = next(bi, None)
            # drain counts for the remaining side are not incurred by
            # skip-ahead intersection; two-finger cost is modeled from
            # per-tensor step counts already recorded.
        return gen()

    def _isect_count(self, payload_dict: Dict[str, Any], ri: RankInfo):
        for t in payload_dict:
            self.instr.isect_step(self.name, ri.name, t)

    def _intersect_lookup(self, lead: "_LeafIter", foll: "_LeafIter",
                          ri: RankInfo):
        def gen():
            for c, pay in lead:
                self.instr.isect_step(self.name, ri.name, lead.tensor)
                self.instr.touch(self.name, foll.tensor, ri.name,
                                 foll.path + (c,), "coord", "r")
                p = foll.fiber.lookup(c)
                if p is None:
                    continue
                self.instr.isect_match(self.name, ri.name)
                merged = dict(pay)
                merged[foll.tensor] = p
                yield c, merged
        return gen()

    def _union2(self, a, b, ri: RankInfo):
        if a is None:
            return b
        if b is None:
            return a

        def gen():
            ai, bi = iter(a), iter(b)
            av = next(ai, None)
            bv = next(bi, None)
            while av is not None or bv is not None:
                if bv is None or (av is not None and av[0] < bv[0]):
                    yield av
                    av = next(ai, None)
                elif av is None or bv[0] < av[0]:
                    yield bv
                    bv = next(bi, None)
                else:
                    merged = dict(av[1])
                    merged.update(bv[1])
                    yield av[0], merged
                    av = next(ai, None)
                    bv = next(bi, None)
        return gen()

    # ------------------------------------------------------------------ #
    def _leaf(self, cursors: Dict[str, _Cursor], bindings: Dict[str, int],
              out_stack: List, out_path: Tuple = ()) -> None:
        val = self._eval(self.einsum.expr, cursors, bindings)
        if val == 0 or val is ABSENT:
            return
        # resolve output position
        tail = out_stack[-1]
        if self.unmatched_out:
            # descend remaining output ranks using coords from bindings
            fiber = tail
            assert isinstance(fiber, Fiber), "bad output stack state"
            for r in self.unmatched_out[:-1]:
                vars_ = self._rank_vars(r)
                c = (tuple(bindings[v] for v in vars_) if len(vars_) > 1
                     else bindings[vars_[0]])
                fiber = fiber.get_or_create(c, Fiber)
                out_path = out_path + (c,)
            vars_ = self._rank_vars(self.unmatched_out[-1])
            coord = (tuple(bindings[v] for v in vars_) if len(vars_) > 1
                     else bindings[vars_[0]])
        elif isinstance(tail, tuple):
            fiber, coord = tail
            out_path = out_path[:-1]
        else:
            # output has no rank at the innermost loops (fully reduced) --
            # the last descend left a (fiber, coord) pair; if out has rank 0
            # this cannot happen in our specs.
            raise AssertionError("output position not resolved")
        old = fiber.lookup(coord)
        ranks = self.out.ranks
        wpath = out_path + (coord,)
        if old is None:
            fiber.insert(coord, val)
            self.instr.touch(self.name, self.name, ranks[-1],
                             wpath, "payload", "w")
        else:
            self.instr.compute(self.name, "add")
            self.instr.touch(self.name, self.name, ranks[-1],
                             wpath, "payload", "r")
            fiber.insert(coord, self.semiring.add(old, val))
            self.instr.touch(self.name, self.name, ranks[-1],
                             wpath, "payload", "w")

    def _eval(self, expr, cursors: Dict[str, _Cursor],
              bindings: Dict[str, int]):
        if isinstance(expr, Literal):
            return expr.value
        if isinstance(expr, TensorAccess):
            cur = cursors[expr.tensor]
            if cur.absent:
                return 0
            if cur.depth < len(self.plan.tensors[expr.tensor].exec_order):
                # not fully descended (shouldn't happen after catch-up)
                return 0
            return cur.payload
        if isinstance(expr, Take):
            vals = [self._eval(a, cursors, bindings) for a in expr.args]
            if any(v == 0 or v is ABSENT for v in vals):
                return 0
            return vals[expr.which]
        if isinstance(expr, BinOp):
            lv = self._eval(expr.lhs, cursors, bindings)
            rv = self._eval(expr.rhs, cursors, bindings)
            if expr.op == "*":
                if lv == 0 or rv == 0:
                    return 0
                self.instr.compute(self.name, "mul")
                return self.semiring.mul(lv, rv)
            if expr.op == "+":
                if lv == 0:
                    return rv
                if rv == 0:
                    return lv
                self.instr.compute(self.name, "add")
                return self.semiring.add(lv, rv)
            if expr.op == "-":
                self.instr.compute(self.name, "add")
                return self.semiring.sub(lv, rv)
        raise TypeError(f"bad expr {expr!r}")


# ---------------------------------------------------------------------- #
# pluggable execution backends
# ---------------------------------------------------------------------- #
class ExecutorBackend:
    """Strategy interface: executes one mapped Einsum on execution-form
    tensors and returns the output fibertree in loop-concordant order.

    Implementations must be interchangeable: identical output tensors
    and identical aggregate Instrumentation action counts for the same
    (plan, tensors) inputs.  ``PythonBackend`` is the per-element
    correctness oracle; ``VectorBackend`` (core/vectorized.py) runs
    per-rank co-iteration over columnar CSF arrays and reports the same
    action counts in aggregate; ``AnalyticBackend`` (core/analytic.py)
    relaxes the contract -- it models the counts statistically and
    returns an *empty* output tensor, trading data fidelity for
    closed-form speed (see DESIGN.md).

    Optional protocol extensions the generator probes with getattr:

      * ``last_path`` / ``last_fallback_reason`` -- set after each
        ``execute`` when the backend transparently fell back to the
        oracle, so the run result can surface silent fallbacks;
      * ``last_downgrades`` / ``last_batch_downgrades`` -- structured
        ``DowngradeEvent`` lists (kernels/backends.py) drained after
        each ``execute`` / ``execute_batch`` when the backend routes
        seam calls through a guarded degradation chain; the generator
        copies them onto ``SimResult.downgrade_events`` so no kernel
        downgrade is ever silent;
      * ``stage_seconds`` / ``last_batch_stage_seconds`` -- per-stage
        wall-second dicts from a profiling backend (VectorBackend's
        pipeline stages); the generator aggregates them onto
        ``SimResult.stage_seconds`` / ``Report.stage_seconds`` so
        benchmarks read the public result instead of backend
        internals;
      * ``prepare_inputs(plan, tensors, var_shapes) -> bool`` -- False
        lets the generator skip ``transform_all`` (analytic
        calibration-cache fast path);
      * ``merge_estimate(tensor, stored_ranks, prefix_depth,
        var_shapes)`` -- analytic merger-work events for
        unmaterialized intermediates;
      * ``notify_copy(dst, src)`` -- whole-tensor aliases the generator
        short-circuits, so stats-tracking backends can follow them.

    ``materializes`` is False for backends whose outputs carry no data
    (analytic): convergence-driven flows (``run_iterative``) must
    reject them rather than mistake empty outputs for convergence.
    """

    name = "abstract"
    materializes = True

    def execute(self, plan: EinsumPlan, tensors: Dict[str, FTensor],
                var_shapes: Dict[str, int],
                semiring: Optional[Semiring] = None,
                instr: Optional[Instrumentation] = None,
                out_initial: Optional[FTensor] = None,
                isect_strategy: str = "two_finger",
                isect_leader: Optional[str] = None) -> FTensor:
        raise NotImplementedError

    def execute_batch(self, requests: "List[Dict]") -> "List[FTensor]":
        """Execute a batch of *independent* Einsums (no request reads
        another's output).  Each request is an ``execute`` kwargs dict;
        results come back in request order with instrumentation and
        per-request fallback state identical to sequential execution.

        The default lowering is the sequential loop; backends override
        to share work across the batch (``VectorBackend`` reuses its
        kernel dispatch and workspace buffers and records the per-
        request paths on ``last_batch_paths``).  When a tracer is
        installed each request runs inside an ``einsum:<output>`` span
        so the batch seam carries the active trace (``VectorBackend``
        opens its own richer span in ``execute`` instead)."""
        from repro.obs.spans import maybe_span
        outs, paths, reasons, events = [], [], [], []
        for req in requests:
            with maybe_span("einsum:" + req["plan"].output, "einsum",
                            {"backend": getattr(self, "name", "?")}):
                outs.append(self.execute(**req))
            paths.append(getattr(self, "last_path", None))
            reasons.append(getattr(self, "last_fallback_reason", None))
            events.append(list(getattr(self, "last_downgrades", ()) or ()))
        self.last_batch_paths = paths
        self.last_batch_fallbacks = reasons
        self.last_batch_downgrades = events
        return outs


class PythonBackend(ExecutorBackend):
    """The original object-interpreter path, kept as the oracle."""

    name = "python"

    def execute(self, plan, tensors, var_shapes, semiring=None, instr=None,
                out_initial=None, isect_strategy="two_finger",
                isect_leader=None) -> FTensor:
        return EinsumExecutor(
            plan, tensors, var_shapes, semiring=semiring, instr=instr,
            out_initial=out_initial, isect_strategy=isect_strategy,
            isect_leader=isect_leader).run()


def get_backend(backend: "str | ExecutorBackend | None") -> ExecutorBackend:
    """Resolve a backend selection
    ('python' | 'vector' | 'analytic' | instance)."""
    if backend is None:
        return PythonBackend()
    if isinstance(backend, ExecutorBackend):
        return backend
    if backend == "python":
        return PythonBackend()
    if backend == "vector":
        from .vectorized import VectorBackend
        return VectorBackend()
    if backend == "analytic":
        from .analytic import AnalyticBackend
        return AnalyticBackend()
    raise ValueError(f"unknown execution backend {backend!r} "
                     f"(expected 'python', 'vector' or 'analytic')")
