"""Runtime invariant guards for the execution layer (``REPRO_GUARDS``).

Execution-driven backends can be corrupted silently -- a faulty kernel
backend, a bad device, a poisoned reduction -- in ways an analytic
model cannot.  This module hosts the *cheap* runtime invariant checks
the vector pipeline and the CSF builders run on the hot path, behind a
single process-wide knob:

    REPRO_GUARDS=strict   violations raise ``GuardViolation``
    REPRO_GUARDS=warn     violations warn once per (check, site) and
                          execution continues (the default)
    REPRO_GUARDS=off      checks are skipped entirely

The checks are deliberately O(n) single-pass or O(1): a NaN/inf scan
over leaf values (arithmetic semirings only -- min-plus legitimately
folds infinities), a monotone-segments check on CSF builds, and stream
conservation ((yielded, drained) accounting) on frontier levels.  The
guard budget is <= 3% of hot-path wall time at the default level
(asserted by ``BENCH_backend.json`` regressions).

Seam-level *postconditions* (output length / range / sortedness of the
kernel-dispatch seams) live with the guarded dispatcher in
``kernels/backends.py`` but consult the same knob; there a violation is
actionable -- the seam downgrades to the next backend in the chain --
rather than merely raised or warned.
"""
from __future__ import annotations

import os
import warnings
from typing import Set, Tuple

import numpy as np

ENV_VAR = "REPRO_GUARDS"

LEVELS = ("strict", "warn", "off")

DEFAULT_LEVEL = "warn"


class GuardViolation(RuntimeError):
    """A runtime invariant of the execution layer failed."""


_warned: Set[Tuple[str, str]] = set()


#: (raw env value, parsed level) of the last lookup -- level() runs on
#: every guarded seam call, so the strip/lower/validate is memoized on
#: the raw string while the env var itself is still read per call
_level_cache: Tuple[str, str] = ("\0unset", DEFAULT_LEVEL)


def level() -> str:
    """The active guard level (env-read per call: tests flip it)."""
    global _level_cache
    raw = os.environ.get(ENV_VAR, DEFAULT_LEVEL)
    if raw != _level_cache[0]:
        lv = raw.strip().lower()
        _level_cache = (raw, lv if lv in LEVELS else DEFAULT_LEVEL)
    return _level_cache[1]


def enabled() -> bool:
    return level() != "off"


def violation(check: str, site: str, detail: str = "") -> None:
    """Report a failed invariant per the active level."""
    lv = level()
    if lv == "off":
        return
    msg = f"guard {check!r} violated at {site}" + \
        (f": {detail}" if detail else "")
    # telemetry first -- a strict-mode raise must not lose the tally.
    # Imported lazily: violations are rare, and repro.obs must stay
    # import-free from the guard hot path.
    from repro.obs.metrics import metrics
    from repro.obs.spans import active_tracer
    metrics().counter(f"guards.violation/{check}").inc()
    tr = active_tracer()
    if tr is not None:
        tr.instant(f"guard:{check}", cat="guard",
                   args={"site": site, "detail": detail, "level": lv})
    if lv == "strict":
        raise GuardViolation(msg)
    key = (check, site)
    if key not in _warned:
        _warned.add(key)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)


# ---------------------------------------------------------------------- #
# the checks
# ---------------------------------------------------------------------- #
def check_finite(arr: np.ndarray, site: str) -> None:
    """NaN/inf scan (call only where the algebra promises finiteness,
    i.e. arithmetic semirings over real data)."""
    if level() == "off" or len(arr) == 0:
        return
    if arr.dtype.kind != "f":
        return
    with np.errstate(invalid="ignore"):
        bad = not bool(np.isfinite(arr).all())
    if bad:
        violation("finite-values", site,
                  f"{int((~np.isfinite(arr)).sum())} non-finite of "
                  f"{len(arr)}")


def check_monotone_segments(seg: np.ndarray, site: str) -> None:
    """CSF segment arrays must be non-decreasing and start at 0."""
    if level() == "off" or len(seg) == 0:
        return
    if int(seg[0]) != 0 or (len(seg) > 1
                            and bool((np.diff(seg) < 0).any())):
        violation("monotone-segments", site,
                  "segment offsets decrease or do not start at 0")


def check_conservation(yielded: int, drained: int, site: str) -> None:
    """(yielded, drained) stream-accounting conservation: a node cannot
    drain more elements than were yielded to it."""
    if level() == "off":
        return
    if drained > yielded or yielded < 0 or drained < 0:
        violation("stream-conservation", site,
                  f"yielded={yielded} drained={drained}")


def reset_warned() -> None:
    """Test hook: forget which (check, site) pairs already warned."""
    _warned.clear()
