"""The TeAAL simulator generator (Sec. 4.3, Fig. 6).

Combines the einsum + mapping specs into executable mapped loop nests
(``EinsumExecutor``), runs them on real tensors represented as
fibertrees, streams the resulting access/compute traces into the
``PerformanceModel`` (format/architecture/binding-aware component
models), and finally produces summary statistics (execution time,
memory traffic, energy) via ``metrics.evaluate``.

Online rank swizzles of intermediate tensors (OuterSPACE's sort,
Gamma's hardware merge) are detected automatically by comparing each
intermediate input tensor's stored rank order to the consuming Einsum's
concordant execution order; the required merge work (elements, sorted
runs) is emitted to the bound Merger component.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .cascade import CascadeDAG
from .components import PerformanceModel
from .einsum import Semiring
from .fibertree import Fiber, FTensor
from .iteration import EinsumExecutor, ExecutorBackend, get_backend
from .mapping import EinsumPlan, MappingResolver
from .metrics import Report, evaluate
from .spec import AcceleratorSpec
from .trace import Instrumentation, NullInstr, TeeInstr


# ---------------------------------------------------------------------- #
# declared-form reconstruction
# ---------------------------------------------------------------------- #
def restore_declared(out_exec: FTensor, plan: EinsumPlan,
                     declared_order: Sequence[str],
                     rank_shapes: Optional[Dict[str, int]] = None) -> FTensor:
    """Rebuild the executor's exec-form output (possibly partitioned /
    flattened / loop-ordered) into its declared storage form with
    original coordinates."""
    var_of_rank: Dict[str, Tuple[str, ...]] = {}
    for r in out_exec.ranks:
        var_of_rank[r] = plan.var_map.get(r, (r.lower(),))

    declared = list(declared_order)
    decl_vars = [plan.var_map.get(r, (r.lower(),))[0] for r in declared]

    out = FTensor(out_exec.name, declared,
                  rank_shapes={r: (rank_shapes or {}).get(r)
                               for r in declared},
                  default=out_exec.default)
    uppers = out_exec.upper_ranks
    for path, val in out_exec.iter_leaves():
        bind: Dict[str, Any] = {}
        for rank, c in zip(out_exec.ranks, path):
            if rank in uppers:
                continue
            vs = var_of_rank[rank]
            if isinstance(c, tuple):
                for v, cv in zip(vs, c):
                    bind[v] = cv
            else:
                bind[vs[0]] = c
        coords = [bind[v] for v in decl_vars]
        node = out.root
        for c in coords[:-1]:
            node = node.get_or_create(c, Fiber)
        node.insert(coords[-1], val)
    return out


# ---------------------------------------------------------------------- #
# online-swizzle (merge) detection
# ---------------------------------------------------------------------- #
def _innermost_var_order(plan: EinsumPlan, tensor: str) -> List[str]:
    """Per-var traversal order of a tensor in execution form: the order
    in which each var's *binding* level appears."""
    tp = plan.tensors[tensor]
    seen: List[str] = []
    for r in reversed(tp.exec_order):
        for v in reversed(plan.var_map.get(r, (r.lower(),))):
            if v not in seen:
                seen.append(v)
    seen.reverse()
    return seen


def merge_prefix(stored_vars: Sequence[str],
                 exec_var_order: Sequence[str]) -> Optional[int]:
    """First discordant level between a stored rank order and the
    consuming Einsum's execution var order, or None when concordant
    (no online swizzle / merger work needed)."""
    p = 0
    while (p < len(stored_vars) and p < len(exec_var_order)
           and stored_vars[p] == exec_var_order[p]):
        p += 1
    if p >= len(stored_vars) - 1:
        return None
    return p


def merge_events(stored: FTensor, exec_var_order: Sequence[str]
                 ) -> List[Tuple[int, int]]:
    """(elements, lists) merge work needed to swizzle ``stored`` (in its
    declared form) into an order consistent with ``exec_var_order``."""
    stored_vars = [r.lower() for r in stored.ranks]
    p = merge_prefix(stored_vars, exec_var_order)
    if p is None:
        return []                             # concordant (or trivial)

    events: List[Tuple[int, int]] = []

    def n_leaves(node: Any) -> int:
        if not isinstance(node, Fiber):
            return 1
        return sum(n_leaves(c) for _, c in node)

    def walk(fiber: Fiber, depth: int) -> None:
        if depth == p:
            elements = n_leaves(fiber)
            lists = len(fiber)
            if elements and lists:
                events.append((elements, lists))
            return
        for _, child in fiber:
            walk(child, depth + 1)

    walk(stored.root, 0)
    return events


def isect_configs(spec: AcceleratorSpec) -> Tuple[Tuple[str, str, Any], ...]:
    """Per-einsum intersection config (strategy, leader) read from each
    Einsum's bound topology.  These arch attributes shape the *event
    stream itself* (unlike capacities/bandwidths, which only shape its
    consumption), so the DSE engine folds them into its batched-replay
    group key alongside ``mapping_signature`` -- two points may only
    share a recorded stream when both agree."""
    out = []
    for e in spec.einsum.expressions:
        name = e.output.tensor
        topo_name = spec.binding.get(name).topology
        topo = spec.arch.topologies.get(topo_name)
        if topo is None and spec.arch.topologies:
            topo = next(iter(spec.arch.topologies.values()))
        strategy, leader = "two_finger", None
        if topo is not None:
            for comp, _ in topo.all_components():
                if comp.klass == "Intersection":
                    strategy = comp.attrs.get("type", "two_finger")
                    leader = comp.attrs.get("leader")
                    break
        out.append((name, strategy, leader))
    return tuple(out)


# ---------------------------------------------------------------------- #
# the cascade simulator
# ---------------------------------------------------------------------- #
@dataclass
class SimResult:
    tensors: Dict[str, FTensor]              # all tensors, declared form
    report: Optional[Report]                 # None when model disabled
    #: einsum -> reason, for Einsums the selected backend executed
    #: through the Python oracle instead of its fast path (empty when
    #: every Einsum ran native)
    fallback_reasons: Dict[str, str] = field(default_factory=dict)
    #: einsum -> kernel-dispatch DowngradeEvents recorded while that
    #: Einsum executed (guarded-chain retries / downgrades / demotions;
    #: empty when every seam call succeeded on its primary backend)
    downgrade_events: Dict[str, list] = field(default_factory=dict)
    #: einsum -> {stage: wall seconds} from a profiling backend
    #: (VectorBackend pipeline stages; empty unless the backend
    #: profiled -- `profile=True` or an active tracer)
    stage_seconds: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def __getitem__(self, name: str) -> FTensor:
        return self.tensors[name]


class CascadeSimulator:
    """spec + real input tensors -> outputs + performance report.

    ``backend`` selects the execution engine per Einsum: 'python' (the
    object-interpreter oracle), 'vector' (columnar CSF co-iteration,
    with transparent per-Einsum fallback to the oracle for unsupported
    plans), or any ExecutorBackend instance."""

    def __init__(self, spec: AcceleratorSpec,
                 params: Optional[Dict[str, int]] = None,
                 semiring: Optional[Semiring] = None,
                 extra_instr: Optional[Instrumentation] = None,
                 model: bool = True,
                 backend: "str | ExecutorBackend | None" = None,
                 plans: Optional[Dict[str, EinsumPlan]] = None):
        self.spec = spec
        self.backend: ExecutorBackend = get_backend(backend)
        self.resolver = MappingResolver(spec, params)
        self.semiring = semiring or spec.einsum.semiring
        self.dag = CascadeDAG.from_spec(spec)
        # `plans` lets a sweep engine reuse memoized lowering across
        # points whose mapping signature is identical (cascade.py)
        self.plans: Dict[str, EinsumPlan] = plans if plans is not None else {
            e.output.tensor: self.resolver.plan(e.output.tensor)
            for e in spec.einsum.expressions
        }
        self.model: Optional[PerformanceModel] = (
            PerformanceModel(spec, self.plans) if model else None)
        sinks = [s for s in (self.model, extra_instr) if s is not None]
        self.instr: Instrumentation = (
            sinks[0] if len(sinks) == 1 else
            TeeInstr(*sinks) if sinks else NullInstr())

    # ------------------------------------------------------------------ #
    def _to_ftensor(self, name: str, value: Any) -> FTensor:
        if isinstance(value, FTensor):
            return value
        ranks = (self.spec.mapping.rank_order.get(name)
                 or self.spec.einsum.declaration[name])
        arr = np.asarray(value)
        decl = self.spec.einsum.declaration[name]
        if list(ranks) != list(decl):
            # provided dense arrays follow the declaration order
            ft = FTensor.from_dense(name, decl, arr)
            return ft.swizzle(ranks)
        return FTensor.from_dense(name, ranks, arr)

    def _var_shapes(self, store: Dict[str, FTensor],
                    overrides: Optional[Dict[str, int]]) -> Dict[str, int]:
        shapes: Dict[str, int] = dict(overrides or {})
        for ft in store.values():
            for r in ft.ranks:
                s = ft.rank_shapes.get(r)
                if isinstance(s, int):
                    v = r.lower()
                    shapes[v] = max(shapes.get(v, 0), s)
        return shapes

    def _isect_config(self, out_name: str):
        """Intersection strategy for this Einsum from its bound topology's
        Intersection component (type, leader attrs)."""
        for name, strategy, leader in isect_configs(self.spec):
            if name == out_name:
                return (strategy, leader)
        return ("two_finger", None)

    # ------------------------------------------------------------------ #
    def run(self, inputs: Dict[str, Any],
            var_shapes: Optional[Dict[str, int]] = None) -> SimResult:
        from repro.obs.spans import maybe_span

        with maybe_span("cascade:" + (self.spec.name or "cascade"),
                        "cascade",
                        {"backend": getattr(self.backend, "name", "?")}):
            return self._run_cascade(inputs, var_shapes)

    def _run_cascade(self, inputs: Dict[str, Any],
                     var_shapes: Optional[Dict[str, int]] = None
                     ) -> SimResult:
        from .einsum import TensorAccess as _TA

        store: Dict[str, FTensor] = {
            name: self._to_ftensor(name, v) for name, v in inputs.items()}
        shapes = self._var_shapes(store, var_shapes)
        fallbacks: Dict[str, str] = {}
        downgrades: Dict[str, list] = {}
        stage_secs: Dict[str, Dict[str, float]] = {}

        # consecutive independent Einsums (no member reads or rewrites
        # another member's output) batch into one execute_batch call;
        # outputs land in the store at flush time.  Results, counts, and
        # fallback recording are identical to the sequential loop: a
        # batched member's inputs and shapes cannot be affected by the
        # other members (shape maxima never grow from adding outputs,
        # since output rank shapes derive from the same shapes dict).
        pending: List[Dict[str, Any]] = []
        pending_out: List[str] = []

        def flush() -> None:
            nonlocal shapes
            if not pending:
                return
            outs = self.backend.execute_batch(list(pending))
            paths = getattr(self.backend, "last_batch_paths", []) or []
            reasons = getattr(self.backend, "last_batch_fallbacks", []) \
                or []
            events = getattr(self.backend, "last_batch_downgrades", []) \
                or []
            stages = getattr(self.backend, "last_batch_stage_seconds",
                             []) or []
            for i, (o_name, out_exec) in enumerate(zip(pending_out, outs)):
                if i < len(paths) and paths[i] == "fallback":
                    fallbacks[o_name] = (reasons[i]
                                         if i < len(reasons) else "") or ""
                if i < len(events) and events[i]:
                    downgrades[o_name] = list(events[i])
                if i < len(stages) and stages[i]:
                    stage_secs[o_name] = dict(stages[i])
                declared_order = (self.spec.mapping.rank_order.get(o_name)
                                  or self.spec.einsum.declaration[o_name])
                decl_shapes = {}
                for r in declared_order:
                    v = r.lower()
                    if v in shapes:
                        decl_shapes[r] = shapes[v]
                store[o_name] = restore_declared(
                    out_exec, self.plans[o_name], declared_order,
                    decl_shapes)
            pending.clear()
            pending_out.clear()
            shapes = self._var_shapes(store, var_shapes)

        for e in self.spec.einsum.expressions:
            out_name = e.output.tensor
            plan = self.plans[out_name]

            # bare whole-tensor copy (e.g. "P1 = P0"): a rename, not data
            # movement -- alias with zero hardware cost.
            if (not e.output.indices and isinstance(e.expr, _TA)
                    and not e.expr.indices):
                flush()
                store[out_name] = store[e.expr.tensor].copy(out_name)
                notify = getattr(self.backend, "notify_copy", None)
                if notify is not None:
                    notify(out_name, e.expr.tensor)
                continue

            if out_name in pending_out \
                    or any(t in pending_out for t in e.input_names):
                flush()

            missing = [t for t in e.input_names if t not in store]
            if missing:
                raise KeyError(f"einsum {out_name}: missing inputs {missing}")

            # stats-only backends (analytic) can skip the data transform
            # entirely once their calibration cache covers this Einsum
            prepare = getattr(self.backend, "prepare_inputs", None)
            need_data = True
            if prepare is not None and out_name not in store:
                need_data = prepare(plan,
                                    {t: store[t] for t in e.input_names},
                                    shapes)
            exec_forms = (self.resolver.transform_all(
                out_name, {t: store[t] for t in e.input_names})
                if need_data else {})

            # online rank swizzles of intermediates -> merger work
            estimate = getattr(self.backend, "merge_estimate", None)
            for t in e.input_names:
                if not self.dag.is_intermediate(t):
                    continue
                order = _innermost_var_order(plan, t)
                stored_ranks = list(store[t].ranks)
                p = merge_prefix([r.lower() for r in stored_ranks], order)
                if p is None:
                    continue
                events = merge_events(store[t], order)
                if not events and estimate is not None:
                    events = estimate(t, stored_ranks, p, shapes) or []
                for elements, lists in events:
                    self.instr.merge(out_name, t, elements, lists)

            out_initial = None
            if out_name in store:
                # update-in-place semantics (e.g. GraphDynS filtered write)
                out_initial = self.resolver.transform_tensor(
                    out_name, store[out_name])

            if self.model is not None and exec_forms:
                self.model.register_exec_tensors(out_name, exec_forms)

            strategy, leader = self._isect_config(out_name)
            pending.append(dict(
                plan=plan, tensors=exec_forms, var_shapes=shapes,
                semiring=self.semiring, instr=self.instr,
                out_initial=out_initial, isect_strategy=strategy,
                isect_leader=leader))
            pending_out.append(out_name)
        flush()

        report = (evaluate(self.spec, self.plans, self.model)
                  if self.model is not None else None)
        if report is not None:
            report.fallback_reasons = dict(fallbacks)
            report.downgrade_events = dict(downgrades)
            # per-Einsum stage seconds aggregate into one dict on the
            # report (the cross-cascade pipeline profile)
            agg: Dict[str, float] = {}
            for per in stage_secs.values():
                for k, v in per.items():
                    agg[k] = agg.get(k, 0.0) + float(v)
            report.stage_seconds = agg
        return SimResult(tensors=store, report=report,
                         fallback_reasons=dict(fallbacks),
                         downgrade_events=dict(downgrades),
                         stage_seconds=dict(stage_secs))

    # ------------------------------------------------------------------ #
    def run_iterative(self, inputs: Dict[str, Any],
                      carry: Dict[str, str],
                      max_iters: int = 64,
                      done_when_empty: Optional[str] = None,
                      var_shapes: Optional[Dict[str, int]] = None
                      ) -> Tuple[SimResult, int]:
        """Run the cascade repeatedly (vertex-centric iterations).

        ``carry`` maps next-iteration input names to this iteration's
        tensor names (e.g. {'A0': 'A1', 'P0': 'P1'}); iteration stops
        when tensor ``done_when_empty`` has no nonzeros or after
        ``max_iters``."""
        if not getattr(self.backend, "materializes", True):
            raise ValueError(
                f"backend {self.backend.name!r} materializes no output "
                "data: carried tensors and the done_when_empty test "
                "would read empty results -- use an execution backend "
                "('python' or 'vector') for iterative cascades")
        state = dict(inputs)
        result: Optional[SimResult] = None
        iters = 0
        for it in range(max_iters):
            result = self.run(state, var_shapes)
            iters = it + 1
            if done_when_empty is not None:
                flag = result.tensors.get(done_when_empty)
                if flag is None or flag.nnz == 0:
                    break
            for dst, src in carry.items():
                ft = result.tensors[src]
                dst_ranks = (self.spec.mapping.rank_order.get(dst)
                             or self.spec.einsum.declaration.get(dst))
                if dst_ranks and list(ft.ranks) != list(dst_ranks):
                    # positional rank rename (e.g. A1[D] -> A0[S])
                    ft = ft.rename_ranks(dict(zip(ft.ranks, dst_ranks)))
                state[dst] = ft.copy(dst)
            # non-carried inputs persist
            for name, v in inputs.items():
                if name not in carry:
                    state.setdefault(name, v)
        assert result is not None
        return result, iters


# ---------------------------------------------------------------------- #
# convenience: functional check against the dense oracle
# ---------------------------------------------------------------------- #
def check_against_dense(spec: AcceleratorSpec, inputs: Dict[str, np.ndarray],
                        var_shapes: Dict[str, int],
                        params: Optional[Dict[str, int]] = None,
                        semiring: Optional[Semiring] = None,
                        atol: float = 1e-8,
                        backend: "str | ExecutorBackend | None" = None
                        ) -> bool:
    """Run the fibertree path and the brute-force dense oracle; compare
    every cascade output."""
    from .einsum import dense_reference

    sim = CascadeSimulator(spec, params=params, semiring=semiring,
                           model=False, backend=backend)
    res = sim.run(dict(inputs), var_shapes)

    dense: Dict[str, np.ndarray] = {k: np.asarray(v)
                                    for k, v in inputs.items()}
    sr = semiring or spec.einsum.semiring
    for e in spec.einsum.expressions:
        dense[e.output.tensor] = dense_reference(e, dense, {
            k.upper(): v for k, v in var_shapes.items()}, sr)

    for e in spec.einsum.expressions:
        name = e.output.tensor
        got = res.tensors[name]
        decl = spec.einsum.declaration[name]
        stored_order = (spec.mapping.rank_order.get(name) or decl)
        ref = dense[name]
        # got is in stored order; bring ref into the same order
        perm = [decl.index(r) for r in stored_order]
        ref_swz = np.transpose(ref, perm) if ref.ndim == len(perm) else ref
        shape = [var_shapes[r.lower()] for r in stored_order]
        got_dense = np.zeros(shape)
        for path, val in got.iter_leaves():
            got_dense[tuple(path)] = val
        if not np.allclose(got_dense, ref_swz, atol=atol):
            return False
    return True
